//! Quickstart: train a utility model, score an unseen video through the
//! **AOT artifact path** (Pallas kernel → HLO → PJRT), shed at a fixed
//! target drop rate, and report QoR — the whole public API in ~80 lines.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use uals::color::NamedColor;
use uals::features::Extractor;
use uals::metrics::QorTracker;
use uals::runtime::Engine;
use uals::utility::{train, Combine, UtilityCdf};
use uals::video::{build_dataset, DatasetConfig, MIN_TARGET_PX};

fn main() -> Result<()> {
    // 1. A small labeled dataset (synthetic VisualRoad substitute).
    let mut cfg = DatasetConfig::tiny();
    cfg.frames_per_video = 300;
    let videos = build_dataset(&cfg);
    println!("dataset: {} videos × {} frames", videos.len(), videos[0].len());

    // 2. Train the utility function (Eq. 12-14) on all but the first
    //    video (the densest camera — it makes a meaningful held-out test).
    let train_idx: Vec<usize> = (1..videos.len()).collect();
    let model = train(&videos, &train_idx, &[NamedColor::Red], Combine::Single);
    println!(
        "trained red model: norm {:.4}, high-sat M+ mass {:.0}%",
        model.colors[0].norm,
        100.0 * model.colors[0].m_pos[32..].iter().sum::<f32>()
            / model.colors[0].m_pos.iter().sum::<f32>().max(1e-9)
    );

    // 3. Production path: the AOT artifact through PJRT — falling back to
    //    the native LUT fast path when artifacts aren't built (the two are
    //    numerically pinned together by rust/tests/artifact_oracle.rs).
    let engine = Engine::from_default_artifacts();
    let extractor = match &engine {
        Ok(engine) => {
            println!("PJRT platform: {}", engine.platform());
            Extractor::artifact(engine, model.clone())?
        }
        Err(e) => {
            println!("artifacts unavailable ({e}); using the native fast path");
            Extractor::native(model.clone())
        }
    };

    // 4. Seed the threshold CDF (Eq. 16/17) from the training videos.
    let mut cdf = UtilityCdf::new(2048);
    let native = Extractor::native(model);
    for &vi in &train_idx {
        let v = &videos[vi];
        for t in 0..v.len() {
            let f = v.render(t);
            let (_, u) = native.extract(&f.rgb, v.background())?;
            cdf.add(u.combined);
        }
    }
    let target_drop = 0.6;
    let threshold = cdf.threshold_for(target_drop);
    println!("target drop rate {target_drop} → utility threshold {threshold:.4}");

    // 5. Shed the held-out video and measure QoR (Eq. 2/3).
    let test = videos.first().unwrap();
    let mut qor = QorTracker::new();
    let mut dropped = 0usize;
    for t in 0..test.len() {
        let frame = test.render(t);
        let (_, utility) = extractor.extract(&frame.rgb, test.background())?;
        let keep = utility.combined >= threshold;
        dropped += !keep as usize;
        qor.observe(&frame.target_ids(NamedColor::Red, MIN_TARGET_PX), keep);
    }
    let observed = dropped as f64 / test.len() as f64;
    println!(
        "unseen video: observed drop rate {observed:.3}, QoR {:.3} over {} targets",
        qor.overall(),
        qor.num_objects()
    );

    // The paper's headline property: high drop rate with (near-)perfect QoR.
    assert!(
        qor.overall() >= 0.85,
        "expected QoR ≥ 0.85, got {:.3}",
        qor.overall()
    );
    println!("quickstart OK");
    Ok(())
}
