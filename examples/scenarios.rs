//! Workload-scenario demo for the clock-abstracted streaming core: the
//! same `ArrivalModel` plugins — bursty Poisson ingress and mid-run
//! camera churn — run under the discrete-event clock (`run_sim_with`) and
//! the wall clock (`run_realtime_with`, fast-forwarded), with metrics
//! reported through the one shared sink either way.
//!
//!     cargo run --release --example scenarios
//!
//! The core guarantees that per-frame shed/transmit decisions depend only
//! on the virtual-time event order, so both clocks agree exactly (also
//! pinned by rust/tests/core_equivalence.rs); this demo prints both sides.
//!
//! The final section runs the multi-query shared-stream path: three
//! queries over the same cameras with one feature extraction per frame
//! and a work-conserving fair-share capacity split, again under both
//! clocks (pinned by rust/tests/multiquery.rs).

use anyhow::Result;
use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::realtime::{run_multi_realtime, run_realtime_with, RealtimeConfig};
use uals::pipeline::{
    backgrounds_of, multi_backends, run_multi_sim, run_sim_with, AdaptationConfig, CameraChurn,
    FaultPlan, MultiSimConfig, PoissonArrivals, Policy, SimConfig, TransportConfig,
};
use uals::shedder::{ArbiterPolicy, QuerySet, QuerySpec};
use uals::utility::{train, Combine};
use uals::video::{build_dataset, streamer::aggregate_fps, DatasetConfig, Video, VideoConfig};

fn cameras(k: usize, frames: usize) -> Vec<Video> {
    (0..k)
        .map(|i| {
            let mut vc = VideoConfig::new(0x5CE + i as u64 % 2, 0xD0 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = 0.35;
            Video::new(vc)
        })
        .collect()
}

fn main() -> Result<()> {
    let videos = cameras(3, 200);
    let fps = aggregate_fps(&videos);
    let query = QueryConfig::single(NamedColor::Red).with_latency_bound(1000.0);

    let train_videos = build_dataset(&DatasetConfig {
        num_seeds: 2,
        videos_per_seed: 2,
        frames_per_video: 250,
        base_seed: 0x5CE9,
        target_boost: 2.0,
    });
    let idx: Vec<usize> = (0..train_videos.len()).collect();
    let model = train(&train_videos, &idx, &query.colors, Combine::Single);

    let cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: query.clone(),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xD0,
        fps_total: fps,
        transport: TransportConfig::default(),
        faults: FaultPlan::default(),
        adaptation: AdaptationConfig::default(),
    };
    let bgs = backgrounds_of(&videos);
    let extractor = Extractor::native(model.clone());
    let mk_backend = || {
        BackendQuery::new(
            query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), cfg.seed),
            25.0,
        )
    };
    let rt_cfg = RealtimeConfig {
        query: query.clone(),
        shedder: cfg.shedder.clone(),
        costs: cfg.costs.clone(),
        cost_emulation_scale: 0.0, // pure compute speed
        time_scale: 0.01,          // 100× fast-forward
        backend_tokens: 1,
        use_artifacts: false,
        policy: Policy::UtilityControlLoop,
        seed: cfg.seed,
        arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
        transport: TransportConfig::default(),
        ..Default::default()
    };

    println!("scenario        clock     ingress  transmitted  shed   qor    viol%");
    let row = |name: &str, clock: &str, ingress: u64, tx: u64, shed: u64, qor: f64, viol: f64| {
        println!(
            "{name:<15} {clock:<9} {ingress:>7}  {tx:>11}  {shed:>4}  {qor:>5.3}  {:>5.2}",
            100.0 * viol
        );
    };

    // Bursty Poisson ingress under both clocks.
    let mut backend = mk_backend();
    let sim = run_sim_with(
        PoissonArrivals::new(&videos, cfg.seed, 1.0),
        &bgs,
        &cfg,
        &extractor,
        &mut backend,
    )?;
    row(
        "bursty-poisson",
        "sim",
        sim.ingress,
        sim.transmitted,
        sim.shed,
        sim.qor.overall(),
        sim.latency.violation_rate(),
    );
    let rt = run_realtime_with(
        &videos,
        &model,
        &rt_cfg,
        PoissonArrivals::new(&videos, cfg.seed, 1.0),
    )?;
    row(
        "bursty-poisson",
        "wall",
        rt.ingress,
        rt.transmitted,
        rt.shed,
        rt.qor.overall(),
        rt.latency.violation_rate(),
    );
    assert_eq!(
        (sim.ingress, sim.transmitted, sim.shed),
        (rt.ingress, rt.transmitted, rt.shed),
        "clock-invariant decisions"
    );

    // Mid-run camera churn (staggered joins, 10 s up per camera).
    let mut backend = mk_backend();
    let sim = run_sim_with(
        CameraChurn::staggered(&videos, 5_000.0, 10_000.0),
        &bgs,
        &cfg,
        &extractor,
        &mut backend,
    )?;
    row(
        "camera-churn",
        "sim",
        sim.ingress,
        sim.transmitted,
        sim.shed,
        sim.qor.overall(),
        sim.latency.violation_rate(),
    );
    let rt = run_realtime_with(
        &videos,
        &model,
        &rt_cfg,
        CameraChurn::staggered(&videos, 5_000.0, 10_000.0),
    )?;
    row(
        "camera-churn",
        "wall",
        rt.ingress,
        rt.transmitted,
        rt.shed,
        rt.qor.overall(),
        rt.latency.violation_rate(),
    );
    assert_eq!(
        (sim.ingress, sim.transmitted, sim.shed),
        (rt.ingress, rt.transmitted, rt.shed),
        "clock-invariant decisions"
    );

    // Multi-query shared stream: three queries over the same cameras,
    // one extraction per frame, fair-share capacity split — under both
    // clocks, which must agree per query.
    let specs = vec![
        QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
        QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)).with_weight(2.0),
        QuerySpec::new(
            "either",
            QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Or),
        ),
    ];
    let set = QuerySet::train(&specs, &train_videos, &idx)?;
    let mcfg = MultiSimConfig {
        costs: cfg.costs.clone(),
        shedder: cfg.shedder.clone(),
        backend_tokens: 1,
        arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
        seed: cfg.seed,
        fps_total: fps,
        transport: TransportConfig::default(),
        faults: FaultPlan::default(),
    };
    let mq_extractor = Extractor::native(set.union_model().clone());
    let mut backends = multi_backends(&set, &mcfg.costs, mcfg.seed);
    let sim = run_multi_sim(
        uals::video::Streamer::new(&videos),
        &bgs,
        &set,
        &mcfg,
        &mq_extractor,
        &mut backends,
    )?;
    assert_eq!(sim.extractions, sim.frames, "one extraction per frame");
    let rt = run_multi_realtime(&videos, &set, &rt_cfg)?;
    for (qs, qr) in sim.queries.iter().zip(&rt.queries) {
        row(
            &format!("mq:{}", qs.name),
            "sim",
            qs.report.ingress,
            qs.report.transmitted,
            qs.report.shed,
            qs.report.qor.overall(),
            qs.report.latency.violation_rate(),
        );
        row(
            &format!("mq:{}", qr.name),
            "wall",
            qr.report.ingress,
            qr.report.transmitted,
            qr.report.shed,
            qr.report.qor.overall(),
            qr.report.latency.violation_rate(),
        );
        assert_eq!(
            (qs.report.ingress, qs.report.transmitted, qs.report.shed),
            (qr.report.ingress, qr.report.transmitted, qr.report.shed),
            "clock-invariant multi-query decisions ({})",
            qs.name
        );
    }

    println!("scenarios OK");
    Ok(())
}
