//! Workload-scenario demo for the clock-abstracted streaming core: the
//! same `ArrivalModel` plugins — bursty Poisson ingress and mid-run
//! camera churn — run under the discrete-event clock and the wall clock
//! (fast-forwarded), with metrics reported through the one shared sink
//! either way. Every run goes through the unified `Pipeline::builder()`
//! entry point: one config template, different deployment modes.
//!
//!     cargo run --release --example scenarios
//!
//! The core guarantees that per-frame shed/transmit decisions depend only
//! on the virtual-time event order, so both clocks agree exactly (also
//! pinned by rust/tests/core_equivalence.rs); this demo prints both sides.
//!
//! The final section runs the multi-query shared-stream path: three
//! queries over the same cameras with one feature extraction per frame
//! and a work-conserving fair-share capacity split, again under both
//! clocks (pinned by rust/tests/multiquery.rs).

use anyhow::Result;
use uals::color::NamedColor;
use uals::config::QueryConfig;
use uals::pipeline::{
    backgrounds_of, CameraChurn, Pipeline, PoissonArrivals, RealtimeOpts,
};
use uals::shedder::{QuerySet, QuerySpec};
use uals::utility::{train, Combine};
use uals::video::{build_dataset, streamer::aggregate_fps, DatasetConfig, Video, VideoConfig};

fn cameras(k: usize, frames: usize) -> Vec<Video> {
    (0..k)
        .map(|i| {
            let mut vc = VideoConfig::new(0x5CE + i as u64 % 2, 0xD0 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = 0.35;
            Video::new(vc)
        })
        .collect()
}

fn main() -> Result<()> {
    let videos = cameras(3, 200);
    let fps = aggregate_fps(&videos);
    let seed = 0xD0;
    let query = QueryConfig::single(NamedColor::Red).with_latency_bound(1000.0);

    let train_videos = build_dataset(&DatasetConfig {
        num_seeds: 2,
        videos_per_seed: 2,
        frames_per_video: 250,
        base_seed: 0x5CE9,
        target_boost: 2.0,
    });
    let idx: Vec<usize> = (0..train_videos.len()).collect();
    let model = train(&train_videos, &idx, &query.colors, Combine::Single);

    // One shared template; `.sim()` / `.realtime()` / `.multi_query()`
    // below compose it into each deployment.
    let template = || {
        Pipeline::builder()
            .query(query.clone())
            .seed(seed)
            .fps_total(fps)
    };
    let opts = RealtimeOpts {
        cost_emulation_scale: 0.0, // pure compute speed
        time_scale: 0.01,          // 100× fast-forward
        use_artifacts: false,
        ..Default::default()
    };
    let bgs = backgrounds_of(&videos);

    println!("scenario        clock     ingress  transmitted  shed   qor    viol%");
    let row = |name: &str, clock: &str, ingress: u64, tx: u64, shed: u64, qor: f64, viol: f64| {
        println!(
            "{name:<15} {clock:<9} {ingress:>7}  {tx:>11}  {shed:>4}  {qor:>5.3}  {:>5.2}",
            100.0 * viol
        );
    };

    // Bursty Poisson ingress under both clocks.
    let sim = template()
        .sim()
        .run_model(PoissonArrivals::new(&videos, seed, 1.0), &bgs, &model)?;
    row(
        "bursty-poisson",
        "sim",
        sim.ingress,
        sim.transmitted,
        sim.shed,
        sim.qor.overall(),
        sim.latency.violation_rate(),
    );
    let rt = template()
        .realtime(opts.clone())
        .run_with(&videos, &model, PoissonArrivals::new(&videos, seed, 1.0))?;
    row(
        "bursty-poisson",
        "wall",
        rt.ingress,
        rt.transmitted,
        rt.shed,
        rt.qor.overall(),
        rt.latency.violation_rate(),
    );
    assert_eq!(
        (sim.ingress, sim.transmitted, sim.shed),
        (rt.ingress, rt.transmitted, rt.shed),
        "clock-invariant decisions"
    );

    // Mid-run camera churn (staggered joins, 10 s up per camera).
    let sim = template()
        .sim()
        .run_model(CameraChurn::staggered(&videos, 5_000.0, 10_000.0), &bgs, &model)?;
    row(
        "camera-churn",
        "sim",
        sim.ingress,
        sim.transmitted,
        sim.shed,
        sim.qor.overall(),
        sim.latency.violation_rate(),
    );
    let rt = template()
        .realtime(opts.clone())
        .run_with(&videos, &model, CameraChurn::staggered(&videos, 5_000.0, 10_000.0))?;
    row(
        "camera-churn",
        "wall",
        rt.ingress,
        rt.transmitted,
        rt.shed,
        rt.qor.overall(),
        rt.latency.violation_rate(),
    );
    assert_eq!(
        (sim.ingress, sim.transmitted, sim.shed),
        (rt.ingress, rt.transmitted, rt.shed),
        "clock-invariant decisions"
    );

    // Multi-query shared stream: three queries over the same cameras,
    // one extraction per frame, fair-share capacity split — under both
    // clocks, which must agree per query.
    let specs = vec![
        QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
        QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)).with_weight(2.0),
        QuerySpec::new(
            "either",
            QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Or),
        ),
    ];
    let set = QuerySet::train(&specs, &train_videos, &idx)?;
    let sim = template().multi_query(&set).run(&videos)?;
    assert_eq!(sim.extractions, sim.frames, "one extraction per frame");
    let rt = template().multi_query(&set).realtime(opts).run(&videos)?;
    for (qs, qr) in sim.queries.iter().zip(&rt.queries) {
        row(
            &format!("mq:{}", qs.name),
            "sim",
            qs.report.ingress,
            qs.report.transmitted,
            qs.report.shed,
            qs.report.qor.overall(),
            qs.report.latency.violation_rate(),
        );
        row(
            &format!("mq:{}", qr.name),
            "wall",
            qr.report.ingress,
            qr.report.transmitted,
            qr.report.shed,
            qr.report.qor.overall(),
            qr.report.latency.violation_rate(),
        );
        assert_eq!(
            (qs.report.ingress, qs.report.transmitted, qs.report.shed),
            (qr.report.ingress, qr.report.transmitted, qr.report.shed),
            "clock-invariant multi-query decisions ({})",
            qs.name
        );
    }

    println!("scenarios OK");
    Ok(())
}
