//! AMBER-alert scenario (paper §II-A): track a *red* vehicle across a
//! camera network under an end-to-end latency bound, with the full
//! control loop — the paper's Fig. 13a worst-case burst, end to end.
//!
//! Runs the discrete-event pipeline over the 3-segment stitched video:
//! quiet → red-vehicle burst → red-pedestrian swarm, and prints the
//! latency + per-stage behavior the paper plots.
//!
//!     cargo run --release --example amber_alert

use anyhow::Result;
use std::collections::HashMap;
use uals::backend::{BackendQuery, CostModel, Detector};
use uals::pipeline::BackgroundMap;
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::{run_sim, Policy, SimConfig};
use uals::utility::{train, Combine};
use uals::video::{build_dataset, DatasetConfig, Paint, SegmentedVideo};

fn main() -> Result<()> {
    // Query: red vehicles, 1-second end-to-end bound.
    let query = QueryConfig::single(NamedColor::Red).with_latency_bound(1000.0);

    // Train on an auxiliary corpus (the shedder must generalize).
    let train_videos = build_dataset(&DatasetConfig {
        num_seeds: 2,
        videos_per_seed: 2,
        frames_per_video: 300,
        base_seed: 0xA11CE,
        target_boost: 2.0,
    });
    let idx: Vec<usize> = (0..train_videos.len()).collect();
    let model = train(&train_videos, &idx, &query.colors, Combine::Single);

    // The worst-case scenario video: 3 × 60 s segments @ 10 fps.
    let sv = SegmentedVideo::fig13a(0xA33, 600, Paint::VividRed);
    println!(
        "scenario: {} frames, segments of {} frames (quiet | red burst | red swarm)",
        sv.len(),
        sv.len() / 3
    );

    let cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: query.clone(),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xA3,
        fps_total: sv.fps(),
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    };
    let extractor = Extractor::native(model);
    let mut backend = BackendQuery::new(
        query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    let mut bgs: BackgroundMap<'_> = HashMap::new();
    bgs.insert(0u32, sv.background());
    let report = run_sim(sv.iter(), &bgs, &cfg, &extractor, &mut backend)?;

    println!("\n-- per-5s-window max E2E latency (bound {} ms) --", query.latency_bound_ms);
    for (t, max, _mean, n) in report.latency_windows.rows() {
        if n == 0 {
            continue;
        }
        let bar = "#".repeat((max / 40.0).min(60.0) as usize);
        println!("{:>6.0}s  {:>7.0} ms  {}", t / 1000.0, max, bar);
    }

    println!("\n-- per-5s-window frames shed / DNN-processed --");
    let shed = report.stages.counts(uals::metrics::Stage::Shed);
    let dnn = report.stages.counts(uals::metrics::Stage::Dnn);
    for (i, (t, s)) in shed.iter().enumerate() {
        let d = dnn.get(i).map(|x| x.1).unwrap_or(0);
        println!("{:>6.0}s  shed {:>3}  dnn {:>3}", t / 1000.0, s, d);
    }

    println!(
        "\nsummary: ingress {}, shed {} ({:.1}%), QoR {:.3}, violations {} ({:.2}%), \
         max E2E {:.0} ms",
        report.ingress,
        report.shed,
        100.0 * report.observed_drop_rate(),
        report.qor.overall(),
        report.latency.violations(),
        100.0 * report.latency.violation_rate(),
        report.latency.max_ms()
    );

    // The paper's expectations for this scenario.
    assert!(
        report.latency.violation_rate() < 0.05,
        "latency must stay (almost always) under the bound"
    );
    assert!(report.shed > 0, "the burst segment must force shedding");
    println!("amber_alert OK");
    Ok(())
}
