//! Smart-city scenario (paper §V-E.2 / Fig. 14): multiple concurrent
//! camera streams multiplexed into one Load Shedder + backend, comparing
//! the utility shedder against the content-agnostic baseline as the
//! number of cameras grows.
//!
//!     cargo run --release --example smart_city [-- --streams 5]

use anyhow::Result;
use uals::backend::{BackendQuery, CostModel, Detector};
use uals::cli::Args;
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::{backgrounds_of, run_sim, Policy, SimConfig};
use uals::utility::{train, Combine};
use uals::video::{
    build_dataset, streamer::aggregate_fps, DatasetConfig, Streamer, Video, VideoConfig,
};

fn city_cameras(k: usize, frames: usize) -> Vec<Video> {
    (0..k)
        .map(|i| {
            let mut vc =
                VideoConfig::new(0xC17 + (i as u64 % 3), 0xCAFE + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = 0.3;
            Video::new(vc)
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let max_streams = args.get_usize("streams", 5)?;
    let frames = args.get_usize("frames", 400)?;

    let query = QueryConfig::single(NamedColor::Red).with_latency_bound(1000.0);
    let train_videos = build_dataset(&DatasetConfig {
        num_seeds: 2,
        videos_per_seed: 2,
        frames_per_video: 300,
        base_seed: 0x5C17,
        target_boost: 2.0,
    });
    let idx: Vec<usize> = (0..train_videos.len()).collect();
    let model = train(&train_videos, &idx, &query.colors, Combine::Single);

    println!("streams  qor_utility  drop_utility  qor_random  drop_random  viol_utility");
    for k in 1..=max_streams {
        let videos = city_cameras(k, frames);
        let fps = aggregate_fps(&videos);
        let bgs = backgrounds_of(&videos);
        let run = |policy: Policy| -> Result<_> {
            let cfg = SimConfig {
                costs: CostConfig::default(),
                shedder: ShedderConfig::default(),
                query: query.clone(),
                backend_tokens: 1,
                policy,
                seed: 0x5C,
                fps_total: fps,
                transport: uals::pipeline::TransportConfig::default(),
                faults: uals::pipeline::FaultPlan::default(),
                adaptation: uals::utility::AdaptationConfig::default(),
            };
            let extractor = Extractor::native(model.clone());
            let mut backend = BackendQuery::new(
                query.clone(),
                Detector::native(12, 25.0),
                CostModel::new(cfg.costs.clone(), cfg.seed),
                25.0,
            );
            run_sim(Streamer::new(&videos), &bgs, &cfg, &extractor, &mut backend)
        };
        let util = run(Policy::UtilityControlLoop)?;
        // Paper baseline: Eq. 18/19 with a lenient assumed proc_Q = 500 ms.
        let rnd = run(Policy::RandomRate { assumed_proc_q_ms: 500.0 })?;
        println!(
            "{:>7}  {:>11.3}  {:>12.3}  {:>10.3}  {:>11.3}  {:>12.4}",
            k,
            util.qor.overall(),
            util.observed_drop_rate(),
            rnd.qor.overall(),
            rnd.observed_drop_rate(),
            util.latency.violation_rate(),
        );
    }
    println!("smart_city OK");
    Ok(())
}
