//! Edge-device overhead (paper §V-F / Fig. 15): measure the camera-side
//! operator costs — RGB→HSV, background subtraction, feature extraction,
//! utility calculation — plus the fused AOT-artifact path, and check the
//! paper's budget (the whole stack must sustain multi-camera 10 fps).
//!
//! This is also the **real-time pipeline** demo: it then pushes a short
//! stream through the threaded runtime with the PJRT artifact on the hot
//! path and reports wall-clock behavior.
//!
//!     make artifacts && cargo run --release --example edge_overhead

use anyhow::Result;
use uals::color::NamedColor;
use uals::config::QueryConfig;
use uals::experiments::{self, Scale};
use uals::pipeline::realtime::{run_realtime, RealtimeConfig};
use uals::utility::{train, Combine};
use uals::video::{build_dataset, DatasetConfig, Video, VideoConfig};

fn main() -> Result<()> {
    // Part 1: the Fig. 15 component breakdown.
    println!("== camera-side overhead breakdown (Fig. 15) ==");
    for (name, table) in experiments::run_figure("15", Scale::Small)? {
        let _ = name;
        print!("{}", table.to_pretty());
    }

    // Part 2: real-time threaded pipeline with artifacts on the hot path.
    println!("\n== real-time pipeline (PJRT artifact hot path) ==");
    let train_videos = build_dataset(&DatasetConfig {
        num_seeds: 2,
        videos_per_seed: 2,
        frames_per_video: 200,
        base_seed: 0xED6E,
        target_boost: 2.0,
    });
    let idx: Vec<usize> = (0..train_videos.len()).collect();
    let model = train(&train_videos, &idx, &[NamedColor::Red], Combine::Single);

    let mut vc = VideoConfig::new(0xED, 0x6E, 0, 100);
    vc.traffic.vehicle_rate = 0.5;
    let videos = vec![Video::new(vc)];

    let use_artifacts = uals::runtime::artifacts_available();
    if !use_artifacts {
        println!("(artifacts/PJRT unavailable — running the native fast path)");
    }
    let cfg = RealtimeConfig {
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1000.0),
        time_scale: 0.2,          // 5× fast-forward (10 s of stream in ~2 s)
        cost_emulation_scale: 1.0, // emulate the DNN's latency
        use_artifacts,
        ..Default::default()
    };
    let report = run_realtime(&videos, &model, &cfg)?;
    println!(
        "frames {} | transmitted {} | shed {} | QoR {:.3}",
        report.ingress,
        report.transmitted,
        report.shed,
        report.qor.overall()
    );
    println!(
        "extractor (AOT artifact) mean latency: {:.3} ms/frame",
        report.extract_ms_mean
    );
    println!(
        "E2E (stream time): mean {:.0} ms, max {:.0} ms, violations {}",
        report.latency.mean_ms(),
        report.latency.max_ms(),
        report.latency.violations()
    );
    println!("wall time: {:.2} s", report.wall.as_secs_f64());

    // Paper budget: camera-side processing must stay well under the frame
    // period; the artifact path must sustain 10 fps × several cameras.
    assert!(
        report.extract_ms_mean < 50.0,
        "artifact extraction too slow: {:.2} ms",
        report.extract_ms_mean
    );
    println!("edge_overhead OK");
    Ok(())
}
