# Build/test/bench entry points for the uals reproduction.
#
#   make build      release build of the Rust stack
#   make test       tier-1 test suite, release profile (green without
#                   artifacts; --release so CI's build+test share ONE
#                   compile pass instead of building debug a second time)
#   make check      CI gate: release build + tier-1 tests + fmt + clippy
#   make docs       rustdoc with warnings denied (the CI docs job)
#   make bench      hot-path microbenchmarks → BENCH_micro.json (repo root)
#                   (incl. the multi-query shared-vs-independent rows; run
#                   from a toolchain image to populate the file; CI prints
#                   an advisory delta vs BENCH_baseline.json)
#   make figures    regenerate the paper's figures at the default scale
#   make artifacts  AOT-lower the JAX/Pallas kernels → rust/artifacts/
#                   (requires jax; the Rust side runs without it, on the
#                   native LUT fast path)

.PHONY: build test check fmt-check clippy docs bench figures artifacts clean

build:
	cargo build --release

test:
	cargo test -q --release

check: build test fmt-check clippy

fmt-check:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench:
	cargo bench --bench microbench

figures:
	cargo run --release --bin uals -- figures --all --scale small

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

clean:
	cargo clean
	rm -f BENCH_micro.json
