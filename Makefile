# Build/test/bench entry points for the uals reproduction.
#
#   make build      release build of the Rust stack
#   make test       tier-1 test suite, release profile (green without
#                   artifacts; --release so CI's build+test share ONE
#                   compile pass instead of building debug a second time)
#   make check      CI gate: release build + tier-1 tests + fmt + clippy
#   make docs       rustdoc with warnings denied (the CI docs job)
#   make bench      hot-path microbenchmarks → BENCH_micro.json (repo root)
#                   (incl. the multi-query shared-vs-independent and
#                   transport/* rows; run from a toolchain image to
#                   populate the file; CI GATES on a per-row delta vs
#                   BENCH_baseline.json — >10% regression fails the job)
#   make bench-baseline  run the benches and commit the result as the new
#                   BENCH_baseline.json (run from a toolchain image)
#   make figures    regenerate the paper's figures at the default scale
#   make artifacts  AOT-lower the JAX/Pallas kernels → rust/artifacts/
#                   (requires jax; the Rust side runs without it, on the
#                   native LUT fast path)

.PHONY: build test check fmt-check clippy docs bench bench-baseline figures artifacts clean

build:
	cargo build --release

test:
	cargo test -q --release

check: build test fmt-check clippy

fmt-check:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench:
	cargo bench --bench microbench

# Absolute ns/op only compare within one machine class: refresh the
# committed baseline from the CI bench job's uploaded BENCH_micro
# artifact (same runner class as the gate), or run this target on a
# matching toolchain image — a laptop-generated baseline will trip (or
# mask) the 10% gate through the cross-hardware offset alone.
bench-baseline: bench
	cp BENCH_micro.json BENCH_baseline.json
	@echo "BENCH_baseline.json refreshed — commit it to reset the CI bench gate"
	@echo "(ns/op are machine-class-specific: prefer the CI artifact as the source)"

figures:
	cargo run --release --bin uals -- figures --all --scale small

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

clean:
	cargo clean
	rm -f BENCH_micro.json
