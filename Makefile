# Build/test/bench entry points for the uals reproduction.
#
#   make build      release build of the Rust stack
#   make test       tier-1 test suite (green without artifacts)
#   make bench      hot-path microbenchmarks → BENCH_micro.json (repo root)
#   make figures    regenerate the paper's figures at the default scale
#   make artifacts  AOT-lower the JAX/Pallas kernels → rust/artifacts/
#                   (requires jax; the Rust side runs without it, on the
#                   native LUT fast path)

.PHONY: build test bench figures artifacts clean

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench microbench

figures:
	cargo run --release --bin uals -- figures --all --scale small

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

clean:
	cargo clean
	rm -f BENCH_micro.json
