"""Unit tests for the bench-delta gate (scripts/bench_delta.py).

Run from the repo root with either runner:

    python3 -m unittest discover -s scripts -p 'test_*.py'
    python3 -m pytest scripts/ -q
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_delta  # noqa: E402


def doc(rows):
    return {
        "schema": "uals-microbench-v1",
        "unit": "ns_per_op",
        "benches": [{"name": n, "mean_ns": v} for n, v in rows.items()],
    }


def write_doc(path, rows):
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc(rows), f)


class CompareTests(unittest.TestCase):
    def test_clean_pass_within_threshold(self):
        lines, failures = bench_delta.compare({"a": 100.0}, {"a": 105.0}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("`a`" in l for l in lines))

    def test_regression_over_threshold_fails(self):
        _, failures = bench_delta.compare({"a": 100.0, "b": 50.0}, {"a": 111.0, "b": 50.0}, 10.0)
        self.assertEqual(failures, ["a"])

    def test_threshold_edge_is_inclusive_pass(self):
        # Exactly +10.0% is NOT a failure — strictly greater gates.
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 110.0}, 10.0)
        self.assertEqual(failures, [])
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 110.0001}, 10.0)
        self.assertEqual(failures, ["a"])

    def test_improvement_never_fails(self):
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 10.0}, 10.0)
        self.assertEqual(failures, [])

    def test_new_rows_pass(self):
        lines, failures = bench_delta.compare({"a": 100.0}, {"a": 100.0, "fresh": 1e9}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("new" in l for l in lines if "`fresh`" in l))

    def test_missing_rows_warn_but_pass(self):
        lines, failures = bench_delta.compare({"a": 100.0, "gone": 5.0}, {"a": 100.0}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("`gone`" in l for l in lines))

    def test_empty_baseline_all_new_pass(self):
        lines, failures = bench_delta.compare({}, {"a": 100.0, "b": 1.0}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("No baseline rows" in l for l in lines))

    def test_zero_baseline_row_is_treated_as_new(self):
        _, failures = bench_delta.compare({"a": 0.0}, {"a": 100.0}, 10.0)
        self.assertEqual(failures, [])

    def test_custom_threshold(self):
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 104.0}, 3.0)
        self.assertEqual(failures, ["a"])
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 104.0}, 50.0)
        self.assertEqual(failures, [])


class MainExitCodeTests(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.base = os.path.join(self.dir.name, "base.json")
        self.cur = os.path.join(self.dir.name, "cur.json")

    def tearDown(self):
        self.dir.cleanup()

    def test_gating_fails_on_regression(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 200.0})
        self.assertEqual(bench_delta.main([self.base, self.cur]), 1)

    def test_gating_passes_within_threshold(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 109.0})
        self.assertEqual(bench_delta.main([self.base, self.cur]), 0)

    def test_advisory_never_fails(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 500.0})
        self.assertEqual(bench_delta.main(["--advisory", self.base, self.cur]), 0)

    def test_missing_current_fails_gating_passes_advisory(self):
        write_doc(self.base, {"a": 100.0})
        missing = os.path.join(self.dir.name, "nope.json")
        self.assertEqual(bench_delta.main([self.base, missing]), 1)
        self.assertEqual(bench_delta.main(["--advisory", self.base, missing]), 0)

    def test_empty_committed_baseline_passes(self):
        # The repo's BENCH_baseline.json starts as an empty doc.
        write_doc(self.base, {})
        write_doc(self.cur, {"a": 100.0})
        self.assertEqual(bench_delta.main([self.base, self.cur]), 0)

    def test_max_regress_flag(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 104.0})
        self.assertEqual(bench_delta.main(["--max-regress", "3", self.base, self.cur]), 1)
        self.assertEqual(bench_delta.main(["--max-regress", "5", self.base, self.cur]), 0)


if __name__ == "__main__":
    unittest.main()
