"""Unit tests for the bench-delta gate (scripts/bench_delta.py).

Run from the repo root with either runner:

    python3 -m unittest discover -s scripts -p 'test_*.py'
    python3 -m pytest scripts/ -q
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_delta  # noqa: E402


def doc(rows, isa=None):
    d = {
        "schema": "uals-microbench-v1",
        "unit": "ns_per_op",
        "benches": [{"name": n, "mean_ns": v} for n, v in rows.items()],
    }
    if isa is not None:
        d["isa"] = isa
    return d


def write_doc(path, rows, isa=None):
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc(rows, isa), f)


class CompareTests(unittest.TestCase):
    def test_clean_pass_within_threshold(self):
        lines, failures = bench_delta.compare({"a": 100.0}, {"a": 105.0}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("`a`" in l for l in lines))

    def test_regression_over_threshold_fails(self):
        _, failures = bench_delta.compare({"a": 100.0, "b": 50.0}, {"a": 111.0, "b": 50.0}, 10.0)
        self.assertEqual(failures, ["a"])

    def test_threshold_edge_is_inclusive_pass(self):
        # Exactly +10.0% is NOT a failure — strictly greater gates.
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 110.0}, 10.0)
        self.assertEqual(failures, [])
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 110.0001}, 10.0)
        self.assertEqual(failures, ["a"])

    def test_improvement_never_fails(self):
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 10.0}, 10.0)
        self.assertEqual(failures, [])

    def test_new_rows_pass(self):
        lines, failures = bench_delta.compare({"a": 100.0}, {"a": 100.0, "fresh": 1e9}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("new" in l for l in lines if "`fresh`" in l))

    def test_missing_rows_warn_but_pass(self):
        lines, failures = bench_delta.compare({"a": 100.0, "gone": 5.0}, {"a": 100.0}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("`gone`" in l for l in lines))

    def test_empty_baseline_all_new_pass(self):
        lines, failures = bench_delta.compare({}, {"a": 100.0, "b": 1.0}, 10.0)
        self.assertEqual(failures, [])
        self.assertTrue(any("No baseline rows" in l for l in lines))

    def test_zero_baseline_row_is_treated_as_new(self):
        _, failures = bench_delta.compare({"a": 0.0}, {"a": 100.0}, 10.0)
        self.assertEqual(failures, [])

    def test_custom_threshold(self):
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 104.0}, 3.0)
        self.assertEqual(failures, ["a"])
        _, failures = bench_delta.compare({"a": 100.0}, {"a": 104.0}, 50.0)
        self.assertEqual(failures, [])


class MainExitCodeTests(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.base = os.path.join(self.dir.name, "base.json")
        self.cur = os.path.join(self.dir.name, "cur.json")

    def tearDown(self):
        self.dir.cleanup()

    def test_gating_fails_on_regression(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 200.0})
        self.assertEqual(bench_delta.main([self.base, self.cur]), 1)

    def test_gating_passes_within_threshold(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 109.0})
        self.assertEqual(bench_delta.main([self.base, self.cur]), 0)

    def test_advisory_never_fails(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 500.0})
        self.assertEqual(bench_delta.main(["--advisory", self.base, self.cur]), 0)

    def test_missing_current_fails_gating_passes_advisory(self):
        write_doc(self.base, {"a": 100.0})
        missing = os.path.join(self.dir.name, "nope.json")
        self.assertEqual(bench_delta.main([self.base, missing]), 1)
        self.assertEqual(bench_delta.main(["--advisory", self.base, missing]), 0)

    def test_empty_committed_baseline_passes(self):
        # The repo's BENCH_baseline.json starts as an empty doc.
        write_doc(self.base, {})
        write_doc(self.cur, {"a": 100.0})
        self.assertEqual(bench_delta.main([self.base, self.cur]), 0)

    def test_max_regress_flag(self):
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 104.0})
        self.assertEqual(bench_delta.main(["--max-regress", "3", self.base, self.cur]), 1)
        self.assertEqual(bench_delta.main(["--max-regress", "5", self.base, self.cur]), 0)


class IsaFieldTests(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.base = os.path.join(self.dir.name, "base.json")
        self.cur = os.path.join(self.dir.name, "cur.json")

    def tearDown(self):
        self.dir.cleanup()

    def run_main(self, argv):
        """main() with captured stdout: returns (exit code, output)."""
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            code = bench_delta.main(argv)
        return code, out.getvalue()

    def test_load_reads_isa_field(self):
        write_doc(self.cur, {"a": 1.0}, isa="avx2")
        rows, isa, note = bench_delta.load(self.cur)
        self.assertEqual(rows, {"a": 1.0})
        self.assertEqual(isa, "avx2")
        self.assertIsNone(note)

    def test_load_missing_isa_is_none(self):
        write_doc(self.cur, {"a": 1.0})
        _, isa, note = bench_delta.load(self.cur)
        self.assertIsNone(isa)
        self.assertIsNone(note)

    def test_isa_mismatch_warns_but_does_not_gate(self):
        write_doc(self.base, {"a": 100.0}, isa="avx2")
        write_doc(self.cur, {"a": 100.0}, isa="neon")
        code, out = self.run_main([self.base, self.cur])
        self.assertEqual(code, 0, "mismatch alone must not fail the gate")
        self.assertIn("ISA mismatch", out)
        self.assertIn("avx2", out)
        self.assertIn("neon", out)

    def test_matching_isa_is_silent(self):
        write_doc(self.base, {"a": 100.0}, isa="avx2")
        write_doc(self.cur, {"a": 100.0}, isa="avx2")
        code, out = self.run_main([self.base, self.cur])
        self.assertEqual(code, 0)
        self.assertNotIn("ISA mismatch", out)

    def test_baseline_without_isa_field_notes_but_passes(self):
        # A pre-SIMD baseline (no isa field) against a current run that
        # records one: noted, never a mismatch warning, never a failure.
        write_doc(self.base, {"a": 100.0})
        write_doc(self.cur, {"a": 100.0}, isa="sse2")
        code, out = self.run_main([self.base, self.cur])
        self.assertEqual(code, 0)
        self.assertNotIn("ISA mismatch", out)
        self.assertIn("no `isa` field", out)

    def test_mismatch_plus_regression_still_fails(self):
        # The warning must not mask a genuine gating failure.
        write_doc(self.base, {"a": 100.0}, isa="avx2")
        write_doc(self.cur, {"a": 500.0}, isa="scalar")
        code, out = self.run_main([self.base, self.cur])
        self.assertEqual(code, 1)
        self.assertIn("ISA mismatch", out)


if __name__ == "__main__":
    unittest.main()

