#!/usr/bin/env python3
"""Per-row bench comparison for CI — gating by default.

Usage: bench_delta.py [--max-regress PCT] [--advisory] BASELINE.json CURRENT.json

Reads two `uals-microbench-v1` files (see rust/src/util/bench.rs), prints
a GitHub-flavoured markdown table of per-row deltas, and exits non-zero
when any row regressed by MORE than --max-regress percent (default 10).

Grace rules (unit-tested in scripts/test_bench_delta.py):
  * empty/missing baseline        -> every row is "new", pass (the
                                     committed baseline starts empty until
                                     `make bench-baseline` refreshes it);
  * row only in current ("new")   -> pass;
  * row only in baseline ("gone") -> warned, pass (renames should not
                                     brick CI; the next baseline refresh
                                     absorbs them);
  * regression == threshold       -> pass (strictly-greater fails);
  * no current rows at all        -> FAIL when gating (the bench run
                                     produced nothing to verify).

--advisory restores the old always-exit-0 behaviour; CI passes it when
the PR carries the `allow-bench-regress` label.
"""

import argparse
import json
import sys


def load(path):
    """Read ({bench name -> mean ns}, isa, note) from a microbench JSON file.

    `isa` is the top-level "isa" field (the SIMD level the run resolved,
    see rust/src/simd) or None for pre-SIMD files that lack it. Returns
    ({}, None, note) on unreadable/empty input instead of raising.
    """
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return {}, None, f"could not read {path}: {e}"
    rows = {}
    for b in doc.get("benches", []):
        name = b.get("name")
        mean = b.get("mean_ns")
        if name is not None and isinstance(mean, (int, float)):
            rows[name] = float(mean)
    isa = doc.get("isa")
    if not isinstance(isa, str):
        isa = None
    return rows, isa, None


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.2f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} µs"
    return f"{ns:.0f} ns"


def compare(baseline, current, max_regress_pct):
    """Compare row dicts; returns (markdown lines, failed row names).

    A row fails when current > baseline by strictly more than
    max_regress_pct percent. Rows missing on either side never fail.
    """
    lines = []
    failures = []
    if not baseline:
        lines.append("_No baseline rows (BENCH_baseline.json is empty) — all rows are new._")
        lines.append("")
    lines.append("| bench | baseline | current | delta |")
    lines.append("|---|---:|---:|---:|")
    for name in sorted(current):
        cur = current[name]
        base = baseline.get(name)
        if base is None or base <= 0:
            delta = "new"
            base_s = "—"
        else:
            base_s = fmt_ns(base)
            pct = (cur - base) / base * 100.0
            if pct > max_regress_pct:
                failures.append(name)
                arrow = "❌"
            elif pct > 5.0:
                arrow = "🔺"
            elif pct < -5.0:
                arrow = "🟢"
            else:
                arrow = "·"
            delta = f"{pct:+.1f}% {arrow}"
        lines.append(f"| `{name}` | {base_s} | {fmt_ns(cur)} | {delta} |")
    gone = sorted(set(baseline) - set(current))
    if gone:
        lines.append("")
        lines.append(
            "Rows in baseline but missing from this run (not gated): "
            + ", ".join(f"`{g}`" for g in gone)
        )
    if failures:
        lines.append("")
        lines.append(
            f"**FAIL: {len(failures)} row(s) regressed > {max_regress_pct:g}%:** "
            + ", ".join(f"`{f}`" for f in failures)
        )
        lines.append(
            "_Refresh BENCH_baseline.json (`make bench-baseline`) if intentional, or "
            "apply the `allow-bench-regress` PR label to waive once._"
        )
    return lines, failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--max-regress",
        type=float,
        default=10.0,
        metavar="PCT",
        help="per-row regression threshold in percent (default 10)",
    )
    ap.add_argument(
        "--advisory",
        action="store_true",
        help="never fail — print the table and exit 0 (allow-bench-regress)",
    )
    args = ap.parse_args(argv)

    baseline, base_isa, base_note = load(args.baseline)
    current, cur_isa, cur_note = load(args.current)
    mode = "advisory" if args.advisory else f"gating at {args.max_regress:g}%"
    print(f"### Microbench vs committed baseline ({mode})")
    print()
    if base_note:
        print(f"_bench_delta: {base_note}_")
    if cur_note:
        print(f"_bench_delta: {cur_note}_")
    # Cross-ISA runs (e.g. an avx2 baseline against a neon runner) are
    # not comparable row by row; warn loudly but leave gating to the
    # regression threshold — the warning tells the reader why a delta
    # column may be nonsense.
    if base_isa and cur_isa and base_isa != cur_isa:
        print(
            f"⚠️ _bench_delta: ISA mismatch — baseline `{base_isa}` vs current "
            f"`{cur_isa}`; cross-ISA deltas are not comparable. Refresh the "
            "baseline on a matching runner._"
        )
    elif baseline and not base_isa:
        print("_bench_delta: baseline has no `isa` field (pre-SIMD file)._")
    if not current:
        print("_bench_delta: no current bench rows — did `make bench` run?_")
        return 0 if args.advisory else 1

    lines, failures = compare(baseline, current, args.max_regress)
    for line in lines:
        print(line)
    if failures and not args.advisory:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
