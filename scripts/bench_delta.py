#!/usr/bin/env python3
"""Advisory per-row bench comparison for the CI job summary.

Usage: bench_delta.py BASELINE.json CURRENT.json

Reads two `uals-microbench-v1` files (see rust/src/util/bench.rs) and
prints a GitHub-flavoured markdown table of per-row deltas. Always exits
0 — the comparison is informational, never a gate. Rows present only in
the current run are marked "new"; rows that vanished are listed at the
end. An empty or missing baseline degrades to "no baseline" gracefully
(the committed BENCH_baseline.json starts empty until a toolchain run
refreshes it).
"""

import json
import sys


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
        rows = {}
        for b in doc.get("benches", []):
            name = b.get("name")
            mean = b.get("mean_ns")
            if name is not None and isinstance(mean, (int, float)):
                rows[name] = float(mean)
        return rows
    except (OSError, ValueError) as e:
        print(f"_bench_delta: could not read {path}: {e}_")
        return {}


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.2f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} µs"
    return f"{ns:.0f} ns"


def main():
    if len(sys.argv) != 3:
        print("usage: bench_delta.py BASELINE.json CURRENT.json")
        return
    baseline = load(sys.argv[1])
    current = load(sys.argv[2])
    if not current:
        print("_bench_delta: no current bench rows — did `make bench` run?_")
        return

    print("### Microbench vs committed baseline (advisory)")
    print()
    if not baseline:
        print("_No baseline rows (BENCH_baseline.json is empty) — all rows are new._")
        print()
    print("| bench | baseline | current | delta |")
    print("|---|---:|---:|---:|")
    for name in sorted(current):
        cur = current[name]
        base = baseline.get(name)
        if base is None:
            delta = "new"
            base_s = "—"
        else:
            base_s = fmt_ns(base)
            pct = (cur - base) / base * 100.0 if base > 0 else 0.0
            arrow = "🔺" if pct > 5.0 else ("🟢" if pct < -5.0 else "·")
            delta = f"{pct:+.1f}% {arrow}"
        print(f"| `{name}` | {base_s} | {fmt_ns(cur)} | {delta} |")
    gone = sorted(set(baseline) - set(current))
    if gone:
        print()
        print("Rows in baseline but missing from this run: " + ", ".join(f"`{g}`" for g in gone))


if __name__ == "__main__":
    try:
        main()
    except Exception as e:  # advisory only — never fail the job
        print(f"_bench_delta error: {e}_")
    sys.exit(0)
