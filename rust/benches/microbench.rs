//! Hot-path microbenchmarks (`cargo bench --bench microbench`, or
//! `make bench` / `cargo bench-micro` from the repo root).
//!
//! Covers every component on the per-frame request path plus the
//! substrates the coordinator leans on. Results go to stdout,
//! `results/microbench.csv`, and the machine-readable `BENCH_micro.json`
//! at the repo root (per-bench ns/op — the cross-PR perf trajectory;
//! see EXPERIMENTS.md §Performance).

use uals::backend::{foreground_mask, largest_blob, BackendQuery, CostModel, Detector};
use uals::color::{ColorLut, NamedColor};
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::{reference, Extractor, FrameFeatures, QuantScratch, UtilityValues};
use uals::pipeline::{run_sharded_sim, Policy, SimConfig};
use uals::runtime::Engine;
use uals::shedder::UtilityQueue;
use uals::util::bench::Bench;
use uals::util::rng::Rng;
use uals::utility::{train, Combine, UtilityCdf};
use uals::video::{Frame, Video, VideoConfig};

fn main() {
    let mut b = Bench::new(3, 40);

    // --- fixtures -----------------------------------------------------------
    let mut vc = VideoConfig::new(7, 21, 0, 60);
    vc.traffic.vehicle_rate = 0.8;
    let video = Video::new(vc);
    let frame = video.render(30);
    let bg = video.background().to_vec();
    // u8-camera variants (what real cameras ship): integer-valued pixels
    // take the LUT fast path; the float fixtures keep the legacy numbers.
    let frame_u8: Vec<f32> = frame.rgb.iter().map(|x| x.round()).collect();
    let bg_u8: Vec<f32> = bg.iter().map(|x| x.round()).collect();
    let ranges = [NamedColor::Red.ranges(), NamedColor::Yellow.ranges()];
    let videos = vec![video];
    let model2 = train(
        &videos,
        &[0],
        &[NamedColor::Red, NamedColor::Yellow],
        Combine::Or,
    );
    let model1 = train(&videos, &[0], &[NamedColor::Red], Combine::Single);

    // --- L3 native hot path -------------------------------------------------
    b.run("video/render_frame_96x96", || {
        std::hint::black_box(videos[0].render(31));
    });
    let mut arena = Frame::empty();
    b.run("video/render_into_96x96 (arena)", || {
        videos[0].render_into(31, &mut arena);
        std::hint::black_box(arena.rgb.len());
    });
    // The fused LUT fast path vs the reference oracle, same u8 frame.
    let lut2 = ColorLut::new(&ranges, reference::FG_THRESHOLD);
    let mut quant = QuantScratch::default();
    let mut feats_buf = FrameFeatures::empty();
    b.run("features/native_extract_2colors", || {
        uals::features::compute_features_fast_into(
            &lut2,
            &frame_u8,
            &bg_u8,
            &mut quant,
            &mut feats_buf,
        );
        std::hint::black_box(feats_buf.fg_frac);
    });
    b.run("features/native_extract_2colors_reference", || {
        std::hint::black_box(reference::compute_features(
            &frame_u8,
            &bg_u8,
            &ranges,
            reference::FG_THRESHOLD,
        ));
    });
    let native1 = Extractor::native(model1.clone());
    b.run("features/native_extract+utility_1color", || {
        std::hint::black_box(native1.extract(&frame_u8, &bg_u8).unwrap());
    });
    let mut utils_buf = UtilityValues::empty();
    b.run("features/extract_into+utility_1color (0-alloc)", || {
        native1
            .extract_into(&frame_u8, &bg_u8, &mut feats_buf, &mut utils_buf)
            .unwrap();
        std::hint::black_box(utils_buf.combined);
    });
    b.run("backend/foreground_mask+largest_blob", || {
        let m = foreground_mask(&frame.rgb, &bg, 96, 96, 25.0);
        std::hint::black_box(largest_blob(&m));
    });
    let det = Detector::native(12, 25.0);
    b.run("backend/native_detector_2colors", || {
        std::hint::black_box(det.detect(&frame.rgb, &bg, 96, 96, &ranges).unwrap());
    });
    let mut bq = BackendQuery::new(
        QueryConfig::single(NamedColor::Red),
        Detector::native(12, 25.0),
        CostModel::new(CostConfig { jitter: 0.0, ..Default::default() }, 1),
        25.0,
    );
    b.run("backend/full_query_process", || {
        std::hint::black_box(bq.process(&frame.rgb, &bg, 96, 96).unwrap());
    });

    // --- multi-camera sweep engine ------------------------------------------
    let sweep_videos: Vec<Video> = (0..4)
        .map(|i| {
            let mut svc = VideoConfig::new(11, 0xBE6 + i as u64, i as u32, 120);
            svc.traffic.vehicle_rate = 0.35;
            svc.quantize_u8 = true; // u8 cameras → LUT fast path in the sweep
            Video::new(svc)
        })
        .collect();
    let sweep_model = train(&sweep_videos, &[0, 1], &[NamedColor::Red], Combine::Single);
    let sweep_cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xBE,
        fps_total: 10.0,
    };
    b.run_n("pipeline/sweep_4cams_serial", 1, 3, || {
        let r = run_sharded_sim(&sweep_videos, &sweep_cfg, &sweep_model, 1).unwrap();
        std::hint::black_box(r.0.ingress);
    });
    let threads = uals::pipeline::default_threads().min(4);
    b.run_n("pipeline/sweep_4cams_parallel", 1, 3, || {
        let r = run_sharded_sim(&sweep_videos, &sweep_cfg, &sweep_model, threads).unwrap();
        std::hint::black_box(r.0.ingress);
    });

    // --- AOT artifact path (PJRT) -------------------------------------------
    if let Ok(engine) = Engine::from_default_artifacts() {
        let art1 = Extractor::artifact(&engine, model1.clone()).unwrap();
        b.run("features/artifact_extract_1color (PJRT)", || {
            std::hint::black_box(art1.extract(&frame.rgb, &bg).unwrap());
        });
        let art2 = Extractor::artifact(&engine, model2.clone()).unwrap();
        b.run("features/artifact_extract_2colors (PJRT)", || {
            std::hint::black_box(art2.extract(&frame.rgb, &bg).unwrap());
        });
        let det_a = Detector::artifact(&engine).unwrap();
        b.run("backend/artifact_detector (PJRT)", || {
            std::hint::black_box(det_a.detect(&frame.rgb, &bg, 96, 96, &ranges).unwrap());
        });
    } else {
        eprintln!("(artifacts not built — skipping PJRT benches; run `make artifacts`)");
    }

    // --- shedder data structures -------------------------------------------
    let mut rng = Rng::new(1);
    b.run("shedder/utility_queue_offer_pop_x1000", || {
        let mut q: UtilityQueue<u64> = UtilityQueue::new(16);
        for i in 0..1000u64 {
            q.offer(rng.f32(), i as f64, i);
            if i % 3 == 0 {
                q.pop_best();
            }
        }
        std::hint::black_box(q.len());
    });
    let mut cdf = UtilityCdf::new(600);
    for _ in 0..600 {
        cdf.add(rng.f32());
    }
    b.run("utility/cdf_add+threshold (window 600)", || {
        cdf.add(rng.f32());
        std::hint::black_box(cdf.threshold_for(0.7));
    });

    // --- substrates ----------------------------------------------------------
    let json_doc = model2.to_json().to_string_pretty();
    b.run("util/json_parse_model_file", || {
        std::hint::black_box(uals::util::json::parse(&json_doc).unwrap());
    });

    // Headline ratios for the PR-perf trajectory.
    if let (Some(fast), Some(slow)) = (
        b.result("features/native_extract_2colors"),
        b.result("features/native_extract_2colors_reference"),
    ) {
        println!(
            "\nLUT fast path speedup (2-color extract): {:.2}x",
            slow.mean_ms / fast.mean_ms.max(1e-12)
        );
    }
    if let (Some(par), Some(ser)) = (
        b.result("pipeline/sweep_4cams_parallel"),
        b.result("pipeline/sweep_4cams_serial"),
    ) {
        println!(
            "parallel 4-camera sweep speedup ({threads} threads): {:.2}x",
            ser.mean_ms / par.mean_ms.max(1e-12)
        );
    }

    b.write_csv(std::path::Path::new("results/microbench.csv")).unwrap();
    // BENCH_micro.json lives at the repo root (one dir above the crate).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .join("BENCH_micro.json");
    b.write_json(&root).unwrap();
    println!("\nwrote results/microbench.csv and {}", root.display());
}
