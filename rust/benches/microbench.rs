//! Hot-path microbenchmarks (`cargo bench --bench microbench`).
//!
//! Covers every component on the per-frame request path plus the
//! substrates the coordinator leans on. Results go to stdout and
//! `results/microbench.csv` (inputs for EXPERIMENTS.md §Perf).

use uals::backend::{foreground_mask, largest_blob, BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig};
use uals::features::{reference, Extractor};
use uals::runtime::Engine;
use uals::shedder::UtilityQueue;
use uals::util::bench::Bench;
use uals::util::rng::Rng;
use uals::utility::{train, Combine, UtilityCdf};
use uals::video::{Video, VideoConfig};

fn main() {
    let mut b = Bench::new(3, 40);

    // --- fixtures -----------------------------------------------------------
    let mut vc = VideoConfig::new(7, 21, 0, 60);
    vc.traffic.vehicle_rate = 0.8;
    let video = Video::new(vc);
    let frame = video.render(30);
    let bg = video.background().to_vec();
    let ranges = [NamedColor::Red.ranges(), NamedColor::Yellow.ranges()];
    let videos = vec![video];
    let model2 = train(
        &videos,
        &[0],
        &[NamedColor::Red, NamedColor::Yellow],
        Combine::Or,
    );
    let model1 = train(&videos, &[0], &[NamedColor::Red], Combine::Single);

    // --- L3 native hot path -------------------------------------------------
    b.run("video/render_frame_96x96", || {
        std::hint::black_box(videos[0].render(31));
    });
    b.run("features/native_extract_2colors", || {
        std::hint::black_box(reference::compute_features(
            &frame.rgb,
            &bg,
            &ranges,
            reference::FG_THRESHOLD,
        ));
    });
    let native1 = Extractor::native(model1.clone());
    b.run("features/native_extract+utility_1color", || {
        std::hint::black_box(native1.extract(&frame.rgb, &bg).unwrap());
    });
    b.run("backend/foreground_mask+largest_blob", || {
        let m = foreground_mask(&frame.rgb, &bg, 96, 96, 25.0);
        std::hint::black_box(largest_blob(&m));
    });
    let det = Detector::native(12, 25.0);
    b.run("backend/native_detector_2colors", || {
        std::hint::black_box(det.detect(&frame.rgb, &bg, 96, 96, &ranges).unwrap());
    });
    let mut bq = BackendQuery::new(
        QueryConfig::single(NamedColor::Red),
        Detector::native(12, 25.0),
        CostModel::new(CostConfig { jitter: 0.0, ..Default::default() }, 1),
        25.0,
    );
    b.run("backend/full_query_process", || {
        std::hint::black_box(bq.process(&frame.rgb, &bg, 96, 96).unwrap());
    });

    // --- AOT artifact path (PJRT) -------------------------------------------
    if let Ok(engine) = Engine::from_default_artifacts() {
        let art1 = Extractor::artifact(&engine, model1.clone()).unwrap();
        b.run("features/artifact_extract_1color (PJRT)", || {
            std::hint::black_box(art1.extract(&frame.rgb, &bg).unwrap());
        });
        let art2 = Extractor::artifact(&engine, model2.clone()).unwrap();
        b.run("features/artifact_extract_2colors (PJRT)", || {
            std::hint::black_box(art2.extract(&frame.rgb, &bg).unwrap());
        });
        let det_a = Detector::artifact(&engine).unwrap();
        b.run("backend/artifact_detector (PJRT)", || {
            std::hint::black_box(det_a.detect(&frame.rgb, &bg, 96, 96, &ranges).unwrap());
        });
    } else {
        eprintln!("(artifacts not built — skipping PJRT benches; run `make artifacts`)");
    }

    // --- shedder data structures -------------------------------------------
    let mut rng = Rng::new(1);
    b.run("shedder/utility_queue_offer_pop_x1000", || {
        let mut q: UtilityQueue<u64> = UtilityQueue::new(16);
        for i in 0..1000u64 {
            q.offer(rng.f32(), i as f64, i);
            if i % 3 == 0 {
                q.pop_best();
            }
        }
        std::hint::black_box(q.len());
    });
    let mut cdf = UtilityCdf::new(600);
    for _ in 0..600 {
        cdf.add(rng.f32());
    }
    b.run("utility/cdf_add+threshold (window 600)", || {
        cdf.add(rng.f32());
        std::hint::black_box(cdf.threshold_for(0.7));
    });

    // --- substrates ----------------------------------------------------------
    let json_doc = model2.to_json().to_string_pretty();
    b.run("util/json_parse_model_file", || {
        std::hint::black_box(uals::util::json::parse(&json_doc).unwrap());
    });

    b.write_csv(std::path::Path::new("results/microbench.csv")).unwrap();
    println!("\nwrote results/microbench.csv");
}
