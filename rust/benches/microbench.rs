//! Hot-path microbenchmarks (`cargo bench --bench microbench`, or
//! `make bench` / `cargo bench-micro` from the repo root).
//!
//! Covers every component on the per-frame request path plus the
//! substrates the coordinator leans on. Results go to stdout,
//! `results/microbench.csv`, and the machine-readable `BENCH_micro.json`
//! at the repo root (per-bench ns/op — the cross-PR perf trajectory;
//! see EXPERIMENTS.md §Performance).

use uals::backend::{foreground_mask, largest_blob, BackendQuery, CostModel, Detector};
use uals::color::{ColorLut, NamedColor};
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::{
    reference, Extractor, FrameFeatures, IncrementalConfig, IncrementalEngine, QuantScratch,
    UtilityValues,
};
use uals::pipeline::{
    multi_backend_seed, multi_backends, run_fleet, run_multi_sim, run_sharded_sim,
    run_sharded_sim_with, AggregatorPolicy, FleetConfig, FleetTopology, MultiSimConfig,
    PipelineConfig, Policy, SimConfig, TransportConfig,
};
use uals::runtime::Engine;
use uals::shedder::{ArbiterPolicy, QuerySet, UtilityQueue};
use uals::util::bench::Bench;
use uals::util::rng::Rng;
use uals::utility::{train, Combine, UtilityCdf};
use uals::video::{Frame, Video, VideoConfig};

fn main() {
    let mut b = Bench::new(3, 40);

    // --- fixtures -----------------------------------------------------------
    let mut vc = VideoConfig::new(7, 21, 0, 60);
    vc.traffic.vehicle_rate = 0.8;
    let video = Video::new(vc);
    let frame = video.render(30);
    let bg = video.background().to_vec();
    // u8-camera variants (what real cameras ship): integer-valued pixels
    // take the LUT fast path; the float fixtures keep the legacy numbers.
    let frame_u8: Vec<f32> = frame.rgb.iter().map(|x| x.round()).collect();
    let bg_u8: Vec<f32> = bg.iter().map(|x| x.round()).collect();
    let ranges = [NamedColor::Red.ranges(), NamedColor::Yellow.ranges()];
    let videos = vec![video];
    let model2 = train(
        &videos,
        &[0],
        &[NamedColor::Red, NamedColor::Yellow],
        Combine::Or,
    );
    let model1 = train(&videos, &[0], &[NamedColor::Red], Combine::Single);

    // --- L3 native hot path -------------------------------------------------
    b.run("video/render_frame_96x96", || {
        std::hint::black_box(videos[0].render(31));
    });
    let mut arena = Frame::empty();
    b.run("video/render_into_96x96 (arena)", || {
        videos[0].render_into(31, &mut arena);
        std::hint::black_box(arena.rgb.len());
    });
    // The fused LUT fast path vs the reference oracle, same u8 frame.
    let lut2 = ColorLut::new(&ranges, reference::FG_THRESHOLD);
    let mut quant = QuantScratch::default();
    let mut feats_buf = FrameFeatures::empty();
    b.run("features/native_extract_2colors", || {
        uals::features::compute_features_fast_into(
            &lut2,
            &frame_u8,
            &bg_u8,
            &mut quant,
            &mut feats_buf,
        );
        std::hint::black_box(feats_buf.fg_frac);
    });
    b.run("features/native_extract_2colors_reference", || {
        std::hint::black_box(reference::compute_features(
            &frame_u8,
            &bg_u8,
            &ranges,
            reference::FG_THRESHOLD,
        ));
    });
    let native1 = Extractor::native(model1.clone());
    b.run("features/native_extract+utility_1color", || {
        std::hint::black_box(native1.extract(&frame_u8, &bg_u8).unwrap());
    });
    let mut utils_buf = UtilityValues::empty();
    b.run("features/extract_into+utility_1color (0-alloc)", || {
        native1
            .extract_into(&frame_u8, &bg_u8, &mut feats_buf, &mut utils_buf)
            .unwrap();
        std::hint::black_box(utils_buf.combined);
    });
    // --- temporal-redundancy incremental engine -----------------------------
    // Four redundancy regimes at 96×96, u8 camera, noise-free (so frames
    // actually repeat): static scene, sparse traffic, dense traffic, and a
    // scene-cut storm (every frame completely different — the worst case,
    // which must degrade to the fused path's cost, not below it).
    let redundancy_video = |vehicle_rate: f64, ped_rate: f64, seed: u64| -> Video {
        let mut rvc = VideoConfig::new(7, seed, 0, 48);
        rvc.traffic.vehicle_rate = vehicle_rate;
        rvc.traffic.pedestrian_rate = ped_rate;
        rvc.pixel_noise = 0.0;
        rvc.brightness_jitter = 0.0;
        rvc.quantize_u8 = true;
        Video::new(rvc)
    };
    let render_all =
        |v: &Video| -> Vec<Vec<f32>> { (0..v.len()).map(|t| v.render(t).rgb).collect() };
    let static_v = redundancy_video(0.0, 0.0, 31);
    let sparse_v = redundancy_video(0.1, 0.1, 33);
    let dense_v = redundancy_video(2.0, 0.8, 35);
    let mut cut_rng = Rng::new(0x5CEE);
    let scenecut_frames: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..96 * 96 * 3).map(|_| cut_rng.below(256) as f32).collect())
        .collect();
    let scenarios: Vec<(&str, Vec<Vec<f32>>, Vec<f32>)> = vec![
        ("static", render_all(&static_v), static_v.background().to_vec()),
        ("sparse", render_all(&sparse_v), sparse_v.background().to_vec()),
        ("dense", render_all(&dense_v), dense_v.background().to_vec()),
        ("scenecut", scenecut_frames, static_v.background().to_vec()),
    ];
    for (name, frames_set, bg_s) in &scenarios {
        let mut eng = IncrementalEngine::new(IncrementalConfig::default(), 96, 96);
        let mut ti = 0usize;
        b.run(&format!("features/incremental_{name}_96x96"), || {
            eng.extract_into(&lut2, &frames_set[ti], bg_s, None, &mut feats_buf);
            ti = (ti + 1) % frames_set.len();
            std::hint::black_box(feats_buf.fg_frac);
        });
        let mut quant_s = QuantScratch::default();
        let mut tj = 0usize;
        b.run(&format!("features/fastpath_{name}_96x96"), || {
            uals::features::compute_features_fast_into(
                &lut2,
                &frames_set[tj],
                bg_s,
                &mut quant_s,
                &mut feats_buf,
            );
            tj = (tj + 1) % frames_set.len();
            std::hint::black_box(feats_buf.fg_frac);
        });
    }
    // Hinted variant: the generator reports moved-object rects, so the
    // engine skips even the diff + full-frame quantization.
    {
        let frames_set = render_all(&sparse_v);
        let hints: Vec<(bool, Vec<(usize, usize, usize, usize)>)> = (0..sparse_v.len())
            .map(|t| {
                let mut r = Vec::new();
                let ok = sparse_v.dirty_rects_into(t, &mut r);
                (ok, r)
            })
            .collect();
        let bg_s = sparse_v.background().to_vec();
        let mut eng = IncrementalEngine::new(IncrementalConfig::default(), 96, 96);
        let mut ti = 0usize;
        b.run("features/incremental_hinted_sparse_96x96", || {
            let (ok, rects) = &hints[ti];
            let h = ok.then_some(rects.as_slice());
            eng.extract_into(&lut2, &frames_set[ti], &bg_s, h, &mut feats_buf);
            ti = (ti + 1) % frames_set.len();
            std::hint::black_box(feats_buf.fg_frac);
        });
    }

    // --- wire encoding (edge→backend transport) -----------------------------
    // Encode throughput + measured compression ratio per redundancy
    // regime: the delta encoder ships only dirty tiles, so the ratio on a
    // fixed camera is the transport headline (scenecut must degrade to
    // ~keyframe size, never worse than raw + header).
    {
        use uals::video::{raw_wire_size, WireEncoder, WireEncoding};
        let mut wire_buf: Vec<u8> = Vec::new();
        let mut enc_raw = WireEncoder::new(WireEncoding::Raw);
        let mut ri = 0usize;
        let raw_frames = &scenarios[1].1; // sparse traffic
        b.run("transport/encode_raw_96x96", || {
            enc_raw.encode_into(0, 96, 96, &raw_frames[ri], &mut wire_buf);
            ri = (ri + 1) % raw_frames.len();
            std::hint::black_box(wire_buf.len());
        });
        for (name, frames_set, _) in &scenarios {
            let mut enc = WireEncoder::new(WireEncoding::delta_default());
            let mut ti = 0usize;
            let mut bytes_total = 0u64;
            let mut msgs = 0u64;
            b.run(&format!("transport/encode_delta_{name}_96x96"), || {
                enc.encode_into(0, 96, 96, &frames_set[ti], &mut wire_buf);
                bytes_total += wire_buf.len() as u64;
                msgs += 1;
                ti = (ti + 1) % frames_set.len();
                std::hint::black_box(wire_buf.len());
            });
            let bpf = bytes_total as f64 / msgs.max(1) as f64;
            let ratio = bpf / raw_wire_size(96, 96) as f64;
            println!("  delta wire ratio vs raw ({name}): {ratio:.4}x ({bpf:.0} bytes/frame)");
        }
    }

    // --- SIMD kernel pairs (scalar oracle vs dispatched vector path) --------
    // The same counting kernel and dirty-tile scan at Level::Scalar and at
    // the host's resolved level, over the four redundancy regimes — the
    // per-kernel speedup the dispatcher buys, isolated from the rest of
    // the extraction pipeline.
    let simd_level = uals::simd::level();
    {
        use uals::features::HIST;
        use uals::simd::{self, Level};
        let quant_u8 = |src: &[f32]| -> Vec<u8> {
            let mut v = Vec::new();
            assert!(
                simd::quantize(Level::Scalar, src, &mut v),
                "bench frames must be integer-valued"
            );
            v
        };
        for (name, frames_set, bg_s) in &scenarios {
            let frame_q = quant_u8(&frames_set[frames_set.len() / 2]);
            let bg_q = quant_u8(bg_s);
            let k = lut2.num_colors();
            let mut pf = vec![0u32; k * HIST];
            let mut ic = vec![0u32; k];
            b.run(&format!("features/count_rect_scalar_{name}_96x96"), || {
                pf.fill(0);
                ic.fill(0);
                std::hint::black_box(simd::count_rect(
                    Level::Scalar,
                    &lut2,
                    &frame_q,
                    &bg_q,
                    96,
                    (0, 0, 96, 96),
                    k,
                    &mut pf,
                    &mut ic,
                ));
            });
            b.run(&format!("features/count_rect_simd_{name}_96x96"), || {
                pf.fill(0);
                ic.fill(0);
                std::hint::black_box(simd::count_rect(
                    simd_level,
                    &lut2,
                    &frame_q,
                    &bg_q,
                    96,
                    (0, 0, 96, 96),
                    k,
                    &mut pf,
                    &mut ic,
                ));
            });
        }
        // Dirty-tile scan between consecutive sparse frames: the 6×6 grid
        // of 16-px tiles the delta encoder walks at 96×96.
        let sparse_q: Vec<Vec<u8>> = scenarios[1].1.iter().map(|f| quant_u8(f)).collect();
        let scan = |level: Level, cur: &[u8], prev: &[u8]| -> usize {
            let mut dirty = 0usize;
            for ty in 0..6 {
                for tx in 0..6 {
                    let rect = (tx * 16, ty * 16, tx * 16 + 16, ty * 16 + 16);
                    if simd::rect_differs(level, cur, prev, 96, rect) {
                        dirty += 1;
                    }
                }
            }
            dirty
        };
        let mut si = 0usize;
        b.run("transport/delta_scan_scalar_96x96", || {
            let next = (si + 1) % sparse_q.len();
            std::hint::black_box(scan(Level::Scalar, &sparse_q[next], &sparse_q[si]));
            si = next;
        });
        let mut sj = 0usize;
        b.run("transport/delta_scan_simd_96x96", || {
            let next = (sj + 1) % sparse_q.len();
            std::hint::black_box(scan(simd_level, &sparse_q[next], &sparse_q[sj]));
            sj = next;
        });
    }

    b.run("backend/foreground_mask+largest_blob", || {
        let m = foreground_mask(&frame.rgb, &bg, 96, 96, 25.0);
        std::hint::black_box(largest_blob(&m));
    });
    let det = Detector::native(12, 25.0);
    b.run("backend/native_detector_2colors", || {
        std::hint::black_box(det.detect(&frame.rgb, &bg, 96, 96, &ranges).unwrap());
    });
    let mut bq = BackendQuery::new(
        QueryConfig::single(NamedColor::Red),
        Detector::native(12, 25.0),
        CostModel::new(CostConfig { jitter: 0.0, ..Default::default() }, 1),
        25.0,
    );
    b.run("backend/full_query_process", || {
        std::hint::black_box(bq.process(&frame.rgb, &bg, 96, 96).unwrap());
    });

    // --- multi-camera sweep engine ------------------------------------------
    let sweep_videos: Vec<Video> = (0..4)
        .map(|i| {
            let mut svc = VideoConfig::new(11, 0xBE6 + i as u64, i as u32, 120);
            svc.traffic.vehicle_rate = 0.35;
            svc.quantize_u8 = true; // u8 cameras → LUT fast path in the sweep
            Video::new(svc)
        })
        .collect();
    let sweep_model = train(&sweep_videos, &[0, 1], &[NamedColor::Red], Combine::Single);
    let sweep_cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xBE,
        fps_total: 10.0,
        transport: TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    };
    b.run_n("pipeline/sweep_4cams_serial", 1, 3, || {
        let r = run_sharded_sim(&sweep_videos, &sweep_cfg, &sweep_model, 1).unwrap();
        std::hint::black_box(r.0.ingress);
    });
    let threads = uals::pipeline::default_threads().min(4);
    b.run_n("pipeline/sweep_4cams_parallel", 1, 3, || {
        let r = run_sharded_sim(&sweep_videos, &sweep_cfg, &sweep_model, threads).unwrap();
        std::hint::black_box(r.0.ingress);
    });
    // Same sweep with noise-free u8 cameras so the per-camera incremental
    // engines actually see temporal redundancy in the event loop.
    let inc_videos: Vec<Video> = (0..4)
        .map(|i| {
            let mut svc = VideoConfig::new(11, 0xBE6 + i as u64, i as u32, 120);
            svc.traffic.vehicle_rate = 0.35;
            svc.pixel_noise = 0.0;
            svc.brightness_jitter = 0.0;
            svc.quantize_u8 = true;
            Video::new(svc)
        })
        .collect();
    let inc_model = train(&inc_videos, &[0, 1], &[NamedColor::Red], Combine::Single);
    b.run_n("pipeline/sweep_4cams_parallel_noisefree", 1, 3, || {
        let r = run_sharded_sim(&inc_videos, &sweep_cfg, &inc_model, threads).unwrap();
        std::hint::black_box(r.0.ingress);
    });
    b.run_n("pipeline/sweep_4cams_parallel_incremental", 1, 3, || {
        let r = run_sharded_sim_with(
            &inc_videos,
            &sweep_cfg,
            &inc_model,
            threads,
            Some(IncrementalConfig::default()),
        )
        .unwrap();
        std::hint::black_box(r.0.ingress);
    });

    // --- end-to-end core pipeline (SimClock driver) -------------------------
    // The shared-shedder deployment through `pipeline::core`: 4 cameras
    // interleaved into one Load Shedder + backend, full lifecycle per
    // frame. The headline below converts the row to frames/sec.
    let core_frames: usize = sweep_videos.iter().map(|v| v.len()).sum();
    let mut core_cfg = sweep_cfg.clone();
    core_cfg.fps_total = uals::video::streamer::aggregate_fps(&sweep_videos);
    b.run_n("pipeline/core_sim_e2e_4cams_480frames", 1, 3, || {
        let extractor = Extractor::native(sweep_model.clone());
        let mut backend = BackendQuery::new(
            core_cfg.query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(core_cfg.costs.clone(), core_cfg.seed),
            25.0,
        );
        let r = uals::pipeline::run_sim(
            uals::video::Streamer::new(&sweep_videos),
            &uals::pipeline::backgrounds_of(&sweep_videos),
            &core_cfg,
            &extractor,
            &mut backend,
        )
        .unwrap();
        std::hint::black_box(r.ingress);
    });

    // --- macro fleet scale (e2e headline rows) ------------------------------
    // 64- and 512-camera fleets through the sharded sweep engine (one
    // shedder + token-paced backend per camera, parallel shards). Short
    // per-camera clips keep the bench CI-sized; the headline rows below
    // convert each to aggregate frames/sec.
    let fleet = |n: usize, frames: usize| -> Vec<Video> {
        (0..n)
            .map(|i| {
                let mut svc =
                    VideoConfig::new(11 + (i as u64 % 3), 0xFEE7 + i as u64, i as u32, frames);
                svc.traffic.vehicle_rate = 0.35;
                svc.quantize_u8 = true;
                Video::new(svc)
            })
            .collect()
    };
    let fleet_threads = uals::pipeline::default_threads();
    let fleet64 = fleet(64, 40);
    let fleet64_frames: usize = fleet64.iter().map(|v| v.len()).sum();
    b.run_n("pipeline/macro_e2e_64cams", 1, 2, || {
        let r = run_sharded_sim(&fleet64, &sweep_cfg, &sweep_model, fleet_threads).unwrap();
        std::hint::black_box(r.0.ingress);
    });
    let fleet512 = fleet(512, 10);
    let fleet512_frames: usize = fleet512.iter().map(|v| v.len()).sum();
    b.run_n("pipeline/macro_e2e_512cams", 1, 2, || {
        let r = run_sharded_sim(&fleet512, &sweep_cfg, &sweep_model, fleet_threads).unwrap();
        std::hint::black_box(r.0.ingress);
    });

    // --- realtime engines: threaded channels vs socket reactor --------------
    // The same 64-camera stream through both wall-clock drivers
    // (fast-forwarded, cost emulation off, native oracle): the threaded
    // worker backend with in-process channels, and the epoll reactor
    // shipping every frame over real loopback TCP. The gap is the
    // kernel-socket tax of measured (rather than modeled) transfers.
    let rt64_cfg = uals::pipeline::realtime::RealtimeConfig {
        cost_emulation_scale: 0.0,
        time_scale: 1e-3,
        use_artifacts: false,
        seed: 0xBE,
        ..Default::default()
    };
    b.run_n("pipeline/threaded_e2e_64cams", 1, 2, || {
        let r = uals::pipeline::realtime::run_realtime(&fleet64, &sweep_model, &rt64_cfg)
            .unwrap();
        std::hint::black_box(r.ingress);
    });
    b.run_n("pipeline/reactor_e2e_64cams", 1, 2, || {
        let opts = uals::pipeline::ReactorOpts::default()
            .transport(uals::pipeline::SocketKind::Tcp);
        let r = uals::pipeline::run_reactor(&fleet64, &sweep_model, &rt64_cfg, &opts).unwrap();
        std::hint::black_box(r.pipeline.ingress + r.socket.acks_received);
    });

    // --- multi-query shared-stream pipeline ---------------------------------
    // 8 concurrent queries over the same 4-camera stream: ONE extraction
    // per frame + per-query shedding behind the fair-share arbiter,
    // versus 8 fully independent single-query pipelines (8 extractions
    // per frame). Same frames, same backend cost seeds per query.
    let mq_specs = uals::experiments::scenarios::multiquery_pool();
    let mq_set = QuerySet::train(&mq_specs, &sweep_videos, &[0, 1]).unwrap();
    let mq_fps = uals::video::streamer::aggregate_fps(&sweep_videos);
    let mq_bgs = uals::pipeline::backgrounds_of(&sweep_videos);
    let mq_cfg = MultiSimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        backend_tokens: 1,
        arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
        seed: 0xBE,
        fps_total: mq_fps,
        transport: TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
    };
    let mq_extractor = Extractor::native(mq_set.union_model().clone());
    b.run_n("multi/shared_extract_8q", 1, 3, || {
        let mut backends = multi_backends(&mq_set, &mq_cfg.costs, mq_cfg.seed);
        let r = run_multi_sim(
            uals::video::Streamer::new(&sweep_videos),
            &mq_bgs,
            &mq_set,
            &mq_cfg,
            &mq_extractor,
            &mut backends,
        )
        .unwrap();
        std::hint::black_box(r.frames);
    });
    // K=32 tenants (the 8-query pool cycled with distinct names) over the
    // same stream: the macro multi-tenant headline.
    let mq32_specs: Vec<uals::shedder::QuerySpec> = (0..32)
        .map(|i| {
            let s = &mq_specs[i % mq_specs.len()];
            uals::shedder::QuerySpec::new(
                format!("{}-{}", s.name, i / mq_specs.len()),
                s.query.clone(),
            )
        })
        .collect();
    let mq32_set = QuerySet::train(&mq32_specs, &sweep_videos, &[0, 1]).unwrap();
    let mq32_extractor = Extractor::native(mq32_set.union_model().clone());
    b.run_n("multi/shared_extract_32q", 1, 2, || {
        let mut backends = multi_backends(&mq32_set, &mq_cfg.costs, mq_cfg.seed);
        let r = run_multi_sim(
            uals::video::Streamer::new(&sweep_videos),
            &mq_bgs,
            &mq32_set,
            &mq_cfg,
            &mq32_extractor,
            &mut backends,
        )
        .unwrap();
        std::hint::black_box(r.frames);
    });
    let single_extractors: Vec<Extractor> = (0..mq_set.len())
        .map(|q| Extractor::native(mq_set.query_model(q)))
        .collect();
    b.run_n("multi/independent_8q", 1, 3, || {
        let mut total = 0u64;
        for q in 0..mq_set.len() {
            let cfg_q = SimConfig {
                costs: CostConfig::default(),
                shedder: ShedderConfig::default(),
                query: mq_set.queries()[q].config.clone(),
                backend_tokens: 1,
                policy: Policy::UtilityControlLoop,
                seed: mq_cfg.seed,
                fps_total: mq_fps,
                transport: TransportConfig::default(),
                faults: uals::pipeline::FaultPlan::default(),
                adaptation: uals::utility::AdaptationConfig::default(),
            };
            let mut backend = BackendQuery::new(
                cfg_q.query.clone(),
                Detector::native(12, 25.0),
                CostModel::new(cfg_q.costs.clone(), multi_backend_seed(mq_cfg.seed, q)),
                25.0,
            );
            let r = uals::pipeline::run_sim(
                uals::video::Streamer::new(&sweep_videos),
                &mq_bgs,
                &cfg_q,
                &single_extractors[q],
                &mut backend,
            )
            .unwrap();
            total += r.ingress;
        }
        std::hint::black_box(total);
    });

    // --- two-tier fleet (pipeline::fleet) -----------------------------------
    // The 64- and 512-camera sets again, but through the hierarchical
    // driver: multi-query edge nodes of 16 cameras each feeding the
    // deadline-capacity aggregator in front of an 8-worker cluster.
    let fleet2_set = QuerySet::train(&mq_specs[..2], &sweep_videos, &[0, 1]).unwrap();
    let fleet2_cfg = |nodes: usize| {
        FleetConfig::uniform(
            PipelineConfig { seed: 0xBE, ..PipelineConfig::default() },
            FleetTopology {
                edge_nodes: nodes,
                workers: 8,
                threads: fleet_threads,
                aggregator: AggregatorPolicy::DeadlineCapacity,
            },
        )
    };
    b.run_n("pipeline/fleet_e2e_64cams_4nodes", 1, 2, || {
        let r = run_fleet(&fleet64, &fleet2_set, &fleet2_cfg(4)).unwrap();
        std::hint::black_box(r.frames);
    });
    b.run_n("pipeline/fleet_e2e_512cams_32nodes", 1, 2, || {
        let r = run_fleet(&fleet512, &fleet2_set, &fleet2_cfg(32)).unwrap();
        std::hint::black_box(r.frames);
    });

    // --- AOT artifact path (PJRT) -------------------------------------------
    if let Ok(engine) = Engine::from_default_artifacts() {
        let art1 = Extractor::artifact(&engine, model1.clone()).unwrap();
        b.run("features/artifact_extract_1color (PJRT)", || {
            std::hint::black_box(art1.extract(&frame.rgb, &bg).unwrap());
        });
        let art2 = Extractor::artifact(&engine, model2.clone()).unwrap();
        b.run("features/artifact_extract_2colors (PJRT)", || {
            std::hint::black_box(art2.extract(&frame.rgb, &bg).unwrap());
        });
        let det_a = Detector::artifact(&engine).unwrap();
        b.run("backend/artifact_detector (PJRT)", || {
            std::hint::black_box(det_a.detect(&frame.rgb, &bg, 96, 96, &ranges).unwrap());
        });
    } else {
        eprintln!("(artifacts not built — skipping PJRT benches; run `make artifacts`)");
    }

    // --- shedder data structures -------------------------------------------
    let mut rng = Rng::new(1);
    b.run("shedder/utility_queue_offer_pop_x1000", || {
        let mut q: UtilityQueue<u64> = UtilityQueue::new(16);
        for i in 0..1000u64 {
            q.offer(rng.f32(), i as f64, i);
            if i % 3 == 0 {
                q.pop_best();
            }
        }
        std::hint::black_box(q.len());
    });
    let mut cdf = UtilityCdf::new(600);
    for _ in 0..600 {
        cdf.add(rng.f32());
    }
    b.run("utility/cdf_add+threshold (window 600)", || {
        cdf.add(rng.f32());
        std::hint::black_box(cdf.threshold_for(0.7));
    });

    // --- substrates ----------------------------------------------------------
    let json_doc = model2.to_json().to_string_pretty();
    b.run("util/json_parse_model_file", || {
        std::hint::black_box(uals::util::json::parse(&json_doc).unwrap());
    });

    // Headline ratios for the PR-perf trajectory.
    if let (Some(fast), Some(slow)) = (
        b.result("features/native_extract_2colors"),
        b.result("features/native_extract_2colors_reference"),
    ) {
        println!(
            "\nLUT fast path speedup (2-color extract): {:.2}x",
            slow.mean_ms / fast.mean_ms.max(1e-12)
        );
    }
    for name in ["static", "sparse", "dense", "scenecut"] {
        if let (Some(inc), Some(fast)) = (
            b.result(&format!("features/incremental_{name}_96x96")),
            b.result(&format!("features/fastpath_{name}_96x96")),
        ) {
            println!(
                "incremental vs fused fast path ({name}): {:.2}x",
                fast.mean_ms / inc.mean_ms.max(1e-12)
            );
        }
    }
    println!("resolved SIMD level: {}", simd_level.name());
    for name in ["static", "sparse", "dense", "scenecut"] {
        if let (Some(s), Some(v)) = (
            b.result(&format!("features/count_rect_scalar_{name}_96x96")),
            b.result(&format!("features/count_rect_simd_{name}_96x96")),
        ) {
            println!(
                "SIMD count_rect speedup, {} ({name}): {:.2}x",
                simd_level.name(),
                s.mean_ms / v.mean_ms.max(1e-12)
            );
        }
    }
    if let (Some(s), Some(v)) = (
        b.result("transport/delta_scan_scalar_96x96"),
        b.result("transport/delta_scan_simd_96x96"),
    ) {
        println!(
            "SIMD delta-scan speedup, {}: {:.2}x",
            simd_level.name(),
            s.mean_ms / v.mean_ms.max(1e-12)
        );
    }
    if let (Some(par), Some(ser)) = (
        b.result("pipeline/sweep_4cams_parallel"),
        b.result("pipeline/sweep_4cams_serial"),
    ) {
        println!(
            "parallel 4-camera sweep speedup ({threads} threads): {:.2}x",
            ser.mean_ms / par.mean_ms.max(1e-12)
        );
    }
    if let Some(core) = b.result("pipeline/core_sim_e2e_4cams_480frames") {
        println!(
            "core pipeline e2e throughput (SimClock driver): {:.0} frames/sec",
            core_frames as f64 / (core.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let (Some(shared), Some(indep)) = (
        b.result("multi/shared_extract_8q"),
        b.result("multi/independent_8q"),
    ) {
        println!(
            "8-query shared pipeline vs 8 independent pipelines: {:.2}x",
            indep.mean_ms / shared.mean_ms.max(1e-12)
        );
    }
    // Macro headline rows: fleet-scale e2e throughput + the K=32 tenant run.
    if let Some(m) = b.result("pipeline/macro_e2e_64cams") {
        println!(
            "macro e2e throughput, 64-camera fleet ({fleet_threads} threads): {:.0} frames/sec",
            fleet64_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let Some(m) = b.result("pipeline/macro_e2e_512cams") {
        println!(
            "macro e2e throughput, 512-camera fleet ({fleet_threads} threads): {:.0} frames/sec",
            fleet512_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let Some(m) = b.result("multi/shared_extract_32q") {
        println!(
            "32-query shared-stream pipeline: {:.0} frames/sec (one extraction per frame)",
            core_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let Some(m) = b.result("pipeline/threaded_e2e_64cams") {
        println!(
            "threaded realtime e2e, 64 cams: {:.0} frames/sec (in-process channels)",
            fleet64_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let Some(m) = b.result("pipeline/reactor_e2e_64cams") {
        println!(
            "reactor realtime e2e, 64 cams: {:.0} frames/sec (loopback TCP, measured transfers)",
            fleet64_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let Some(m) = b.result("pipeline/fleet_e2e_64cams_4nodes") {
        println!(
            "two-tier fleet e2e, 64 cams / 4 nodes / 8 workers: {:.0} frames/sec",
            fleet64_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }
    if let Some(m) = b.result("pipeline/fleet_e2e_512cams_32nodes") {
        println!(
            "two-tier fleet e2e, 512 cams / 32 nodes / 8 workers: {:.0} frames/sec",
            fleet512_frames as f64 / (m.mean_ms.max(1e-12) / 1e3)
        );
    }

    b.write_csv(std::path::Path::new("results/microbench.csv")).unwrap();
    // BENCH_micro.json lives at the repo root (one dir above the crate).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .join("BENCH_micro.json");
    b.write_json(&root).unwrap();
    println!("\nwrote results/microbench.csv and {}", root.display());
}
