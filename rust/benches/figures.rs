//! End-to-end figure benches (`cargo bench --bench figures`): one bench
//! per paper table/figure (DESIGN.md §6). Each run regenerates the
//! figure's data series (written under `results/`) and reports the
//! wall time of the full regeneration at the default scale.
//!
//! Scale via env: UALS_BENCH_SCALE=tiny|small|paper (default tiny so
//! `cargo bench` completes quickly; use small/paper for the real runs).

use uals::experiments::{run_and_save, Scale, ALL_FIGURES, OVERHEAD_FIGURE, SCENARIOS};
use uals::util::bench::Bench;

fn main() {
    let scale = std::env::var("UALS_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Tiny);
    println!("figure benches at scale {scale:?} (set UALS_BENCH_SCALE to change)\n");

    let out = std::path::PathBuf::from("results");
    let mut b = Bench::new(0, 1);
    for id in ALL_FIGURES.iter().chain([&OVERHEAD_FIGURE]).chain(SCENARIOS.iter()) {
        b.run(&format!("figure_{id}"), || {
            run_and_save(&[id], scale, &out, true).expect("figure run");
        });
    }
    b.write_csv(std::path::Path::new("results/figure_bench.csv")).unwrap();
    println!("\nall figure CSVs under results/; timings in results/figure_bench.csv");
}
