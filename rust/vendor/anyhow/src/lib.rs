//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the repo uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Context is flattened into the
//! message (`"outer: inner"`), which matches how the binary prints
//! errors (`{e:#}`); source-chain introspection (`downcast_ref` etc.)
//! is intentionally not provided — nothing in the repo uses it.

use std::fmt;

/// A flattened, `Send + Sync` error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Construct from a concrete error value (mirrors `anyhow::Error::new`).
    pub fn new<E: std::error::Error>(e: E) -> Error {
        Error { msg: e.to_string() }
    }

    /// Prepend a context layer: `"ctx: previous"`.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket conversion below coherent (exactly the
// trick real `anyhow` relies on).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Create an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to the error arm of a `Result` (or to a `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let name = "red";
        let e = anyhow!("unknown color '{name}'");
        assert_eq!(e.to_string(), "unknown color 'red'");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "nope 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest: gone");
        let e2: Error = Error::msg("inner").context("outer");
        assert_eq!(e2.to_string(), "outer: inner");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        let s: Option<u32> = Some(4);
        assert_eq!(s.with_context(|| "unused").unwrap(), 4);
    }
}
