//! Offline **stub** of the `xla` crate (PJRT binding).
//!
//! The build environment does not ship the native `xla_extension`
//! runtime, so this vendored crate mirrors the API surface that
//! `uals::runtime::engine` compiles against and fails *at runtime* when
//! a PJRT client is requested. Every artifact-backed code path in the
//! repo is gated on `Engine::from_default_artifacts()` succeeding, so
//! with this stub those paths cleanly report "unavailable" instead of
//! breaking the build.
//!
//! To run the real AOT artifacts, replace this crate with the actual
//! `xla` binding (same module paths and method names) — no change to
//! `uals` is required.

use std::fmt;
use std::path::Path;

/// Error type matching the real binding's `xla::Error` role.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn unavailable() -> Error {
        Error::msg(
            "PJRT runtime unavailable: this build links the offline `xla` stub \
             (rust/vendor/xla); vendor the real xla crate to execute AOT artifacts",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client: construction always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Host literal: a flat f32 buffer plus dims (enough for the engine's use).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        let n = data.len() as i64;
        Literal { data: data.to_vec(), dims: vec![n] }
    }

    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error::msg(format!(
                "reshape to {dims:?} mismatches {} elements",
                self.data.len()
            )));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone() }))
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::msg("stub literal is not a tuple"))
    }
}

/// Element conversion used by `Literal::to_vec` (the engine only asks f32).
pub trait FromF32 {
    fn from_f32(x: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

/// Shape of a device value.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Array shape with i64 dims, matching the real binding.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"), "{e}");
    }

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        match l.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(Literal::vec1(&[1.0]).reshape(&[3]).is_err());
    }
}
