//! Defaults audit for the unified builder (`Pipeline::builder()`):
//!
//! 1. **Field pin** — `PipelineConfig::default()` carries exactly the
//!    values the historical per-driver config literals spelled out, so
//!    replacing a literal with the builder can never silently move a
//!    knob.
//! 2. **Run pin** — a builder run touched only where the historical
//!    code differed from the defaults (fps) bit-matches the fully
//!    spelled-out `SimConfig` literal through the free function.
//! 3. **Shared slice** — `RealtimeConfig::default()` embeds the same
//!    `PipelineConfig` slice plus the documented wall-clock-only knobs.

use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::{
    backgrounds_of, run_sim, FaultPlan, Pipeline, PipelineConfig, Policy, RealtimeConfig,
    SimConfig, TransportConfig,
};
use uals::utility::{train, AdaptationConfig, Combine};
use uals::video::{
    streamer::aggregate_fps, Streamer, Video, VideoConfig, WireEncoding, MIN_TARGET_PX,
};

fn cameras(n: usize, frames: usize) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc =
                VideoConfig::new(0xDEF + i as u64 % 2, 0xDEF0 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = 0.35;
            Video::new(vc)
        })
        .collect()
}

#[test]
fn pipeline_defaults_pin_the_historical_literals() {
    let p = PipelineConfig::default();

    // CostConfig: the paper-calibrated stage costs.
    assert_eq!(p.costs.cam_ms, 30.0);
    assert_eq!(p.costs.blob_ms, 4.0);
    assert_eq!(p.costs.color_ms, 1.5);
    assert_eq!(p.costs.dnn_ms, 120.0);
    assert_eq!(p.costs.sink_ms, 1.0);
    assert_eq!(p.costs.net_cam_ls_ms, 5.0);
    assert_eq!(p.costs.net_ls_q_ms, 5.0);
    assert_eq!(p.costs.jitter, 0.08);

    // ShedderConfig: §IV-C/D tuning.
    assert_eq!(p.shedder.history, 600);
    assert_eq!(p.shedder.update_every, 5);
    assert_eq!(p.shedder.queue_cap_max, 16);
    assert_eq!(p.shedder.proc_ewma_alpha, 0.3);
    assert!(p.shedder.watchdog_ms.is_infinite(), "watchdog off by default");
    assert!(p.shedder.camera_liveness_ms.is_infinite(), "liveness off by default");

    // Query: single red, paper blob floor, 1 s bound.
    assert_eq!(p.query.colors, vec![NamedColor::Red]);
    assert_eq!(p.query.combine, Combine::Single);
    assert_eq!(p.query.min_blob_px, MIN_TARGET_PX);
    assert_eq!(p.query.latency_bound_ms, 1000.0);

    // Driver knobs.
    assert_eq!(p.backend_tokens, 1);
    assert!(matches!(p.policy, Policy::UtilityControlLoop));
    assert_eq!(p.seed, 0xB_E);
    assert_eq!(p.fps_total, 10.0);

    // Transport / faults / adaptation: all off.
    assert!(p.transport.is_ideal(), "default link must be ideal");
    assert_eq!(p.transport.encoding, WireEncoding::Raw);
    assert!(p.faults.is_empty(), "default fault plan must be empty");
    assert!(!p.adaptation.enabled, "adaptation off by default");

    // The builder with no setters is exactly this default, and the
    // SimConfig round trip preserves it.
    let built: SimConfig = Pipeline::builder().build().into();
    assert_eq!(built.seed, 0xB_E);
    assert_eq!(built.fps_total, 10.0);
    assert_eq!(built.query.colors, vec![NamedColor::Red]);
}

#[test]
fn builder_default_run_matches_the_spelled_out_literal() {
    let videos = cameras(3, 140);
    let fps = aggregate_fps(&videos);
    let idx: Vec<usize> = (0..videos.len()).collect();
    let model = train(&videos, &idx, &[NamedColor::Red], Combine::Single);

    // The fully spelled-out historical literal (every field explicit).
    let cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xB_E,
        fps_total: fps,
        transport: TransportConfig::default(),
        faults: FaultPlan::default(),
        adaptation: AdaptationConfig::default(),
    };
    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    let hist = run_sim(
        Streamer::new(&videos),
        &backgrounds_of(&videos),
        &cfg,
        &extractor,
        &mut backend,
    )
    .expect("literal run");

    // The builder, touching only what the literal changed (fps).
    let built = Pipeline::builder()
        .fps_total(fps)
        .sim()
        .run(&videos, &model)
        .expect("builder run");

    assert_eq!(hist.decisions, built.decisions, "decision logs must be bit-identical");
    assert_eq!(hist.ingress, built.ingress);
    assert_eq!(hist.transmitted, built.transmitted);
    assert_eq!(hist.shed, built.shed);
    assert_eq!(hist.qor.overall(), built.qor.overall());
    assert_eq!(hist.latency.count(), built.latency.count());
    assert_eq!(hist.latency.max_ms(), built.latency.max_ms());
}

#[test]
fn realtime_default_embeds_the_shared_pipeline_slice() {
    let rt = RealtimeConfig::default();
    let p = PipelineConfig::default();

    // Shared slice: identical to PipelineConfig::default().
    assert_eq!(rt.seed, p.seed);
    assert_eq!(rt.backend_tokens, p.backend_tokens);
    assert!(matches!(rt.policy, Policy::UtilityControlLoop));
    assert_eq!(rt.query.colors, p.query.colors);
    assert_eq!(rt.query.latency_bound_ms, p.query.latency_bound_ms);
    assert_eq!(rt.costs.dnn_ms, p.costs.dnn_ms);
    assert_eq!(rt.costs.jitter, p.costs.jitter);
    assert_eq!(rt.shedder.history, p.shedder.history);
    assert_eq!(rt.shedder.queue_cap_max, p.shedder.queue_cap_max);
    assert!(rt.transport.is_ideal());
    assert!(rt.faults.is_empty());
    assert!(!rt.adaptation.enabled);

    // Wall-clock-only knobs: the documented RealtimeOpts defaults.
    assert_eq!(rt.cost_emulation_scale, 1.0);
    assert_eq!(rt.time_scale, 1.0);
    assert!(rt.use_artifacts);
    assert_eq!(rt.backend_recv_timeout_ms, 30_000.0);
    assert_eq!(rt.worker_restart_max, 2);
    assert_eq!(rt.worker_restart_backoff_ms, 50.0);
}
