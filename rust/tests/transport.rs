//! Transport-layer properties:
//!
//! 1. **Ideal-link bit-identity** — with [`LinkModel::ideal`] (the
//!    default), every driver (sim, realtime, sharded, multi) produces
//!    decision logs bit-identical to the pre-transport pipeline, across
//!    seeds and policies, regardless of the configured wire encoding.
//! 2. **Wire round trip** — decode(encode(frame)) reproduces the input
//!    exactly along randomized streams (raw and delta modes, keyframe
//!    fallback and float escapes included).
//! 3. **Accounting invariant** — `ingress = transmitted + shed +
//!    link_dropped` under constrained and lossy links, and the decision
//!    log stays one entry per ingress frame.
//! 4. **Congestion response** — as bandwidth drops the control loop
//!    sheds more while the measured E2E latency (transmit time included)
//!    stays essentially within the bound; sim and realtime agree
//!    frame-for-frame even on a constrained, jittered, lossy link.
//! 5. **Shared transmission** — the multi-query engine ships each
//!    admitted frame once over the one shared link.

use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::realtime::{run_realtime, RealtimeConfig};
use uals::pipeline::{
    backgrounds_of, multi_backends, run_multi_sim, run_sharded_sim, run_sim, FrameDecision,
    LinkModel, MultiSimConfig, Policy, SimConfig, SimReport, TransportConfig,
};
use uals::shedder::{ArbiterPolicy, QuerySet, QuerySpec};
use uals::utility::{train, Combine, UtilityModel};
use uals::video::{
    raw_wire_size, streamer::aggregate_fps, Streamer, Video, VideoConfig, WireDecoder,
    WireEncoder, WireEncoding, WireMode,
};

fn cameras(n: usize, frames: usize, vehicle_rate: f64, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0x7A0 ^ seed, seed * 37 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = vehicle_rate;
            Video::new(vc)
        })
        .collect()
}

/// Noise-free u8 cameras: integer frames (raw-u8 wire path) with real
/// temporal redundancy (delta wire path).
fn u8_cameras(n: usize, frames: usize, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0x7A1, seed * 53 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = 0.35;
            vc.pixel_noise = 0.0;
            vc.brightness_jitter = 0.0;
            vc.quantize_u8 = true;
            Video::new(vc)
        })
        .collect()
}

fn model_for(videos: &[Video]) -> UtilityModel {
    let idx: Vec<usize> = (0..videos.len()).collect();
    train(videos, &idx, &[NamedColor::Red], Combine::Single)
}

fn sim_cfg(fps: f64, seed: u64, policy: Policy) -> SimConfig {
    SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1200.0),
        backend_tokens: 1,
        policy,
        seed,
        fps_total: fps,
        transport: TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    }
}

fn run_sim_driver(videos: &[Video], cfg: &SimConfig, model: &UtilityModel) -> SimReport {
    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    run_sim(
        Streamer::new(videos),
        &backgrounds_of(videos),
        cfg,
        &extractor,
        &mut backend,
    )
    .expect("sim driver")
}

fn assert_decisions_equal(a: &[FrameDecision], b: &[FrameDecision], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: decision counts differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x, y, "{label}: decision {i} diverges");
    }
}

// ---------------------------------------------------------------------------
// 1. Ideal-link bit-identity
// ---------------------------------------------------------------------------

#[test]
fn ideal_link_is_bit_identical_across_seeds_policies_and_encodings() {
    for (seed, policy) in [
        (0x91u64, Policy::UtilityControlLoop),
        (0x92, Policy::UtilityControlLoop),
        (0x91, Policy::FifoControlLoop),
        (0x92, Policy::RandomRate { assumed_proc_q_ms: 120.0 }),
    ] {
        let videos = cameras(2, 90, 0.4, seed);
        let model = model_for(&videos);
        let base = sim_cfg(aggregate_fps(&videos), seed, policy.clone());
        let baseline = run_sim_driver(&videos, &base, &model);

        // Explicitly-constructed ideal link: identical to the default.
        let mut explicit = base.clone();
        explicit.transport =
            TransportConfig { link: LinkModel::ideal(), encoding: WireEncoding::Raw };
        let r1 = run_sim_driver(&videos, &explicit, &model);
        assert_decisions_equal(&baseline.decisions, &r1.decisions, "explicit ideal");
        assert_eq!(baseline.control_series, r1.control_series, "seed {seed:x}");
        assert_eq!(baseline.qor.overall(), r1.qor.overall());

        // The wire encoding must not influence decisions under any link.
        let mut delta = base.clone();
        delta.transport = TransportConfig {
            link: LinkModel::ideal(),
            encoding: WireEncoding::delta_default(),
        };
        let r2 = run_sim_driver(&videos, &delta, &model);
        assert_decisions_equal(&baseline.decisions, &r2.decisions, "ideal+delta");
        assert_eq!(baseline.link_dropped, 0);
        assert_eq!(r2.link_dropped, 0);
        // Ideal links are byte-accounted at the raw-u8 yardstick.
        let w = videos[0].config.width;
        let h = videos[0].config.height;
        assert_eq!(
            baseline.bytes_on_wire,
            baseline.transmitted * raw_wire_size(w, h) as u64
        );
    }
}

#[test]
fn ideal_link_is_clock_and_shard_invariant() {
    let videos = cameras(2, 80, 0.4, 0x95);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0x95, Policy::UtilityControlLoop);
    cfg.transport =
        TransportConfig { link: LinkModel::ideal(), encoding: WireEncoding::delta_default() };

    let sim = run_sim_driver(&videos, &cfg, &model);
    let rt = RealtimeConfig {
        query: cfg.query.clone(),
        shedder: cfg.shedder.clone(),
        costs: cfg.costs.clone(),
        cost_emulation_scale: 0.0,
        time_scale: 1e-3,
        backend_tokens: cfg.backend_tokens,
        use_artifacts: false,
        policy: cfg.policy.clone(),
        seed: cfg.seed,
        arbiter: ArbiterPolicy::Standalone,
        transport: cfg.transport,
        ..Default::default()
    };
    let wall = run_realtime(&videos, &model, &rt).expect("wall driver");
    assert_decisions_equal(&sim.decisions, &wall.decisions, "ideal sim vs wall");
    assert_eq!(sim.bytes_on_wire, wall.bytes_on_wire);

    // Sharded: per-camera shards with the transport config stay
    // deterministic and conserve frames.
    let (merged_1, _) = run_sharded_sim(&videos, &cfg, &model, 1).expect("sharded x1");
    let (merged_n, _) = run_sharded_sim(&videos, &cfg, &model, 4).expect("sharded x4");
    assert_decisions_equal(&merged_1.decisions, &merged_n.decisions, "shard threads");
    assert_eq!(merged_1.ingress, merged_1.transmitted + merged_1.shed);
    assert_eq!(merged_1.link_dropped, 0);
}

// ---------------------------------------------------------------------------
// 2. Wire round trip over randomized streams
// ---------------------------------------------------------------------------

#[test]
fn wire_roundtrip_is_exact_over_rendered_streams() {
    // A real rendered stream (u8, redundant) through the delta encoder,
    // with a float frame and a scene cut spliced in: every decode must
    // equal the encoder input exactly.
    let videos = u8_cameras(1, 40, 0x61);
    let v = &videos[0];
    let (w, h) = (v.config.width, v.config.height);
    let mut frames: Vec<Vec<f32>> = (0..v.len()).map(|t| v.render(t).rgb).collect();
    frames[13][7] += 0.5; // float escape mid-stream
    for x in frames[29].iter_mut() {
        *x = (*x + 91.0) % 256.0; // synthetic scene cut
    }

    for encoding in [WireEncoding::Raw, WireEncoding::delta_default()] {
        let mut enc = WireEncoder::new(encoding);
        let mut dec = WireDecoder::new().with_tile(16);
        let (mut buf, mut out) = (Vec::new(), Vec::new());
        let mut delta_msgs = 0u64;
        let mut delta_bytes = 0u64;
        for f in &frames {
            let mode = enc.encode_into(0, w, h, f, &mut buf);
            let hdr = dec.decode_into(&buf, &mut out).expect("decode");
            assert_eq!(hdr.mode, mode);
            assert_eq!(&out, f, "round trip must be exact");
            if mode == WireMode::Delta {
                delta_msgs += 1;
                delta_bytes += buf.len() as u64;
            }
        }
        if encoding != WireEncoding::Raw {
            assert!(delta_msgs > 30, "delta path must dominate ({delta_msgs})");
            // Measured compression: dirty-tile diffs on a fixed camera
            // are far below the raw frame size.
            let mean = delta_bytes as f64 / delta_msgs as f64;
            assert!(
                mean < raw_wire_size(w, h) as f64 / 2.0,
                "mean delta message {mean} bytes"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 3 + 4. Constrained and lossy links
// ---------------------------------------------------------------------------

fn constrained_cfg(fps: f64, mbps: f64, link: LinkModel) -> SimConfig {
    let mut cfg = sim_cfg(fps, 0xC0, Policy::UtilityControlLoop);
    cfg.transport = TransportConfig {
        link: LinkModel { bandwidth_mbps: mbps, ..link },
        encoding: WireEncoding::Raw,
    };
    cfg
}

#[test]
fn narrowing_the_link_makes_the_control_loop_shed_more() {
    let videos = u8_cameras(3, 120, 0x77);
    let model = model_for(&videos);
    let fps = aggregate_fps(&videos);

    let mut drops = Vec::new();
    for mbps in [1000.0, 1.5, 0.5] {
        let cfg = constrained_cfg(fps, mbps, LinkModel::ideal());
        let r = run_sim_driver(&videos, &cfg, &model);
        assert_eq!(r.ingress, r.transmitted + r.shed + r.link_dropped);
        assert_eq!(r.decisions.len() as u64, r.ingress);
        // No loss configured: nothing may vanish on the link.
        assert_eq!(r.link_dropped, 0);
        // The measured E2E latency includes transmit time, and the
        // deadline check + Eq. 20 sizing keep it essentially bounded
        // (the EWMA transient before the link latency is learned allows
        // a few early violations on the severely constrained points).
        let viol_cap = if mbps >= 100.0 { 0.05 } else { 0.35 };
        assert!(
            r.latency.violation_rate() < viol_cap,
            "{mbps} Mbps: violation rate {}",
            r.latency.violation_rate()
        );
        assert!(r.transmit_ms_mean() >= 0.0);
        drops.push((r.shed + r.link_dropped) as f64 / r.ingress as f64);
    }
    assert!(
        drops[2] > drops[0] + 0.05,
        "0.5 Mbps drop {} must exceed 1000 Mbps drop {}",
        drops[2],
        drops[0]
    );
    assert!(drops[1] >= drops[0] - 1e-9, "monotone-ish: {drops:?}");
}

#[test]
fn lossy_link_accounting_invariant_holds() {
    let videos = u8_cameras(2, 100, 0x78);
    let model = model_for(&videos);
    let fps = aggregate_fps(&videos);
    let link = LinkModel {
        bandwidth_mbps: 4.0,
        propagation_ms: 3.0,
        jitter: 0.2,
        loss: 0.35,
        max_retransmits: 1,
    };
    let mut cfg = sim_cfg(fps, 0xD1, Policy::UtilityControlLoop);
    cfg.transport = TransportConfig { link, encoding: WireEncoding::Raw };
    let r = run_sim_driver(&videos, &cfg, &model);

    assert!(r.link_dropped > 0, "p(loss twice) = 12% must bite");
    assert_eq!(r.ingress, r.transmitted + r.shed + r.link_dropped);
    assert_eq!(r.decisions.len() as u64, r.ingress);
    let kept = r.decisions.iter().filter(|d| d.kept).count() as u64;
    assert_eq!(kept, r.transmitted);
    // Every frame that entered the link is byte-accounted, lost or not.
    let (w, h) = (videos[0].config.width, videos[0].config.height);
    assert_eq!(
        r.bytes_on_wire,
        (r.transmitted + r.link_dropped) * raw_wire_size(w, h) as u64
    );
}

#[test]
fn sim_and_realtime_agree_on_a_constrained_lossy_link() {
    let videos = u8_cameras(2, 80, 0x79);
    let model = model_for(&videos);
    let fps = aggregate_fps(&videos);
    let link = LinkModel {
        bandwidth_mbps: 2.0,
        propagation_ms: 4.0,
        jitter: 0.1,
        loss: 0.2,
        max_retransmits: 2,
    };
    let mut cfg = sim_cfg(fps, 0xE7, Policy::UtilityControlLoop);
    cfg.transport = TransportConfig { link, encoding: WireEncoding::delta_default() };

    let sim = run_sim_driver(&videos, &cfg, &model);
    let rt = RealtimeConfig {
        query: cfg.query.clone(),
        shedder: cfg.shedder.clone(),
        costs: cfg.costs.clone(),
        cost_emulation_scale: 0.0,
        time_scale: 1e-3,
        backend_tokens: cfg.backend_tokens,
        use_artifacts: false,
        policy: cfg.policy.clone(),
        seed: cfg.seed,
        arbiter: ArbiterPolicy::Standalone,
        transport: cfg.transport,
        ..Default::default()
    };
    let wall = run_realtime(&videos, &model, &rt).expect("wall driver");
    assert_decisions_equal(&sim.decisions, &wall.decisions, "constrained link");
    assert_eq!(sim.transmitted, wall.transmitted);
    assert_eq!(sim.link_dropped, wall.link_dropped);
    assert_eq!(sim.bytes_on_wire, wall.bytes_on_wire);
}

// ---------------------------------------------------------------------------
// 5. Shared transmission in the multi-query engine
// ---------------------------------------------------------------------------

#[test]
fn multi_query_ships_each_admitted_frame_once() {
    let videos = u8_cameras(2, 100, 0x80);
    let idx: Vec<usize> = (0..videos.len()).collect();
    let specs = vec![
        QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
        QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)),
        QuerySpec::new(
            "either",
            QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Or),
        ),
    ];
    let set = QuerySet::train(&specs, &videos, &idx).expect("query set");
    let fps = aggregate_fps(&videos);
    let cfg = MultiSimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        backend_tokens: 1,
        arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
        seed: 0xF0,
        fps_total: fps,
        // Fast but *modeled* link: the wire path engages (per-frame
        // encode + byte accounting) without starving any query's
        // dispatch, so the sharing arithmetic below is load-independent.
        transport: TransportConfig::constrained(50.0, WireEncoding::Raw),
        faults: uals::pipeline::FaultPlan::default(),
    };
    let extractor = Extractor::native(set.union_model().clone());
    let mut backends = multi_backends(&set, &cfg.costs, cfg.seed);
    let bgs = backgrounds_of(&videos);
    let r = run_multi_sim(
        Streamer::new(&videos),
        &bgs,
        &set,
        &cfg,
        &extractor,
        &mut backends,
    )
    .expect("multi sim");

    // The shared-transmission invariant: at most one crossing per
    // physical frame, regardless of how many of the 3 queries admit it.
    assert!(r.wire_frames <= r.frames, "{} crossings > {} frames", r.wire_frames, r.frames);
    assert!(r.wire_frames > 0);
    let (w, h) = (videos[0].config.width, videos[0].config.height);
    assert_eq!(r.bytes_on_wire, r.wire_frames * raw_wire_size(w, h) as u64);
    assert_eq!(r.link_lost_frames, 0);
    for q in &r.queries {
        // Every frame a query sent (or lost) crossed the shared link —
        // never more crossings than physically happened.
        assert!(q.report.transmitted + q.report.link_dropped <= r.wire_frames);
        assert_eq!(
            q.report.ingress,
            q.report.transmitted + q.report.shed + q.report.link_dropped
        );
        // Physical bytes live on the shared report only.
        assert_eq!(q.report.bytes_on_wire, 0);
    }
    // An independent deployment would pay one crossing per (query,
    // frame): strictly more than the shared link carried.
    let per_query_sum: u64 = r
        .queries
        .iter()
        .map(|q| q.report.transmitted + q.report.link_dropped)
        .sum();
    assert!(
        per_query_sum > r.wire_frames,
        "sharing must be visible: {per_query_sum} query-sends vs {} crossings",
        r.wire_frames
    );
}
