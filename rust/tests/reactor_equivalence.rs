//! Socket-transparency property of the reactor driver: shipping every
//! frame across a real loopback socket (TCP or Unix-domain) must not
//! change a single shedding decision. With measured-transfer feeding
//! off, the reactor and the threaded `WallClock` driver see the same
//! virtual-time event order on the same seed and stream, so their
//! per-frame decision logs must be **bit-identical** — the reactor's
//! epoll loop, wire encoding and ack rendezvous are pure plumbing.
//!
//! Plus the measurement property (feeding on actually reaches the
//! control loop) and a fault-composition smoke (a randomized fault
//! storm in reactor mode still satisfies the conservation ledger).

use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::pipeline::realtime::{run_realtime, RealtimeConfig};
use uals::pipeline::{
    run_reactor, FaultPlan, FrameDecision, Pipeline, Policy, ReactorOpts, SocketKind,
};
use uals::utility::{train, Combine, UtilityModel};
use uals::video::{Video, VideoConfig, WireEncoding};

fn cameras(n: usize, frames: usize, vehicle_rate: f64, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0xE01 ^ seed, seed * 31 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = vehicle_rate;
            Video::new(vc)
        })
        .collect()
}

fn model_for(videos: &[Video]) -> UtilityModel {
    let idx: Vec<usize> = (0..videos.len()).collect();
    train(videos, &idx, &[NamedColor::Red], Combine::Single)
}

/// Ideal-conditions realtime config: cost emulation off, 1000×
/// fast-forward, native oracle — the `core_equivalence.rs` recipe.
fn rt_cfg(seed: u64, policy: Policy) -> RealtimeConfig {
    RealtimeConfig {
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1200.0),
        shedder: ShedderConfig::default(),
        costs: CostConfig::default(),
        cost_emulation_scale: 0.0,
        time_scale: 1e-3,
        backend_tokens: 1,
        use_artifacts: false,
        policy,
        seed,
        ..Default::default()
    }
}

fn assert_decisions_equal(wall: &[FrameDecision], reactor: &[FrameDecision], label: &str) {
    assert_eq!(wall.len(), reactor.len(), "{label}: decision counts differ");
    for (i, (a, b)) in wall.iter().zip(reactor).enumerate() {
        assert_eq!(a, b, "{label}: decision {i} diverges");
    }
}

#[test]
fn reactor_matches_threaded_wallclock_across_seeds_policies_and_sockets() {
    // Property over (seed, policy, socket family): the socket hop is
    // decision-transparent when measured feeding is off.
    for (seed, policy) in [
        (0x61u64, Policy::UtilityControlLoop),
        (0x62, Policy::FifoControlLoop),
    ] {
        let videos = cameras(2, 30, 0.35, seed);
        let model = model_for(&videos);
        let cfg = rt_cfg(seed, policy.clone());

        let wall = run_realtime(&videos, &model, &cfg).expect("wall driver");

        for kind in [SocketKind::Tcp, SocketKind::Unix] {
            let opts = ReactorOpts::default().transport(kind).feed_network(false);
            let label = format!("seed {seed:x} / {policy:?} / {}", kind.name());
            let r = run_reactor(&videos, &model, &cfg, &opts).expect("reactor driver");

            assert_eq!(r.pipeline.ingress, 60, "{label}");
            assert_eq!(wall.ingress, r.pipeline.ingress, "{label}");
            assert_eq!(wall.transmitted, r.pipeline.transmitted, "{label}");
            assert_eq!(wall.shed, r.pipeline.shed, "{label}");
            assert_decisions_equal(&wall.decisions, &r.pipeline.decisions, &label);
            assert_eq!(wall.qor.overall(), r.pipeline.qor.overall(), "{label}");

            // Every transmitted frame physically crossed the socket and
            // came back acked, and each ack yielded one measured sample
            // (recorded in the stats even though feeding is off).
            assert_eq!(r.socket.frames_sent, wall.transmitted, "{label}");
            assert_eq!(r.socket.acks_received, wall.transmitted, "{label}");
            assert_eq!(r.socket.net_samples_fed, 0, "{label}: feed is off");
            assert!(r.socket.bytes_sent > 0, "{label}");
            if wall.transmitted > 0 {
                assert!(
                    r.socket.transfer_ms_mean >= 0.0 && r.socket.transfer_ms_max >= 0.0,
                    "{label}: transfer summary"
                );
            }
        }
    }
}

#[test]
fn reactor_builder_leaf_matches_free_function() {
    let videos = cameras(2, 24, 0.3, 0x71);
    let model = model_for(&videos);
    let cfg = rt_cfg(0x71, Policy::UtilityControlLoop);
    let opts = ReactorOpts::default()
        .transport(SocketKind::Unix)
        .feed_network(false);

    let direct = run_reactor(&videos, &model, &cfg, &opts).expect("free function");
    let built = Pipeline::builder()
        .query(cfg.query.clone())
        .seed(cfg.seed)
        .realtime(uals::pipeline::RealtimeOpts::fast_forward(1e-3))
        .reactor(opts)
        .run(&videos, &model)
        .expect("builder leaf");

    assert_eq!(direct.pipeline.transmitted, built.pipeline.transmitted);
    assert_eq!(direct.pipeline.shed, built.pipeline.shed);
    assert_decisions_equal(&direct.pipeline.decisions, &built.pipeline.decisions, "builder");
}

#[test]
fn reactor_feeds_measured_transfers_to_the_control_loop() {
    let videos = cameras(2, 30, 0.35, 0x65);
    let model = model_for(&videos);
    let cfg = rt_cfg(0x65, Policy::UtilityControlLoop);

    // Delta encoding on a Unix socket, measured feeding ON: every ack
    // becomes an observe_network sample.
    let opts = ReactorOpts::default()
        .transport(SocketKind::Unix)
        .encoding(WireEncoding::delta_default())
        .workers(3);
    let r = run_reactor(&videos, &model, &cfg, &opts).expect("reactor driver");

    assert!(r.pipeline.transmitted > 0, "stream must transmit something");
    assert_eq!(
        r.socket.net_samples_fed, r.pipeline.transmitted,
        "every completed frame feeds one measured sample"
    );
    assert_eq!(r.socket.frames_sent, r.pipeline.transmitted);
    // Delta mode emitted keyframes first, then deltas.
    let keys = r.socket.wire_modes[2];
    let deltas = r.socket.wire_modes[3];
    assert!(keys >= 2, "one keyframe per camera, got {keys}");
    assert!(keys + deltas > 0);
    // Conservation is untouched by feeding.
    assert_eq!(
        r.pipeline.ingress,
        r.pipeline.transmitted + r.pipeline.shed
    );
}

#[test]
fn reactor_survives_randomized_fault_storm_with_conservation() {
    // Fault composition smoke: a randomized storm (camera dropout /
    // freeze, worker crash, slowdown, poisoned observations) over the
    // reactor's real sockets still completes and conserves frames.
    let videos = cameras(2, 60, 0.35, 0x8F);
    let model = model_for(&videos);
    let mut cfg = rt_cfg(0x8F, Policy::UtilityControlLoop);
    cfg.faults = FaultPlan::randomized(7, 4_000.0, 2);
    assert!(!cfg.faults.is_empty());

    let opts = ReactorOpts::default().transport(SocketKind::Tcp);
    let r = run_reactor(&videos, &model, &cfg, &opts).expect("reactor under faults");
    let p = &r.pipeline;
    assert_eq!(
        p.ingress,
        p.transmitted + p.shed + p.link_dropped + p.faults.fault_dropped,
        "conservation ledger under faults"
    );
    assert!(p.end_ms.is_finite() && p.end_ms > 0.0);
    let q = p.qor.overall();
    assert!((0.0..=1.0).contains(&q), "QoR {q}");
    // Frames that reached dispatch crossed the socket and were acked.
    assert_eq!(r.socket.acks_received, r.socket.frames_sent);
}

#[test]
fn reactor_rejects_modeled_link_contention() {
    // The reactor replaces the modeled link with real sockets; asking
    // for both at once is a config error, not silent double-counting.
    let videos = cameras(1, 8, 0.3, 0x99);
    let model = model_for(&videos);
    let mut cfg = rt_cfg(0x99, Policy::UtilityControlLoop);
    cfg.transport =
        uals::pipeline::TransportConfig::constrained(8.0, WireEncoding::Raw);

    let err = run_reactor(&videos, &model, &cfg, &ReactorOpts::default())
        .expect_err("non-ideal link must be rejected");
    assert!(
        err.to_string().contains("ideal"),
        "error should name the ideal-link requirement: {err}"
    );
}
