//! Property tests pinning the fused LUT fast path to the reference
//! oracle, and the zero-allocation APIs to their allocating twins.
//!
//! These are the equivalence guarantees the perf work rests on: if they
//! hold, switching sweeps to the fast path cannot change any QoR figure.

use uals::color::{ColorLut, HueRanges, NamedColor};
use uals::features::{
    compute_features, compute_features_fast, Extractor, FrameFeatures, UtilityValues,
};
use uals::util::prop::{Gen, Prop};
use uals::util::rng::Rng;
use uals::utility::{train, Combine};
use uals::video::{Video, VideoConfig};

/// Random hue-range set (1–2 colors): mix of named palettes and
/// arbitrary (possibly wrap-around) intervals.
fn random_ranges(g: &mut Gen) -> Vec<HueRanges> {
    let named = [
        NamedColor::Red,
        NamedColor::Yellow,
        NamedColor::Green,
        NamedColor::Blue,
    ];
    let k = 1 + g.usize_in(0..2);
    (0..k)
        .map(|_| {
            if g.bool() {
                named[g.usize_in(0..named.len())].ranges()
            } else {
                let rng = g.rng();
                let lo1 = rng.f32() * 170.0;
                let hi1 = (lo1 + rng.f32() * (180.0 - lo1)).min(180.0);
                if rng.chance(0.5) {
                    let lo2 = rng.f32() * 170.0;
                    let hi2 = (lo2 + rng.f32() * (180.0 - lo2)).min(180.0);
                    HueRanges::pair(lo1, hi1, lo2, hi2)
                } else {
                    HueRanges::single(lo1, hi1)
                }
            }
        })
        .collect()
}

fn random_int_frame(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.below(256) as f32).collect()
}

#[test]
fn fast_path_is_bit_equal_to_oracle_on_integer_frames() {
    Prop::new("lut fast path ≡ oracle (integer frames)")
        .cases(60)
        .run(|g| {
            let ranges = random_ranges(g);
            // Integer and fractional thresholds, including 0 and 255.
            let fg_threshold = match g.usize_in(0..4) {
                0 => 25.0,
                1 => g.f64_in(0.0, 80.0) as f32,
                2 => 0.0,
                _ => 255.0,
            };
            let lut = ColorLut::new(&ranges, fg_threshold);
            let side = 4 + g.usize_in(0..13); // 4..16 px square
            let n = side * side * 3;
            let rng = g.rng();
            let bg = random_int_frame(rng, n);
            // Frames correlated with the background (realistic fg sparsity)
            // and fully random ones.
            let rgb = if rng.chance(0.5) {
                let mut f = bg.clone();
                for _ in 0..rng.range(0, n / 2) {
                    let i = rng.range(0, n);
                    f[i] = rng.below(256) as f32;
                }
                f
            } else {
                random_int_frame(rng, n)
            };
            let fast = compute_features_fast(&lut, &rgb, &bg);
            let oracle = compute_features(&rgb, &bg, &ranges, fg_threshold);
            assert_eq!(fast, oracle, "case seed {}", g.case_seed);
        });
}

#[test]
fn fast_path_is_bit_equal_on_float_frames_via_fallback() {
    Prop::new("lut fast path ≡ oracle (float frames)")
        .cases(30)
        .run(|g| {
            let ranges = random_ranges(g);
            let lut = ColorLut::new(&ranges, 25.0);
            let n = 10 * 10 * 3;
            let rng = g.rng();
            let bg: Vec<f32> = (0..n).map(|_| rng.f32() * 255.0).collect();
            let rgb: Vec<f32> = bg
                .iter()
                .map(|x| (x + (rng.f32() - 0.5) * 80.0).clamp(0.0, 255.0))
                .collect();
            let fast = compute_features_fast(&lut, &rgb, &bg);
            let oracle = compute_features(&rgb, &bg, &ranges, 25.0);
            assert_eq!(fast, oracle, "case seed {}", g.case_seed);
        });
}

#[test]
fn extractor_fast_default_matches_legacy_reference_scoring() {
    // The native extractor now routes through the LUT kernel; its output
    // must equal scoring the reference features through the model — on
    // both float (synthetic-noise) and u8 (quantized camera) frames.
    for quantize in [false, true] {
        let mut vc = VideoConfig::new(5, 42, 0, 40);
        vc.traffic.vehicle_rate = 0.7;
        vc.quantize_u8 = quantize;
        let video = Video::new(vc);
        let videos = vec![video];
        let model = train(&videos, &[0], &[NamedColor::Red], Combine::Single);
        let ranges = model.ranges();
        let ex = Extractor::native(model.clone());
        let v = &videos[0];
        for t in (0..v.len()).step_by(5) {
            let f = v.render(t);
            let (feats, utils) = ex.extract(&f.rgb, v.background()).unwrap();
            let oracle =
                compute_features(&f.rgb, v.background(), &ranges, model.fg_threshold);
            assert_eq!(feats, oracle, "quantize={quantize} t={t}");
            let u = model.utility(&oracle);
            assert_eq!(utils, u, "quantize={quantize} t={t}");
        }
    }
}

#[test]
fn extract_into_agrees_with_extract_across_frames() {
    let mut vc = VideoConfig::new(6, 43, 0, 30);
    vc.traffic.vehicle_rate = 0.6;
    vc.quantize_u8 = true;
    let video = Video::new(vc);
    let videos = vec![video];
    let model = train(
        &videos,
        &[0],
        &[NamedColor::Red, NamedColor::Yellow],
        Combine::Or,
    );
    let ex = Extractor::native(model);
    let v = &videos[0];
    let mut feats = FrameFeatures::empty();
    let mut utils = UtilityValues::empty();
    let mut arena = uals::video::Frame::empty();
    for t in 0..v.len() {
        v.render_into(t, &mut arena);
        let (f1, u1) = ex.extract(&arena.rgb, v.background()).unwrap();
        ex.extract_into(&arena.rgb, v.background(), &mut feats, &mut utils)
            .unwrap();
        assert_eq!(feats, f1, "t={t}");
        assert_eq!(utils, u1, "t={t}");
    }
}
