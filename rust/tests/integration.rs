//! Integration: runtime loads and executes real AOT artifacts.
//! Gated on artifact + PJRT availability so `cargo test` stays green in
//! checkouts that haven't run `make artifacts` (or that link the offline
//! xla stub).

use uals::runtime::{Engine, Tensor};

#[test]
fn shedder_k1_runs_on_zero_frame() {
    let engine = match Engine::from_default_artifacts() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping shedder_k1_runs_on_zero_frame: {e}");
            return;
        }
    };
    let exe = engine.load("shedder_k1").unwrap();
    let m = engine.manifest();
    let frame = Tensor::zeros(&[m.frame_h, m.frame_w, 3]);
    let bg = Tensor::zeros(&[m.frame_h, m.frame_w, 3]);
    let ranges = Tensor::new(vec![0.0, 10.0, 170.0, 180.0], vec![1, 4]).unwrap();
    let mm = Tensor::zeros(&[1, 8, 8]);
    let out = exe.run(&[&frame, &bg, &ranges, &mm]).unwrap();
    assert_eq!(out.len(), 4);
    assert_eq!(out[0].shape(), &[1]); // utility
    assert_eq!(out[1].shape(), &[1]); // hf
    assert_eq!(out[2].shape(), &[1, 8, 8]); // pf
    assert_eq!(out[0].data()[0], 0.0); // all-background frame: zero utility
    assert_eq!(out[1].data()[0], 0.0);
}

#[test]
fn artifacts_available_reports_consistently() {
    // The gate used across the test suite must agree with building an
    // engine directly.
    assert_eq!(
        uals::runtime::artifacts_available(),
        Engine::from_default_artifacts().is_ok()
    );
}
