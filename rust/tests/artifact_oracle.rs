//! The cross-language numeric contract: the AOT artifacts (JAX + Pallas →
//! HLO text → PJRT) must agree with the pure-Rust oracle on real frames.
//!
//! This is the test that pins the entire three-layer stack together; if it
//! passes, shedding decisions are identical no matter which backend runs.

use uals::color::NamedColor;
use uals::features::{Extractor, HIST};
use uals::runtime::Engine;
use uals::utility::{train, Combine};
use uals::video::{Video, VideoConfig};

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Artifact availability gate: these tests pin the artifact path against
/// the native oracle, which is only possible when `make artifacts` has
/// run and a real PJRT runtime is linked. Absent that, skip (the native
/// oracle itself is covered by the unit + fast-path property tests).
fn test_engine(name: &str) -> Option<Engine> {
    match Engine::from_default_artifacts() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping {name}: artifacts/PJRT unavailable ({e})");
            None
        }
    }
}

fn make_video(seed: u64) -> Video {
    let mut cfg = VideoConfig::new(7, seed, 0, 60);
    cfg.width = 96; // matches artifacts' FRAME_H/W
    cfg.height = 96;
    cfg.traffic.vehicle_rate = 0.8;
    // Ensure targets + confounders actually appear in a 60-frame clip.
    cfg.traffic.paint_weights = vec![
        (uals::video::Paint::VividRed, 0.3),
        (uals::video::Paint::VividYellow, 0.15),
        (uals::video::Paint::DullRed, 0.15),
        (uals::video::Paint::Gray, 0.25),
        (uals::video::Paint::Silver, 0.15),
    ];
    Video::new(cfg)
}

#[test]
fn artifact_matches_native_oracle_single_color() {
    let Some(engine) = test_engine("artifact_matches_native_oracle_single_color") else {
        return;
    };
    let videos = vec![make_video(21), make_video(22)];
    let model = train(&videos, &[0], &[NamedColor::Red], Combine::Single);

    let native = Extractor::native(model.clone());
    let artifact = Extractor::artifact(&engine, model).unwrap();

    let v = &videos[1];
    let mut checked = 0;
    for t in (0..v.len()).step_by(7) {
        let f = v.render(t);
        let (nf, nu) = native.extract(&f.rgb, v.background()).unwrap();
        let (af, au) = artifact.extract(&f.rgb, v.background()).unwrap();
        assert!(
            close(nu.combined, au.combined, 1e-4),
            "t={t}: native u {} vs artifact u {}",
            nu.combined,
            au.combined
        );
        assert!(close(nf.hf[0], af.hf[0], 1e-5), "hf mismatch at t={t}");
        assert!(close(nf.fg_frac, af.fg_frac, 1e-5), "fg mismatch at t={t}");
        for b in 0..HIST {
            assert!(
                close(nf.pf[0][b], af.pf[0][b], 1e-4),
                "pf[{b}] mismatch at t={t}: {} vs {}",
                nf.pf[0][b],
                af.pf[0][b]
            );
        }
        checked += 1;
    }
    assert!(checked >= 8);
}

#[test]
fn artifact_matches_native_oracle_composite_or_and() {
    let Some(engine) = test_engine("artifact_matches_native_oracle_composite_or_and") else {
        return;
    };
    let videos = vec![make_video(31), make_video(32)];
    for combine in [Combine::Or, Combine::And] {
        let model = train(
            &videos,
            &[0],
            &[NamedColor::Red, NamedColor::Yellow],
            combine,
        );
        let native = Extractor::native(model.clone());
        let artifact = Extractor::artifact(&engine, model).unwrap();
        let v = &videos[1];
        for t in (0..v.len()).step_by(11) {
            let f = v.render(t);
            let (nf, nu) = native.extract(&f.rgb, v.background()).unwrap();
            let (af, au) = artifact.extract(&f.rgb, v.background()).unwrap();
            assert!(
                close(nu.combined, au.combined, 1e-4),
                "{combine:?} t={t}: {} vs {}",
                nu.combined,
                au.combined
            );
            for c in 0..2 {
                assert!(close(nu.per_color[c], au.per_color[c], 1e-4));
                assert!(close(nf.hf[c], af.hf[c], 1e-5));
            }
        }
    }
}

#[test]
fn detector_artifact_fires_on_targets() {
    use uals::runtime::Tensor;
    let Some(engine) = test_engine("detector_artifact_fires_on_targets") else {
        return;
    };
    let exe = engine.load("detector").unwrap();
    let m = engine.manifest();

    let v = make_video(41);
    // Find a frame with a large red target and check the detector fires.
    let mut fired_on_target = false;
    for t in 0..v.len() {
        let f = v.render(t);
        let has_red = f
            .truth
            .iter()
            .any(|o| o.paint == uals::video::Paint::VividRed && o.visible_px > 80);
        if !has_red {
            continue;
        }
        let rgb = Tensor::new(f.rgb.clone(), vec![m.frame_h, m.frame_w, 3]).unwrap();
        let bg = Tensor::new(v.background().to_vec(), vec![m.frame_h, m.frame_w, 3]).unwrap();
        let ranges = Tensor::new(
            vec![0.0, 10.0, 170.0, 180.0, 20.0, 35.0, 0.0, 0.0],
            vec![2, 4],
        )
        .unwrap();
        let outs = exe.run(&[&rgb, &bg, &ranges]).unwrap();
        let counts = &outs[1];
        if counts.data()[0] > 0.0 {
            fired_on_target = true;
            break;
        }
    }
    assert!(fired_on_target, "detector never fired on a large red target");
}
