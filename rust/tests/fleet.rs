//! Fleet correctness properties:
//!
//! 1. **Tier equivalence** — a 1-node pass-through fleet over ideal
//!    links is *exactly* `run_multi_sim`: per-query decision logs, QoR,
//!    per-object recall, control series and latency bit-match, so the
//!    fleet wrapper provably adds nothing to the single-site semantics.
//! 2. **Deterministic replay** — the fleet decision log is identical
//!    across repeat runs and across tier-1 thread counts, including
//!    under a lossy hop-B link and the deadline-capacity aggregator.
//! 3. **Cross-tier conservation** — under randomized fault storms on
//!    every edge node, each query's ledger still balances exactly:
//!    ingress = completed + edge shed + aggregator shed + hop-A losses
//!    + hop-B losses + fault-destroyed.

use uals::experiments::scenarios::multiquery_pool;
use uals::pipeline::{
    run_fleet, run_multi_sim, AggregatorPolicy, FaultPlan, FleetConfig, FleetTopology,
    LinkModel, MultiSimConfig, Pipeline, PipelineConfig, TransportConfig,
};
use uals::features::Extractor;
use uals::shedder::{ArbiterPolicy, QuerySet};
use uals::video::{
    streamer::aggregate_fps, Streamer, Video, VideoConfig, WireEncoding,
};

fn cameras(n: usize, frames: usize, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0xF1E ^ seed, seed * 41 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = 0.4;
            Video::new(vc)
        })
        .collect()
}

fn trained_set(videos: &[Video], k: usize) -> QuerySet {
    let specs = multiquery_pool()[..k].to_vec();
    let idx: Vec<usize> = (0..videos.len()).collect();
    QuerySet::train(&specs, videos, &idx).unwrap()
}

#[test]
fn one_node_pass_through_fleet_is_exactly_run_multi_sim() {
    for content_seed in [0x21u64, 0x5A] {
        let videos = cameras(3, 100, content_seed);
        let set = trained_set(&videos, 3);
        let seed = 0xF1EE7;

        // The reference: the plain multi-query engine, default
        // (jittered) costs, ideal transport — the historical deployment.
        let tier = PipelineConfig {
            seed,
            fps_total: aggregate_fps(&videos),
            ..PipelineConfig::default()
        };
        let mcfg = MultiSimConfig::from_pipeline(
            &tier,
            ArbiterPolicy::WeightedFair { work_conserving: true },
        );
        let extractor = Extractor::native(set.union_model().clone());
        let mut backends = uals::pipeline::multi_backends(&set, &mcfg.costs, mcfg.seed);
        let reference = run_multi_sim(
            Streamer::new(&videos),
            &uals::pipeline::backgrounds_of(&videos),
            &set,
            &mcfg,
            &extractor,
            &mut backends,
        )
        .unwrap();

        // The fleet: one edge node, pass-through aggregator, both hops
        // ideal. Node 0 keeps the base seed, so the engines must agree
        // bit for bit.
        let fleet = Pipeline::builder()
            .seed(seed)
            .fleet(FleetTopology {
                edge_nodes: 1,
                workers: 1,
                threads: 1,
                aggregator: AggregatorPolicy::PassThrough,
            })
            .run(&videos, &set)
            .unwrap();

        assert!(fleet.conserves(), "seed {content_seed:x}: conservation");
        assert_eq!(fleet.frames, reference.frames);
        assert_eq!(fleet.extractions, reference.extractions);
        assert_eq!(fleet.uplink_bytes, reference.bytes_on_wire);
        for (q, (fq, rq)) in fleet.queries.iter().zip(&reference.queries).enumerate() {
            let label = format!("seed {content_seed:x} query {q} ({})", fq.name);
            assert_eq!(fq.name, rq.name, "{label}: name");
            assert_eq!(fq.report.ingress, rq.report.ingress, "{label}: ingress");
            assert_eq!(fq.report.transmitted, rq.report.transmitted, "{label}: transmitted");
            assert_eq!(fq.report.shed, rq.report.shed, "{label}: shed");
            assert_eq!(fq.completed, rq.report.transmitted, "{label}: completed");
            assert_eq!(fq.agg_shed, 0, "{label}: pass-through never sheds");
            assert_eq!(fq.agg_link_dropped, 0, "{label}: ideal hop B never drops");
            assert_eq!(
                fq.report.decisions.len(),
                rq.report.decisions.len(),
                "{label}: decision counts"
            );
            for (i, (a, b)) in fq.report.decisions.iter().zip(&rq.report.decisions).enumerate()
            {
                assert_eq!(a, b, "{label}: decision {i} diverges");
            }
            assert_eq!(fq.report.qor.overall(), rq.report.qor.overall(), "{label}: QoR");
            assert_eq!(
                fq.report.qor.per_object_all(),
                rq.report.qor.per_object_all(),
                "{label}: per-object QoR"
            );
            assert_eq!(
                fq.report.control_series, rq.report.control_series,
                "{label}: control series"
            );
            assert_eq!(
                fq.report.latency.count(),
                rq.report.latency.count(),
                "{label}: completions"
            );
            assert_eq!(
                fq.report.latency.max_ms(),
                rq.report.latency.max_ms(),
                "{label}: max e2e"
            );
        }
    }
}

#[test]
fn fleet_decision_log_is_thread_and_replay_invariant() {
    let videos = cameras(6, 80, 0x7C);
    let set = trained_set(&videos, 2);
    let mk = |threads| {
        let tier = PipelineConfig { seed: 0xACE, ..PipelineConfig::default() };
        let mut cfg = FleetConfig::uniform(
            tier,
            FleetTopology {
                edge_nodes: 3,
                workers: 2,
                threads,
                aggregator: AggregatorPolicy::DeadlineCapacity,
            },
        );
        // Thin lossy hop B: losses and deadline sheds must replay
        // identically too.
        cfg.aggregator.transport = TransportConfig {
            link: LinkModel { loss: 0.08, max_retransmits: 0, ..LinkModel::mbps(4.0) },
            encoding: WireEncoding::Raw,
        };
        cfg
    };
    let serial = run_fleet(&videos, &set, &mk(1)).unwrap();
    let threaded = run_fleet(&videos, &set, &mk(4)).unwrap();
    let replay = run_fleet(&videos, &set, &mk(4)).unwrap();

    assert!(serial.conserves());
    assert_eq!(serial.decisions, threaded.decisions, "thread-count invariance");
    assert_eq!(threaded.decisions, replay.decisions, "replay determinism");
    assert_eq!(serial.worker_frames, threaded.worker_frames);
    assert_eq!(serial.cluster_bytes, threaded.cluster_bytes);
    for (a, b) in serial.queries.iter().zip(&threaded.queries) {
        assert_eq!(a.completed, b.completed, "{}", a.name);
        assert_eq!(a.agg_shed, b.agg_shed, "{}", a.name);
        assert_eq!(a.agg_link_dropped, b.agg_link_dropped, "{}", a.name);
        assert_eq!(a.report.qor.overall(), b.report.qor.overall(), "{}", a.name);
    }
}

#[test]
fn conservation_holds_under_randomized_fault_storms() {
    // Chaos property: seeded random fault storms on every edge node,
    // a modeled lossy uplink AND a lossy hop-B link — the per-query
    // ledger must balance exactly in every draw.
    let videos = cameras(6, 60, 0x99);
    let set = trained_set(&videos, 2);
    let horizon = 60.0 / 10.0 * 1e3; // frames / native fps → ms
    for storm_seed in 0..8u64 {
        let mut tier = PipelineConfig { seed: 0xC0 + storm_seed, ..PipelineConfig::default() };
        tier.transport = TransportConfig {
            link: LinkModel { loss: 0.03, max_retransmits: 0, ..LinkModel::mbps(8.0) },
            encoding: WireEncoding::Raw,
        };
        tier.faults = FaultPlan::randomized(storm_seed, horizon, videos.len() as u32);
        let mut cfg = FleetConfig::uniform(
            tier,
            FleetTopology {
                edge_nodes: 2,
                workers: 2,
                threads: 2,
                aggregator: AggregatorPolicy::DeadlineCapacity,
            },
        );
        cfg.aggregator.transport = TransportConfig {
            link: LinkModel { loss: 0.05, max_retransmits: 0, ..LinkModel::mbps(4.0) },
            encoding: WireEncoding::Raw,
        };
        let r = run_fleet(&videos, &set, &cfg).unwrap();
        for q in &r.queries {
            let rep = &q.report;
            assert!(
                q.conserves(),
                "storm {storm_seed}: query {} ledger: ingress {} vs completed {} + shed {} \
                 + agg_shed {} + linkA {} + linkB {} + faults {}",
                q.name,
                rep.ingress,
                q.completed,
                rep.shed,
                q.agg_shed,
                rep.link_dropped,
                q.agg_link_dropped,
                rep.faults.fault_dropped
            );
        }
        // The tier-2 log covers exactly the edge egress stream.
        let egress: u64 = r.queries.iter().map(|q| q.report.transmitted).sum();
        assert_eq!(r.decisions.len() as u64, egress, "storm {storm_seed}: log coverage");
    }
}
