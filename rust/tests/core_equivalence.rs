//! Clock-equivalence property of the streaming core: the discrete-event
//! driver (`SimClock`) and the threaded wall-clock driver (`WallClock`,
//! fast-forwarded, cost emulation off, native oracle) must produce
//! **identical per-frame shed/transmit decisions** on the same seed and
//! stream — decisions depend only on the virtual-time event order, which
//! the clock abstraction keeps invariant across drivers.

use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::realtime::{run_realtime, run_realtime_with, RealtimeConfig};
use uals::pipeline::{
    backgrounds_of, run_sim, run_sim_with, FrameDecision, PoissonArrivals, Policy, SimConfig,
    SimReport,
};
use uals::utility::{train, Combine, UtilityModel};
use uals::video::{streamer::aggregate_fps, Streamer, Video, VideoConfig};

fn cameras(n: usize, frames: usize, vehicle_rate: f64, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0xE01 ^ seed, seed * 31 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = vehicle_rate;
            Video::new(vc)
        })
        .collect()
}

fn model_for(videos: &[Video]) -> UtilityModel {
    let idx: Vec<usize> = (0..videos.len()).collect();
    train(videos, &idx, &[NamedColor::Red], Combine::Single)
}

fn sim_cfg(fps: f64, seed: u64) -> SimConfig {
    SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1200.0),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed,
        fps_total: fps,
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    }
}

fn rt_cfg(cfg: &SimConfig) -> RealtimeConfig {
    RealtimeConfig {
        query: cfg.query.clone(),
        shedder: cfg.shedder.clone(),
        costs: cfg.costs.clone(),
        cost_emulation_scale: 0.0, // pure compute speed
        time_scale: 1e-3,          // 1000× fast-forward
        backend_tokens: cfg.backend_tokens,
        use_artifacts: false, // native oracle
        policy: cfg.policy.clone(),
        seed: cfg.seed,
        arbiter: uals::shedder::ArbiterPolicy::Standalone,
        transport: cfg.transport,
        ..Default::default()
    }
}

fn run_sim_driver(videos: &[Video], cfg: &SimConfig, model: &UtilityModel) -> SimReport {
    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    run_sim(
        Streamer::new(videos),
        &backgrounds_of(videos),
        cfg,
        &extractor,
        &mut backend,
    )
    .expect("sim driver")
}

fn assert_decisions_equal(sim: &[FrameDecision], wall: &[FrameDecision], label: &str) {
    assert_eq!(sim.len(), wall.len(), "{label}: decision counts differ");
    for (i, (a, b)) in sim.iter().zip(wall).enumerate() {
        assert_eq!(a, b, "{label}: decision {i} diverges");
    }
}

#[test]
fn sim_and_wallclock_drivers_make_identical_decisions() {
    // Property over several (seed, load) points: light, moderate and
    // overloaded traffic must all agree frame-for-frame.
    for (seed, rate) in [(0x51u64, 0.1), (0x52, 0.35), (0x53, 0.6)] {
        let videos = cameras(2, 100, rate, seed);
        let model = model_for(&videos);
        let cfg = sim_cfg(aggregate_fps(&videos), seed);

        let sim = run_sim_driver(&videos, &cfg, &model);
        let wall = run_realtime(&videos, &model, &rt_cfg(&cfg)).expect("wall driver");

        assert_eq!(sim.ingress, 200, "seed {seed:x}");
        assert_eq!(sim.ingress, wall.ingress, "seed {seed:x}");
        assert_eq!(sim.transmitted, wall.transmitted, "seed {seed:x}");
        assert_eq!(sim.shed, wall.shed, "seed {seed:x}");
        assert_decisions_equal(&sim.decisions, &wall.decisions, "uniform stream");
        // Same decision sequence ⇒ bit-identical QoR.
        assert_eq!(sim.qor.overall(), wall.qor.overall(), "seed {seed:x}");
    }
}

#[test]
fn churn_workload_is_clock_invariant_too() {
    use uals::pipeline::CameraChurn;
    let videos = cameras(3, 60, 0.4, 0x88);
    let model = model_for(&videos);
    let cfg = sim_cfg(aggregate_fps(&videos), 0x88);

    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    let sim = run_sim_with(
        CameraChurn::staggered(&videos, 2_000.0, 4_000.0),
        &backgrounds_of(&videos),
        &cfg,
        &extractor,
        &mut backend,
    )
    .expect("sim driver");
    let wall = run_realtime_with(
        &videos,
        &model,
        &rt_cfg(&cfg),
        CameraChurn::staggered(&videos, 2_000.0, 4_000.0),
    )
    .expect("wall driver");

    // 4 s up at 10 fps → 40 frames per camera.
    assert_eq!(sim.ingress, 120);
    assert_eq!(sim.ingress, sim.transmitted + sim.shed);
    assert_eq!(sim.transmitted, wall.transmitted);
    assert_eq!(sim.shed, wall.shed);
    assert_decisions_equal(&sim.decisions, &wall.decisions, "churn stream");
    assert_eq!(sim.qor.overall(), wall.qor.overall());
}

#[test]
fn bursty_workload_is_clock_invariant_too() {
    // The ArrivalModel plugins must behave identically under both clocks:
    // two independently-constructed Poisson processes with the same seed
    // drive the two drivers.
    let videos = cameras(2, 80, 0.4, 0x77);
    let model = model_for(&videos);
    let cfg = sim_cfg(aggregate_fps(&videos), 0x77);

    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    let sim = run_sim_with(
        PoissonArrivals::new(&videos, cfg.seed, 1.0),
        &backgrounds_of(&videos),
        &cfg,
        &extractor,
        &mut backend,
    )
    .expect("sim driver");
    let wall = run_realtime_with(
        &videos,
        &model,
        &rt_cfg(&cfg),
        PoissonArrivals::new(&videos, cfg.seed, 1.0),
    )
    .expect("wall driver");

    assert_eq!(sim.ingress, 160);
    assert_eq!(sim.ingress, sim.transmitted + sim.shed);
    assert_eq!(sim.transmitted, wall.transmitted);
    assert_eq!(sim.shed, wall.shed);
    assert_decisions_equal(&sim.decisions, &wall.decisions, "poisson stream");
    assert_eq!(sim.qor.overall(), wall.qor.overall());
}
