//! Fault-injection + graceful-degradation properties:
//!
//! 1. **Off-state bit-identity** — a plan whose windows never cover the
//!    run (and the empty plan it equals) changes nothing: decision logs,
//!    control series, QoR and byte counts are bit-identical across seeds
//!    and policies, and a real fault storm is *clock-invariant* (sim vs
//!    wall drivers shed/transmit exactly the same frames).
//! 2. **Extended conservation** — every fault mode keeps
//!    `ingress == transmitted + shed + link_dropped + fault_dropped`
//!    exact, with one terminal decision per ingress frame, in both the
//!    single- and multi-query engines.
//! 3. **Graceful degradation** — a crashed backend worker trips the
//!    completion watchdog into a *declared* degraded window and the
//!    pipeline recovers after the fault clears; camera dropout
//!    re-normalizes the nominal fps via the liveness check; poisoned
//!    control observations are rejected, never applied.
//! 4. **Chaos property** — ≥20 seeded random fault storms: no deadlock,
//!    exact conservation, and every latency-bound violation is
//!    attributable to the declared fault/degraded windows (or already
//!    present in the no-fault baseline).
//! 5. **Supervision** — a panicking backend worker surfaces its real
//!    cause as an `Err` out of `run_pipeline`, not a hang or an opaque
//!    unwrap panic.

use anyhow::Result;
use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::metrics::Stage;
use uals::pipeline::realtime::{run_realtime, RealtimeConfig};
use uals::pipeline::{
    backgrounds_of, multi_backends, run_multi_sim, run_pipeline, run_sim, BackendExecutor,
    FaultKind, FaultPlan, FaultStats, FramePayload, IterArrivals, MultiSimConfig, Policy,
    PoisonKind, RunnerFactory, SimClock, SimConfig, SimReport, SupervisedWorker,
    SupervisorConfig, TransportConfig,
};
use uals::shedder::{ArbiterPolicy, QuerySet, QuerySpec};
use uals::utility::{train, Combine, UtilityModel};
use uals::video::{streamer::aggregate_fps, Streamer, Video, VideoConfig};

fn cameras(n: usize, frames: usize, vehicle_rate: f64, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0xFA0 ^ seed, seed * 41 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = vehicle_rate;
            Video::new(vc)
        })
        .collect()
}

fn model_for(videos: &[Video]) -> UtilityModel {
    let idx: Vec<usize> = (0..videos.len()).collect();
    train(videos, &idx, &[NamedColor::Red], Combine::Single)
}

fn sim_cfg(fps: f64, seed: u64, policy: Policy) -> SimConfig {
    SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1200.0),
        backend_tokens: 1,
        policy,
        seed,
        fps_total: fps,
        transport: TransportConfig::default(),
        faults: FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    }
}

fn run_driver(videos: &[Video], cfg: &SimConfig, model: &UtilityModel) -> SimReport {
    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    run_sim(
        Streamer::new(videos),
        &backgrounds_of(videos),
        cfg,
        &extractor,
        &mut backend,
    )
    .expect("sim driver")
}

/// The extended conservation invariant: every ingress frame terminates in
/// exactly one of {transmitted, shed, link_dropped, fault_dropped}, and
/// the decision log records it.
fn assert_conserved(r: &SimReport) {
    assert_eq!(
        r.ingress,
        r.transmitted + r.shed + r.link_dropped + r.faults.fault_dropped,
        "conservation: {} != {} + {} + {} + {}",
        r.ingress,
        r.transmitted,
        r.shed,
        r.link_dropped,
        r.faults.fault_dropped
    );
    assert_eq!(r.decisions.len() as u64, r.ingress, "one decision per ingress frame");
    let kept = r.decisions.iter().filter(|d| d.kept).count() as u64;
    assert_eq!(kept, r.transmitted, "kept decisions == transmitted");
}

/// Starts (ms) of the 5 s windows whose max E2E latency violates `bound`.
fn violating_windows(r: &SimReport, bound: f64) -> Vec<f64> {
    r.latency_windows
        .rows()
        .iter()
        .filter(|&&(_, max, _, n)| n > 0 && max > bound)
        .map(|&(w, ..)| w)
        .collect()
}

// ---------------------------------------------------------------------------
// 1. Off-state bit-identity
// ---------------------------------------------------------------------------

#[test]
fn far_future_fault_windows_are_bit_identical_to_the_empty_plan() {
    for (seed, policy) in [
        (0xA1u64, Policy::UtilityControlLoop),
        (0xA2, Policy::FifoControlLoop),
        (0xA3, Policy::RandomRate { assumed_proc_q_ms: 120.0 }),
    ] {
        let videos = cameras(2, 90, 0.4, seed);
        let model = model_for(&videos);
        let base = sim_cfg(aggregate_fps(&videos), seed, policy);
        let baseline = run_driver(&videos, &base, &model);
        assert!(base.faults.is_empty());
        assert_eq!(baseline.faults, FaultStats::default());

        // Every fault kind armed — but a billion virtual seconds away.
        // None of the windows cover the run, so the armed plan must be
        // bit-identical to the empty one (the freeze retention buffer and
        // every per-event fault query engage without perturbing anything).
        let far = 1.0e9;
        let mut armed = base.clone();
        armed.faults = FaultPlan::new()
            .with(far, far + 1e6, FaultKind::CameraDrop { camera: 0 })
            .with(far, far + 1e6, FaultKind::CameraFreeze { camera: 1 })
            .with(far, far + 1e6, FaultKind::LinkBlackout)
            .with(far, far + 1e6, FaultKind::BandwidthCollapse { mbps: 0.5 })
            .with(far, far + 1e6, FaultKind::WorkerCrash)
            .with(far, far + 1e6, FaultKind::BackendSlowdown { factor: 8.0 })
            .with(far, far + 1e6, FaultKind::PoisonControl { kind: PoisonKind::Nan });
        let r = run_driver(&videos, &armed, &model);
        assert_eq!(baseline.decisions, r.decisions, "seed {seed:x}: decisions diverge");
        assert_eq!(baseline.control_series, r.control_series, "seed {seed:x}");
        assert_eq!(baseline.qor.overall(), r.qor.overall());
        assert_eq!(baseline.bytes_on_wire, r.bytes_on_wire);
        assert_eq!(baseline.transmitted, r.transmitted);
        assert_eq!(r.faults, FaultStats::default());
        assert_conserved(&r);
    }
}

#[test]
fn fault_storms_are_clock_invariant() {
    // The whole point of time-keyed fault windows: the storm fires
    // identically under the discrete-event and the wall-clock drivers.
    let videos = cameras(2, 100, 0.4, 0xB3);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0xB3, Policy::UtilityControlLoop);
    cfg.shedder.watchdog_ms = 1_500.0;
    cfg.shedder.camera_liveness_ms = 2_000.0;
    cfg.faults = FaultPlan::new()
        .with(2_000.0, 4_000.0, FaultKind::CameraDrop { camera: 0 })
        .with(3_000.0, 5_000.0, FaultKind::PoisonControl { kind: PoisonKind::Stale })
        .with(6_000.0, 8_000.0, FaultKind::WorkerCrash)
        .with(8_500.0, 9_500.0, FaultKind::LinkBlackout);

    let sim = run_driver(&videos, &cfg, &model);
    assert!(sim.faults.fault_dropped > 0, "the storm must bite");

    let rt = RealtimeConfig {
        query: cfg.query.clone(),
        shedder: cfg.shedder.clone(),
        costs: cfg.costs.clone(),
        cost_emulation_scale: 0.0,
        time_scale: 1e-3,
        backend_tokens: cfg.backend_tokens,
        use_artifacts: false,
        policy: cfg.policy.clone(),
        seed: cfg.seed,
        arbiter: ArbiterPolicy::Standalone,
        transport: cfg.transport,
        faults: cfg.faults.clone(),
        ..Default::default()
    };
    let wall = run_realtime(&videos, &model, &rt).expect("wall driver");
    assert_eq!(sim.decisions, wall.decisions, "storm must be clock-invariant");
    assert_eq!(sim.faults, wall.faults, "fault accounting must be clock-invariant");
    assert_eq!(sim.transmitted, wall.transmitted);
    assert_eq!(sim.bytes_on_wire, wall.bytes_on_wire);
    assert_eq!(wall.worker_restarts, 0, "modeled crash, real worker untouched");
}

// ---------------------------------------------------------------------------
// 2 + 3. Per-fault accounting and degradation
// ---------------------------------------------------------------------------

#[test]
fn camera_dropout_is_fault_accounted_and_renormalizes_liveness() {
    let videos = cameras(2, 150, 0.4, 0xC4);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0xC4, Policy::UtilityControlLoop);
    cfg.shedder.camera_liveness_ms = 2_000.0;
    cfg.faults = FaultPlan::new().with(3_000.0, 9_000.0, FaultKind::CameraDrop { camera: 0 });
    let r = run_driver(&videos, &cfg, &model);
    assert_conserved(&r);
    assert_eq!(r.ingress, 300, "dropped frames still count as ingress");
    // ~60 camera-0 frames (10 fps × 6 s) fall inside the dropout window.
    assert!(
        (55..=65u64).contains(&r.faults.fault_dropped),
        "fault_dropped {}",
        r.faults.fault_dropped
    );
    // The liveness check re-normalized the nominal fps down when the
    // camera vanished and back up when it returned.
    assert!(r.faults.liveness_renorms >= 2, "renorms {}", r.faults.liveness_renorms);
    assert!(r.faults.degraded_windows.is_empty(), "no completion stall here");
}

#[test]
fn camera_freeze_keeps_the_stream_alive_with_stale_pixels() {
    let videos = cameras(2, 150, 0.4, 0xC5);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0xC5, Policy::UtilityControlLoop);
    cfg.faults = FaultPlan::new().with(3_000.0, 8_000.0, FaultKind::CameraFreeze { camera: 0 });
    let r = run_driver(&videos, &cfg, &model);
    // A frozen camera destroys nothing — stale pixels, live ground truth.
    assert_conserved(&r);
    assert_eq!(r.faults.fault_dropped, 0);
    assert_eq!(r.ingress, 300);
}

#[test]
fn link_blackout_destroys_dispatched_frames_without_burning_tokens() {
    let videos = cameras(2, 150, 0.4, 0xC6);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0xC6, Policy::UtilityControlLoop);
    cfg.faults = FaultPlan::new().with(4_000.0, 7_000.0, FaultKind::LinkBlackout);
    let r = run_driver(&videos, &cfg, &model);
    assert_conserved(&r);
    assert!(r.faults.fault_dropped > 10, "fault_dropped {}", r.faults.fault_dropped);
    assert_eq!(r.link_dropped, 0, "blackout losses are fault drops, not link loss");
    // No token is burned on a dead wire, so the stream keeps flowing
    // right through the window and recovers instantly after it.
    assert!(r.decisions.iter().any(|d| d.kept && d.capture_ms > 7_500.0));
}

#[test]
fn bandwidth_collapse_engages_the_modeled_link_and_backpressures() {
    let videos = cameras(2, 150, 0.4, 0xC7);
    let model = model_for(&videos);
    let cfg = sim_cfg(aggregate_fps(&videos), 0xC7, Policy::UtilityControlLoop);
    let base = run_driver(&videos, &cfg, &model);

    let mut collapsed = cfg.clone();
    collapsed.faults =
        FaultPlan::new().with(3_000.0, 10_000.0, FaultKind::BandwidthCollapse { mbps: 0.8 });
    let r = run_driver(&videos, &collapsed, &model);
    assert_conserved(&r);
    // Nothing is destroyed — frames flow, slowly, through the collapsed
    // link, and the measured transfer time shows up in the report.
    assert_eq!(r.faults.fault_dropped, 0);
    assert!(r.transmit_ms_total > 0.0, "collapse must engage the modeled link");
    // The control loop sees the congestion (via the measured network
    // pair) and sheds more than the unconstrained baseline.
    assert!(
        r.shed > base.shed,
        "collapse must backpressure: shed {} vs baseline {}",
        r.shed,
        base.shed
    );
    assert_ne!(r.decisions, base.decisions);
}

#[test]
fn worker_crash_declares_degraded_mode_and_recovers() {
    let videos = cameras(2, 150, 0.4, 0xD5);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0xD5, Policy::UtilityControlLoop);
    cfg.shedder.watchdog_ms = 1_500.0;
    cfg.faults = FaultPlan::new().with(5_000.0, 11_000.0, FaultKind::WorkerCrash);
    let r = run_driver(&videos, &cfg, &model);
    assert_conserved(&r);
    // Exactly one in-flight frame dies with the worker (one token).
    assert_eq!(r.faults.fault_dropped, 1);
    // The completion watchdog declared degraded mode inside the crash
    // window and closed it when the restart recovered the token.
    assert!(
        !r.faults.degraded_windows.is_empty(),
        "watchdog must declare degraded mode"
    );
    for &(s, e) in &r.faults.degraded_windows {
        assert!(s >= 5_000.0, "degraded start {s} before the crash");
        assert!(e > s && e <= r.end_ms, "degraded window ({s}, {e})");
        assert!(e >= 10_999.0, "recovery happens at the crash window's end, got {e}");
    }
    assert!(r.faults.degraded_ms() > 1_000.0, "degraded {} ms", r.faults.degraded_ms());
    assert!(r.faults.degraded_shed > 10, "degraded_shed {}", r.faults.degraded_shed);
    // Graceful recovery: the pipeline transmits again after the window.
    assert!(
        r.decisions.iter().any(|d| d.kept && d.capture_ms > 11_500.0),
        "pipeline must recover after the crash window"
    );
}

#[test]
fn straggler_slowdown_backpressures_the_control_loop() {
    let videos = cameras(2, 150, 0.4, 0xD6);
    let model = model_for(&videos);
    let cfg = sim_cfg(aggregate_fps(&videos), 0xD6, Policy::UtilityControlLoop);
    let base = run_driver(&videos, &cfg, &model);

    let mut slow = cfg.clone();
    slow.faults =
        FaultPlan::new().with(4_000.0, 10_000.0, FaultKind::BackendSlowdown { factor: 8.0 });
    let r = run_driver(&videos, &slow, &model);
    assert_conserved(&r);
    // A straggler destroys nothing, but the inflated service time drives
    // the control loop to shed harder than the healthy baseline.
    assert_eq!(r.faults.fault_dropped, 0);
    assert!(
        r.shed > base.shed,
        "slowdown must backpressure: shed {} vs baseline {}",
        r.shed,
        base.shed
    );
    assert_ne!(r.decisions, base.decisions);
}

#[test]
fn poisoned_control_observations_are_rejected_and_the_loop_survives() {
    for kind in [PoisonKind::Nan, PoisonKind::Stale] {
        let videos = cameras(2, 150, 0.4, 0xD7);
        let model = model_for(&videos);
        let mut cfg = sim_cfg(aggregate_fps(&videos), 0xD7, Policy::UtilityControlLoop);
        cfg.faults = FaultPlan::new().with(2_000.0, 12_000.0, FaultKind::PoisonControl { kind });
        let r = run_driver(&videos, &cfg, &model);
        assert_conserved(&r);
        assert!(
            r.faults.poisoned_rejected > 10,
            "{kind:?}: rejected {}",
            r.faults.poisoned_rejected
        );
        // Validation keeps the loop's state finite: threshold and target
        // rate never go NaN, and the metrics latency stays honest.
        assert!(
            r.control_series.iter().all(|&(_, th, rate)| th.is_finite() && rate.is_finite()),
            "{kind:?}: control series must stay finite"
        );
        assert!(r.latency.max_ms().is_finite());
    }
}

#[test]
fn multi_query_engine_books_fault_losses_per_query() {
    let videos = cameras(2, 120, 0.35, 0xE6);
    let idx: Vec<usize> = (0..videos.len()).collect();
    let specs = vec![
        QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
        QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)),
    ];
    let set = QuerySet::train(&specs, &videos, &idx).expect("query set");
    let cfg = MultiSimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        backend_tokens: 1,
        arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
        seed: 0xE6,
        fps_total: aggregate_fps(&videos),
        transport: TransportConfig::default(),
        faults: FaultPlan::new()
            .with(2_000.0, 5_000.0, FaultKind::CameraDrop { camera: 1 })
            .with(6_000.0, 8_000.0, FaultKind::LinkBlackout)
            .with(8_500.0, 10_000.0, FaultKind::WorkerCrash),
    };
    let extractor = Extractor::native(set.union_model().clone());
    let mut backends = multi_backends(&set, &cfg.costs, cfg.seed);
    let bgs = backgrounds_of(&videos);
    let r = run_multi_sim(
        Streamer::new(&videos),
        &bgs,
        &set,
        &cfg,
        &extractor,
        &mut backends,
    )
    .expect("multi sim");

    for q in &r.queries {
        let rep = &q.report;
        assert_eq!(
            rep.ingress,
            rep.transmitted + rep.shed + rep.link_dropped + rep.faults.fault_dropped,
            "{}: per-query conservation",
            q.name
        );
        assert_eq!(rep.decisions.len() as u64, rep.ingress, "{}: decision log", q.name);
        // Every query lost its copy of the dropped camera's frames.
        assert!(rep.faults.fault_dropped > 0, "{}: faults must bite", q.name);
    }
}

// ---------------------------------------------------------------------------
// 4. Chaos property test: randomized fault storms
// ---------------------------------------------------------------------------

#[test]
fn chaos_randomized_fault_plans_preserve_core_invariants() {
    let videos = cameras(2, 150, 0.35, 0xF7);
    let model = model_for(&videos);
    let horizon = 15_000.0;
    let mut base_cfg = sim_cfg(aggregate_fps(&videos), 0xF7, Policy::UtilityControlLoop);
    base_cfg.shedder.watchdog_ms = 1_000.0;
    base_cfg.shedder.camera_liveness_ms = 2_000.0;
    let bound = base_cfg.query.latency_bound_ms;
    let baseline = run_driver(&videos, &base_cfg, &model);
    assert_conserved(&baseline);
    let base_bad = violating_windows(&baseline, bound);

    let mut storms_with_losses = 0u32;
    for seed in 0..24u64 {
        let plan = FaultPlan::randomized(seed, horizon, 2);
        assert!(!plan.is_empty(), "randomized plans are never empty");
        let mut cfg = base_cfg.clone();
        cfg.faults = plan.clone();
        // Completing at all is the no-deadlock property: a stuck token or
        // an unclosed fault window would hang the event loop instead.
        let r = run_driver(&videos, &cfg, &model);
        assert_conserved(&r);
        assert!(r.end_ms.is_finite() && r.end_ms > 0.0);
        let q = r.qor.overall();
        assert!((0.0..=1.0).contains(&q), "seed {seed}: QoR {q}");

        // Bounded latency, or an explanation: every violating 5 s window
        // must already violate in the no-fault baseline, or lie within
        // the declared fault span / degraded windows (+ grace for the
        // post-fault queue flush).
        let span_start = plan
            .windows()
            .iter()
            .map(|w| w.start_ms)
            .fold(f64::INFINITY, f64::min);
        let span_end = plan.windows().iter().map(|w| w.end_ms).fold(0.0, f64::max);
        let grace = bound + 5_000.0;
        for w in violating_windows(&r, bound) {
            let explained_by_baseline = base_bad.iter().any(|&b| (b - w).abs() < 1.0);
            let explained_by_faults = w < span_end + grace && w + 5_000.0 > span_start - grace;
            let explained_by_degraded = r
                .faults
                .degraded_windows
                .iter()
                .any(|&(s, e)| w < e + grace && w + 5_000.0 > s);
            assert!(
                explained_by_baseline || explained_by_faults || explained_by_degraded,
                "seed {seed}: unexplained latency violation in window starting at {w} ms \
                 (fault span [{span_start}, {span_end}), degraded {:?})",
                r.faults.degraded_windows
            );
        }
        if r.faults.fault_dropped > 0 {
            storms_with_losses += 1;
        }
    }
    assert!(storms_with_losses >= 8, "storms must bite: {storms_with_losses}/24");
}

// ---------------------------------------------------------------------------
// 5. Supervision surfaces the real cause through run_pipeline
// ---------------------------------------------------------------------------

/// A backend executor whose worker thread panics on one job — the
/// integration analogue of the `pipeline::supervise` unit tests: the
/// panic's message must come out of `run_pipeline` as an `Err`.
struct CrashyExecutor {
    worker: SupervisedWorker<u64>,
    jobs: u64,
}

impl BackendExecutor for CrashyExecutor {
    fn submit(&mut self, _payload: FramePayload, _background: &[f32]) -> Result<(Stage, f64)> {
        let job = self.jobs;
        self.jobs += 1;
        self.worker.submit(job)?;
        Ok((Stage::Sink, 40.0))
    }

    fn on_complete(&mut self, seq: u64, _dnn: bool) -> Result<()> {
        // Single-token runs complete in dispatch order, so the dispatch
        // ordinal is the FIFO job index.
        self.worker.wait_for(seq)
    }

    fn finish(&mut self) -> Result<()> {
        self.worker.finish()
    }
}

#[test]
fn worker_panic_surfaces_its_cause_through_run_pipeline() {
    let videos = cameras(1, 40, 0.3, 0x9A);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(10.0, 0x9A, Policy::NoShedding);
    cfg.shedder.queue_cap_max = 10_000;

    let factory: RunnerFactory<u64> = std::sync::Arc::new(|| {
        Ok(Box::new(|job: &u64| -> Result<()> {
            if *job == 5 {
                panic!("injected detector crash on job 5");
            }
            Ok(())
        }))
    });
    let worker = SupervisedWorker::spawn(
        factory,
        SupervisorConfig {
            recv_timeout: std::time::Duration::from_secs(5),
            max_restarts: 0,
            backoff: std::time::Duration::from_millis(1),
        },
    )
    .expect("spawn worker");
    let mut executor = CrashyExecutor { worker, jobs: 0 };
    let extractor = Extractor::native(model);

    let err = run_pipeline(
        IterArrivals::new(Streamer::new(&videos), 10.0),
        &backgrounds_of(&videos),
        &cfg,
        &extractor,
        &mut executor,
        &mut SimClock,
    )
    .expect_err("the worker's panic must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("injected detector crash on job 5"), "got: {msg}");
    assert!(msg.contains("panicked"), "got: {msg}");
}
