//! Content-drift + online-adaptation properties:
//!
//! 1. **Off-state bit-identity** — with adaptation disabled (its other
//!    knobs armed) and a drift schedule that never covers the run, the
//!    pipeline is bit-identical to the undrifted default-config system:
//!    decision logs, control series, QoR and byte counts match across
//!    seeds and policies, and the adaptation counters stay zero.
//! 2. **Clock invariance** — an active drift schedule with the full
//!    adaptation loop armed (delayed labels → retrain → shadow →
//!    swap/rollback → CDF reseed) drives the sim and wall-clock drivers
//!    to exactly the same decisions and the same adaptation event log,
//!    because every state transition is keyed to virtual time.
//! 3. **Chaos composition** — ≥12 seeded random drift schedules overlaid
//!    on random fault storms ([`FaultPlan::randomized_with_drift`]) with
//!    adaptation armed: no deadlock, exact extended conservation, finite
//!    metrics.
//!
//! (Pixel-level drift determinism and the rollback-exactness property
//! are pinned at unit level in `video::generator` and `utility::adapt`.)

use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::realtime::{run_realtime, RealtimeConfig};
use uals::pipeline::{
    backgrounds_of, run_sim, FaultPlan, Policy, SimConfig, SimReport, TransportConfig,
};
use uals::shedder::ArbiterPolicy;
use uals::utility::{train, AdaptationConfig, AdaptationStats, Combine, UtilityModel};
use uals::video::{
    streamer::aggregate_fps, DriftKind, DriftPlan, Streamer, Video, VideoConfig,
};

fn cameras_with_drift(
    n: usize,
    frames: usize,
    vehicle_rate: f64,
    seed: u64,
    drift: &DriftPlan,
) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let mut vc = VideoConfig::new(0xFA0 ^ seed, seed * 41 + i as u64, i as u32, frames);
            vc.traffic.vehicle_rate = vehicle_rate;
            vc.drift = drift.clone();
            Video::new(vc)
        })
        .collect()
}

fn model_for(videos: &[Video]) -> UtilityModel {
    let idx: Vec<usize> = (0..videos.len()).collect();
    train(videos, &idx, &[NamedColor::Red], Combine::Single)
}

fn sim_cfg(fps: f64, seed: u64, policy: Policy) -> SimConfig {
    SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1200.0),
        backend_tokens: 1,
        policy,
        seed,
        fps_total: fps,
        transport: TransportConfig::default(),
        faults: FaultPlan::default(),
        adaptation: AdaptationConfig::default(),
    }
}

/// Aggressive adaptation tuning so small integration runs reach the
/// retrain → shadow → verdict cycle.
fn fast_adaptation() -> AdaptationConfig {
    AdaptationConfig {
        enabled: true,
        label_delay_ms: 250.0,
        retrain_every: 16,
        min_labels: 2,
        decay: 0.9,
        shadow_min_labels: 12,
        swap_margin: 0.01,
        probation_labels: 12,
        rollback_margin: 0.1,
        reseed_window: 128,
    }
}

fn run_driver(videos: &[Video], cfg: &SimConfig, model: &UtilityModel) -> SimReport {
    let extractor = Extractor::native(model.clone());
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    run_sim(
        Streamer::new(videos),
        &backgrounds_of(videos),
        cfg,
        &extractor,
        &mut backend,
    )
    .expect("sim driver")
}

fn assert_conserved(r: &SimReport) {
    assert_eq!(
        r.ingress,
        r.transmitted + r.shed + r.link_dropped + r.faults.fault_dropped,
        "conservation: {} != {} + {} + {} + {}",
        r.ingress,
        r.transmitted,
        r.shed,
        r.link_dropped,
        r.faults.fault_dropped
    );
    assert_eq!(r.decisions.len() as u64, r.ingress, "one decision per ingress frame");
}

// ---------------------------------------------------------------------------
// 1. Off-state bit-identity
// ---------------------------------------------------------------------------

#[test]
fn disabled_adaptation_and_far_future_drift_are_bit_identical_to_the_default() {
    for (seed, policy) in [
        (0xA1u64, Policy::UtilityControlLoop),
        (0xA2, Policy::FifoControlLoop),
        (0xA3, Policy::RandomRate { assumed_proc_q_ms: 120.0 }),
    ] {
        let clean = cameras_with_drift(2, 90, 0.4, seed, &DriftPlan::default());
        let model = model_for(&clean);
        let base = sim_cfg(aggregate_fps(&clean), seed, policy);
        let baseline = run_driver(&clean, &base, &model);
        assert_eq!(baseline.adaptation, AdaptationStats::default());

        // Every drift kind scheduled — a billion virtual seconds away —
        // and every adaptation knob armed except the master switch. The
        // run must be bit-identical to the clean default-config system:
        // no window covers the run, and a disabled adapter is never even
        // constructed.
        let far = 1.0e9;
        let armed_drift = DriftPlan::new()
            .with(far, far + 1e6, DriftKind::IlluminationRamp { delta: -80.0 })
            .with(far, far + 1e6, DriftKind::HueShift { degrees: 45.0 })
            .with(far, far + 1e6, DriftKind::Occlusion { camera: 0, frac: 0.4 })
            .with(far, far + 1e6, DriftKind::ObjectSurge { multiplier: 3.0 });
        let drifted = cameras_with_drift(2, 90, 0.4, seed, &armed_drift);
        let mut armed = base.clone();
        armed.adaptation = AdaptationConfig {
            enabled: false,
            label_delay_ms: 50.0,
            retrain_every: 4,
            min_labels: 1,
            decay: 0.5,
            shadow_min_labels: 4,
            swap_margin: 0.0,
            probation_labels: 4,
            rollback_margin: 0.0,
            reseed_window: 16,
        };
        let r = run_driver(&drifted, &armed, &model);
        assert_eq!(baseline.decisions, r.decisions, "seed {seed:x}: decisions diverge");
        assert_eq!(baseline.control_series, r.control_series, "seed {seed:x}");
        assert_eq!(baseline.qor.overall(), r.qor.overall());
        assert_eq!(baseline.bytes_on_wire, r.bytes_on_wire);
        assert_eq!(baseline.transmitted, r.transmitted);
        assert_eq!(r.adaptation, AdaptationStats::default());
        assert_conserved(&r);
    }
}

// ---------------------------------------------------------------------------
// 2. Clock invariance of drift + adaptation
// ---------------------------------------------------------------------------

#[test]
fn drift_with_adaptation_is_clock_invariant() {
    // Drift windows over the middle of a 2-camera run, full adaptation
    // loop armed. The whole design rides on virtual-time keying: render,
    // labels, retrains, swap verdicts and CDF reseeds must all fire
    // identically under the discrete-event and wall-clock drivers.
    let drift = DriftPlan::new()
        .with(2_000.0, 7_000.0, DriftKind::IlluminationRamp { delta: -70.0 })
        .with(4_000.0, 8_000.0, DriftKind::Occlusion { camera: 0, frac: 0.3 });
    let videos = cameras_with_drift(2, 100, 0.4, 0xB4, &drift);
    let model = model_for(&videos);
    let mut cfg = sim_cfg(aggregate_fps(&videos), 0xB4, Policy::UtilityControlLoop);
    cfg.adaptation = fast_adaptation();

    let sim = run_driver(&videos, &cfg, &model);
    assert!(
        sim.adaptation.labels_observed > 0,
        "the adaptation loop must consume labels"
    );

    let rt = RealtimeConfig {
        query: cfg.query.clone(),
        shedder: cfg.shedder.clone(),
        costs: cfg.costs.clone(),
        cost_emulation_scale: 0.0,
        time_scale: 1e-3,
        backend_tokens: cfg.backend_tokens,
        use_artifacts: false,
        policy: cfg.policy.clone(),
        seed: cfg.seed,
        arbiter: ArbiterPolicy::Standalone,
        transport: cfg.transport,
        faults: cfg.faults.clone(),
        adaptation: cfg.adaptation.clone(),
        ..Default::default()
    };
    let wall = run_realtime(&videos, &model, &rt).expect("wall driver");
    assert_eq!(sim.decisions, wall.decisions, "drift+adaptation must be clock-invariant");
    assert_eq!(
        sim.adaptation, wall.adaptation,
        "adaptation event log must be clock-invariant"
    );
    assert_eq!(sim.transmitted, wall.transmitted);
    assert_eq!(sim.bytes_on_wire, wall.bytes_on_wire);
}

// ---------------------------------------------------------------------------
// 3. Chaos composition: random drift over random fault storms
// ---------------------------------------------------------------------------

#[test]
fn chaos_drift_and_fault_storms_compose_without_losing_frames() {
    let horizon = 15_000.0;
    let mut engaged = 0u32;
    for seed in 0..12u64 {
        let (faults, drift) = FaultPlan::randomized_with_drift(seed, horizon, 2);
        assert!(!faults.is_empty() && !drift.is_empty());
        let videos = cameras_with_drift(2, 150, 0.35, 0xF8 ^ seed, &drift);
        let model = model_for(&videos);
        let mut cfg = sim_cfg(aggregate_fps(&videos), 0xF8 ^ seed, Policy::UtilityControlLoop);
        cfg.shedder.watchdog_ms = 1_000.0;
        cfg.shedder.camera_liveness_ms = 2_000.0;
        cfg.faults = faults;
        cfg.adaptation = fast_adaptation();

        // Completing at all is the no-deadlock property — an adaptation
        // step stalled on a label that never drains, or an unclosed
        // window, would hang the event loop instead.
        let r = run_driver(&videos, &cfg, &model);
        assert_conserved(&r);
        assert!(r.end_ms.is_finite() && r.end_ms > 0.0, "seed {seed}");
        let q = r.qor.overall();
        assert!((0.0..=1.0).contains(&q), "seed {seed}: QoR {q}");
        assert!(
            r.control_series.iter().all(|&(_, th, rate)| th.is_finite() && rate.is_finite()),
            "seed {seed}: control series must stay finite under drift+faults"
        );
        // Reseeds only ever follow a promoted or rolled-back model.
        assert!(
            r.adaptation.reseeds <= r.adaptation.swaps + r.adaptation.rollbacks,
            "seed {seed}: reseeds {} > swaps {} + rollbacks {}",
            r.adaptation.reseeds,
            r.adaptation.swaps,
            r.adaptation.rollbacks
        );
        if r.adaptation.labels_observed > 0 {
            engaged += 1;
        }
    }
    // Faults destroy frames but most storms still transmit plenty, so
    // the label feedback loop must engage in the large majority.
    assert!(engaged >= 8, "adaptation engaged in only {engaged}/12 chaos runs");
}
