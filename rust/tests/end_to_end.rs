//! End-to-end integration: the whole stack composed — training → CDF
//! seeding → shedding → backend query → metrics — in both the
//! discrete-event simulator and the threaded real-time runtime (with the
//! AOT artifacts on the hot path).

use std::collections::HashMap;
use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, Deployment, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::realtime::{run_realtime, RealtimeConfig};
use uals::pipeline::{backgrounds_of, run_sim, BackgroundMap, Policy, SimConfig};
use uals::video::{
    build_dataset, DatasetConfig, Paint, SegmentedVideo, Streamer, Video, VideoConfig,
};
use uals::utility::{train, Combine};

fn aux_model(colors: &[NamedColor], combine: Combine) -> uals::utility::UtilityModel {
    let videos = build_dataset(&DatasetConfig {
        num_seeds: 2,
        videos_per_seed: 2,
        frames_per_video: 250,
        base_seed: 0xE2E,
        target_boost: 2.0,
    });
    let idx: Vec<usize> = (0..videos.len()).collect();
    train(&videos, &idx, colors, combine)
}

#[test]
fn fig13a_scenario_shape_holds_end_to_end() {
    // The paper's synthetic worst case: shedding must concentrate in the
    // middle (red-burst) segment, and segments 1/3 must be mostly cheap.
    let sv = SegmentedVideo::fig13a(0xE2E1, 200, Paint::VividRed);
    let model = aux_model(&[NamedColor::Red], Combine::Single);
    let query = QueryConfig::single(NamedColor::Red).with_latency_bound(1000.0);
    let cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: query.clone(),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xE,
        fps_total: sv.fps(),
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    };
    let extractor = Extractor::native(model);
    let mut backend = BackendQuery::new(
        query,
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    let mut bgs: BackgroundMap<'_> = HashMap::new();
    bgs.insert(0u32, sv.background());
    let report = run_sim(sv.iter(), &bgs, &cfg, &extractor, &mut backend).unwrap();

    assert_eq!(report.ingress, 600);
    assert_eq!(report.ingress, report.transmitted + report.shed);
    // Latency bound held (paper: at most an odd transient violation).
    assert!(
        report.latency.violation_rate() <= 0.02,
        "violation rate {}",
        report.latency.violation_rate()
    );
    // Shedding concentrates in the burst segment (frames 200..400).
    let shed_windows = report.stages.counts(uals::metrics::Stage::Shed);
    let shed_in = |lo_ms: f64, hi_ms: f64| -> u64 {
        shed_windows
            .iter()
            .filter(|(t, _)| *t >= lo_ms && *t < hi_ms)
            .map(|(_, n)| n)
            .sum()
    };
    let seg1 = shed_in(0.0, 20_000.0);
    let seg2 = shed_in(20_000.0, 40_000.0);
    let seg3 = shed_in(40_000.0, 60_000.0);
    assert!(
        seg2 > seg1 && seg2 > seg3,
        "shedding must peak in the burst: {seg1} / {seg2} / {seg3}"
    );
    // DNN activity also peaks in segment 2.
    let dnn_windows = report.stages.counts(uals::metrics::Stage::Dnn);
    let dnn_in = |lo: f64, hi: f64| -> u64 {
        dnn_windows
            .iter()
            .filter(|(t, _)| *t >= lo && *t < hi)
            .map(|(_, n)| n)
            .sum()
    };
    assert!(dnn_in(20_000.0, 40_000.0) > dnn_in(0.0, 20_000.0));
}

#[test]
fn composite_or_query_end_to_end() {
    let model = aux_model(&[NamedColor::Red, NamedColor::Yellow], Combine::Or);
    let query = QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Or)
        .with_latency_bound(1200.0);
    let mut vc = VideoConfig::new(0xE2E2, 5, 0, 250);
    vc.traffic.vehicle_rate = 0.5;
    vc.traffic.paint_weights = vec![
        (Paint::VividRed, 0.15),
        (Paint::VividYellow, 0.15),
        (Paint::Gray, 0.4),
        (Paint::Silver, 0.3),
    ];
    let videos = vec![Video::new(vc)];
    let cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: query.clone(),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 2,
        fps_total: 10.0,
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    };
    let extractor = Extractor::native(model);
    let mut backend = BackendQuery::new(
        query,
        Detector::native(12, 25.0),
        CostModel::new(cfg.costs.clone(), cfg.seed),
        25.0,
    );
    let report = run_sim(
        Streamer::new(&videos),
        &backgrounds_of(&videos),
        &cfg,
        &extractor,
        &mut backend,
    )
    .unwrap();
    assert_eq!(report.ingress, 250);
    assert!(report.qor.overall() > 0.5, "OR-query QoR {}", report.qor.overall());
    assert!(report.latency.violation_rate() < 0.05);
}

#[test]
fn deployment_scenarios_tighten_queue() {
    // Fig. 2: cloud deployments have higher network latency, which must
    // translate into smaller dynamic queues (Eq. 20) — same bound, less
    // budget for queueing.
    let mk = |dep: Deployment| {
        let costs = dep.costs();
        let mut cl = uals::shedder::ControlLoop::new(
            &ShedderConfig::default(),
            &costs,
            1000.0,
        );
        for _ in 0..100 {
            cl.observe_backend(100.0);
        }
        cl.queue_size()
    };
    let edge = mk(Deployment::EdgeCompute);
    let cloud = mk(Deployment::EdgeToCloud);
    assert!(cloud <= edge, "cloud queue {cloud} vs edge {edge}");
}

#[test]
fn realtime_pipeline_with_artifacts() {
    // Threaded runtime at 10× fast-forward; conservation + sane QoR.
    // Uses the PJRT artifact path when available, otherwise the native
    // fast path (the extractor contract is identical either way).
    let use_artifacts = uals::runtime::artifacts_available();
    if !use_artifacts {
        eprintln!(
            "realtime_pipeline_with_artifacts: artifacts/PJRT unavailable, using native path"
        );
    }
    let model = aux_model(&[NamedColor::Red], Combine::Single);
    let mut vc = VideoConfig::new(0xE2E3, 9, 0, 60);
    vc.traffic.vehicle_rate = 0.4;
    let videos = vec![Video::new(vc)];
    let cfg = RealtimeConfig {
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
        time_scale: 0.1,
        cost_emulation_scale: 1.0,
        use_artifacts,
        ..Default::default()
    };
    let report = run_realtime(&videos, &model, &cfg).expect("realtime run");
    assert_eq!(report.ingress, 60);
    assert_eq!(report.ingress, report.transmitted + report.shed);
    // The extractor must be fast enough for 10 fps real time.
    assert!(
        report.extract_ms_mean < 100.0,
        "extractor too slow: {} ms",
        report.extract_ms_mean
    );
}

#[test]
fn sharded_multi_camera_sweep_end_to_end() {
    // The per-camera-shedder deployment: N independent edge boxes swept in
    // parallel, metrics merged deterministically.
    let model = aux_model(&[NamedColor::Red], Combine::Single);
    let videos: Vec<Video> = (0..4)
        .map(|i| {
            let mut vc = VideoConfig::new(0xE2E4, 31 + i as u64, i as u32, 150);
            vc.traffic.vehicle_rate = 0.4;
            vc.quantize_u8 = true; // u8 camera frames → LUT fast path
            Video::new(vc)
        })
        .collect();
    let cfg = SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0xE4,
        fps_total: 10.0,
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    };
    let (merged, per_camera) =
        uals::pipeline::run_sharded_sim(&videos, &cfg, &model, uals::pipeline::default_threads())
            .expect("sharded sim");
    assert_eq!(per_camera.len(), 4);
    assert_eq!(merged.ingress, 600);
    assert_eq!(merged.ingress, merged.transmitted + merged.shed);
    assert!(merged.qor.overall() > 0.0);
}
