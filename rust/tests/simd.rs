//! Property tests pinning every SIMD kernel **bit-identical** to the
//! scalar oracle, at every ISA [`Level`] available on the host.
//!
//! `Level::available()` always starts with `Scalar`; on x86_64 it adds
//! `Sse2` (baseline) and, where detected, `Avx2`; on aarch64 it adds
//! `Neon`. Each kernel is compared against the scalar result over
//! randomized frames at deliberately awkward geometries — widths and
//! rect extents that are not multiples of the vector width, 1-px-wide
//! rects, empty rects — plus the degenerate contents (all-background,
//! all-foreground) that exercise the gate's early-out structure.

use uals::color::{ColorLut, HueRanges, NamedColor};
use uals::features::{reference, HIST};
use uals::simd::{self, Level};
use uals::util::rng::Rng;

fn random_frame(rng: &mut Rng, n_px: usize) -> Vec<u8> {
    (0..n_px * 3).map(|_| rng.below(256) as u8).collect()
}

/// `base` with `n_muts` random pixels replaced — a sparse-foreground
/// frame relative to `base` as background.
fn mutate(rng: &mut Rng, base: &[u8], n_muts: usize) -> Vec<u8> {
    let mut out = base.to_vec();
    let n_px = base.len() / 3;
    for _ in 0..n_muts {
        let p = rng.range(0, n_px);
        for c in 0..3 {
            out[3 * p + c] = rng.below(256) as u8;
        }
    }
    out
}

/// Assert `count_rect` agrees with the scalar oracle at every available
/// level: foreground count, per-color histograms, and in-color counts.
fn assert_count_rect_matches(
    lut: &ColorLut,
    frame: &[u8],
    bg: &[u8],
    width: usize,
    rect: (usize, usize, usize, usize),
    k: usize,
) {
    let mut want_pf = vec![0u32; k * HIST];
    let mut want_ic = vec![0u32; k];
    let want_fg =
        simd::count_rect(Level::Scalar, lut, frame, bg, width, rect, k, &mut want_pf, &mut want_ic);
    for level in Level::available() {
        let mut pf = vec![0u32; k * HIST];
        let mut ic = vec![0u32; k];
        let fg = simd::count_rect(level, lut, frame, bg, width, rect, k, &mut pf, &mut ic);
        assert_eq!(fg, want_fg, "{}: fg count, rect {rect:?} width {width}", level.name());
        assert_eq!(pf, want_pf, "{}: pf histogram, rect {rect:?} width {width}", level.name());
        assert_eq!(ic, want_ic, "{}: in_color, rect {rect:?} width {width}", level.name());
    }
}

fn two_color_lut() -> ColorLut {
    ColorLut::new(
        &[NamedColor::Red.ranges(), NamedColor::Yellow.ranges()],
        reference::FG_THRESHOLD,
    )
}

#[test]
fn count_rect_matches_scalar_at_awkward_geometries() {
    let lut = two_color_lut();
    let mut rng = Rng::new(0x51D0);
    // Widths straddling the 16- and 32-pixel block sizes; heights small
    // enough to keep the full sweep cheap.
    for &(width, height) in &[(17usize, 9usize), (31, 7), (33, 5), (96, 12), (1, 40), (16, 16)] {
        let bg = random_frame(&mut rng, width * height);
        let frame = mutate(&mut rng, &bg, (width * height) / 6);
        // Full frame, interior rect with odd extents, 1-px-wide column,
        // 1-px-tall row, and an empty rect.
        let rects = [
            (0, 0, width, height),
            (width / 3, height / 3, width, height),
            (width.saturating_sub(1), 0, width, height),
            (0, height / 2, width, height / 2 + 1),
            (width / 2, height / 2, width / 2, height / 2),
        ];
        for rect in rects {
            assert_count_rect_matches(&lut, &frame, &bg, width, rect, lut.num_colors());
        }
    }
}

#[test]
fn count_rect_matches_scalar_on_degenerate_contents() {
    let lut = two_color_lut();
    let mut rng = Rng::new(0xDE6E);
    let (width, height) = (33usize, 11usize);
    let bg = random_frame(&mut rng, width * height);

    // All-background: frame == bg, every block rejected by the gate.
    assert_count_rect_matches(&lut, &bg, &bg, width, (0, 0, width, height), lut.num_colors());

    // Dense foreground: an unrelated random frame.
    let noise = random_frame(&mut rng, width * height);
    assert_count_rect_matches(&lut, &noise, &bg, width, (0, 0, width, height), lut.num_colors());

    // All-foreground via a negative threshold (fg_floor = -1): the gate
    // cannot reject anything, which the vector paths special-case.
    let lut_all = ColorLut::new(&[NamedColor::Red.ranges()], -3.0);
    assert_count_rect_matches(
        &lut_all,
        &bg,
        &bg,
        width,
        (0, 0, width, height),
        lut_all.num_colors(),
    );

    // Threshold 0: any nonzero channel diff is foreground — exercises
    // the floor_u8 = 0 saturating-subtract edge.
    let lut_zero = ColorLut::new(&[NamedColor::Yellow.ranges()], 0.0);
    let frame = mutate(&mut rng, &bg, 40);
    assert_count_rect_matches(
        &lut_zero,
        &frame,
        &bg,
        width,
        (0, 0, width, height),
        lut_zero.num_colors(),
    );
}

#[test]
fn count_rect_matches_scalar_at_max_colors() {
    // k = 8 fills the bitmask (the `(1 << k) - 1` edge); overlapping
    // ranges make several mask bits fire per pixel.
    let ranges: Vec<HueRanges> = (0..8)
        .map(|i| {
            if i % 2 == 0 {
                NamedColor::Red.ranges()
            } else {
                NamedColor::Yellow.ranges()
            }
        })
        .collect();
    let lut = ColorLut::new(&ranges, 10.0);
    let mut rng = Rng::new(0x8C);
    let (width, height) = (31usize, 13usize);
    let bg = random_frame(&mut rng, width * height);
    let frame = mutate(&mut rng, &bg, 120);
    assert_count_rect_matches(&lut, &frame, &bg, width, (0, 0, width, height), 8);
}

#[test]
fn quantize_matches_scalar_decision_and_bytes() {
    let mut rng = Rng::new(0x0AF32);
    // Integer-valued sources at lengths straddling the 16- and 32-lane
    // blocks (and the empty source).
    for &n in &[0usize, 1, 15, 16, 17, 31, 32, 33, 100] {
        let src: Vec<f32> = (0..n).map(|_| rng.below(256) as f32).collect();
        let mut want = Vec::new();
        assert!(simd::quantize(Level::Scalar, &src, &mut want), "len {n}");
        for level in Level::available() {
            let mut got = Vec::new();
            assert!(simd::quantize(level, &src, &mut got), "{}: len {n}", level.name());
            assert_eq!(got, want, "{}: len {n}", level.name());
        }
    }
}

#[test]
fn quantize_rejects_exactly_what_scalar_rejects() {
    // Poison values at the head, inside a vector block, and in the
    // scalar tail; the decision (not the dst bytes — unspecified on
    // reject) must match the oracle everywhere.
    let poisons =
        [0.5f32, 17.25, -0.25, f32::NAN, f32::INFINITY, -1.0, 256.0, 300.0, -2147483648.0];
    let mut rng = Rng::new(0xBAD);
    for &poison in &poisons {
        for &(n, at) in &[(40usize, 0usize), (40, 20), (40, 39), (17, 16), (33, 32)] {
            let mut src: Vec<f32> = (0..n).map(|_| rng.below(256) as f32).collect();
            src[at] = poison;
            let want = simd::quantize(Level::Scalar, &src, &mut Vec::new());
            for level in Level::available() {
                let got = simd::quantize(level, &src, &mut Vec::new());
                assert_eq!(got, want, "{}: poison {poison} at {at}/{n}", level.name());
            }
        }
    }
    // Boundary values that must be ACCEPTED: 0.0, -0.0 (== 0.0, q = 0),
    // and 255.0.
    let src = [0.0f32, -0.0, 255.0, 1.0];
    let mut want = Vec::new();
    assert!(simd::quantize(Level::Scalar, &src, &mut want));
    assert_eq!(want, vec![0u8, 0, 255, 1]);
    for level in Level::available() {
        let mut got = Vec::new();
        assert!(simd::quantize(level, &src, &mut got), "{}", level.name());
        assert_eq!(got, want, "{}", level.name());
    }
}

#[test]
fn rect_differs_matches_scalar_everywhere() {
    let mut rng = Rng::new(0xD1FF);
    for &(width, height) in &[(96usize, 96usize), (17, 9), (33, 5), (1, 20)] {
        let a = random_frame(&mut rng, width * height);

        // Identical frames: no rect may report a difference.
        let tile = 16usize;
        let tiles_x = width.div_ceil(tile);
        let tiles_y = height.div_ceil(tile);
        for ti in 0..tiles_x * tiles_y {
            let (tx, ty) = (ti % tiles_x, ti / tiles_x);
            let rect = (
                tx * tile,
                ty * tile,
                (tx * tile + tile).min(width),
                (ty * tile + tile).min(height),
            );
            for level in Level::available() {
                assert!(
                    !simd::rect_differs(level, &a, &a, width, rect),
                    "{}: equal frames, rect {rect:?}",
                    level.name()
                );
            }
        }

        // Single-byte diffs at positions chosen to land in a vector
        // block, in a row tail, and at the very last byte of the frame.
        for _ in 0..30 {
            let mut b = a.clone();
            let at = rng.range(0, b.len());
            b[at] ^= 0x40;
            for ti in 0..tiles_x * tiles_y {
                let (tx, ty) = (ti % tiles_x, ti / tiles_x);
                let rect = (
                    tx * tile,
                    ty * tile,
                    (tx * tile + tile).min(width),
                    (ty * tile + tile).min(height),
                );
                let want = simd::rect_differs(Level::Scalar, &a, &b, width, rect);
                for level in Level::available() {
                    assert_eq!(
                        simd::rect_differs(level, &a, &b, width, rect),
                        want,
                        "{}: diff at byte {at}, rect {rect:?} width {width}",
                        level.name()
                    );
                }
            }
        }

        // Empty rect never differs.
        for level in Level::available() {
            assert!(!simd::rect_differs(level, &a, &a, width, (3, 2, 3, 2)), "{}", level.name());
        }
    }
}

#[test]
fn dispatched_fast_path_still_matches_reference_oracle() {
    // End to end through the cached process-wide level: the fused fast
    // path (quantize + count_rect at `simd::level()`) must stay
    // bit-identical to the float reference.
    let ranges = [NamedColor::Red.ranges(), NamedColor::Yellow.ranges()];
    let lut = ColorLut::new(&ranges, reference::FG_THRESHOLD);
    let mut rng = Rng::new(0xE2E);
    for _ in 0..20 {
        let n_px = 33 * 11;
        let bg: Vec<f32> = (0..n_px * 3).map(|_| rng.below(256) as f32).collect();
        let mut rgb = bg.clone();
        for _ in 0..rng.range(0, 150) {
            let p = rng.range(0, n_px);
            for c in 0..3 {
                rgb[3 * p + c] = rng.below(256) as f32;
            }
        }
        let fast = uals::features::compute_features_fast(&lut, &rgb, &bg);
        let oracle = reference::compute_features(&rgb, &bg, &ranges, reference::FG_THRESHOLD);
        assert_eq!(fast, oracle);
    }
}
