//! Pins the temporal-redundancy incremental engine to the fused fast
//! path and the reference oracle — **bit-equality on every input**,
//! including the fallback paths (first frame, non-integer frames, scene
//! cuts) — and the sharded simulator's determinism under incremental
//! extraction.

use uals::color::{ColorLut, HueRanges, NamedColor};
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::{
    compute_features, compute_features_fast, Extractor, FrameFeatures, IncrementalConfig,
    IncrementalEngine, UtilityValues,
};
use uals::pipeline::{run_sharded_sim, run_sharded_sim_with, Policy, SimConfig};
use uals::util::prop::{Gen, Prop};
use uals::util::rng::Rng;
use uals::utility::{train, Combine};
use uals::video::{Video, VideoConfig};

/// Random hue-range set (1–2 colors), as in `fast_path.rs`.
fn random_ranges(g: &mut Gen) -> Vec<HueRanges> {
    let named = [
        NamedColor::Red,
        NamedColor::Yellow,
        NamedColor::Green,
        NamedColor::Blue,
    ];
    let k = 1 + g.usize_in(0..2);
    (0..k)
        .map(|_| {
            if g.bool() {
                named[g.usize_in(0..named.len())].ranges()
            } else {
                let rng = g.rng();
                let lo = rng.f32() * 170.0;
                let hi = (lo + rng.f32() * (180.0 - lo)).min(180.0);
                HueRanges::single(lo, hi)
            }
        })
        .collect()
}

fn random_int_frame(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.below(256) as f32).collect()
}

/// Mutate a random rect of `frame` with random integer pixels (object
/// motion / appearance).
fn mutate_rect(rng: &mut Rng, frame: &mut [f32], w: usize, h: usize) {
    let rw = 1 + rng.range(0, (w / 2).max(1));
    let rh = 1 + rng.range(0, (h / 2).max(1));
    let x0 = rng.range(0, w - rw + 1);
    let y0 = rng.range(0, h - rh + 1);
    for y in y0..y0 + rh {
        for x in x0..x0 + rw {
            let i = 3 * (y * w + x);
            for c in 0..3 {
                frame[i + c] = rng.below(256) as f32;
            }
        }
    }
}

#[test]
fn incremental_is_bit_equal_to_fast_and_reference_over_streams() {
    Prop::new("incremental ≡ fast ≡ reference (streams)")
        .cases(25)
        .run(|g| {
            let ranges = random_ranges(g);
            let fg_threshold = match g.usize_in(0..3) {
                0 => 25.0,
                1 => g.f64_in(0.0, 80.0) as f32,
                _ => 0.0,
            };
            let lut = ColorLut::new(&ranges, fg_threshold);
            let w = 8 + g.usize_in(0..33);
            let h = 8 + g.usize_in(0..25);
            let tile = [4usize, 8, 16][g.usize_in(0..3)];
            let cfg = IncrementalConfig { tile, max_dirty_frac: g.f64_in(0.1, 0.9) };
            let mut engine = IncrementalEngine::new(cfg, w, h);
            let mut out = FrameFeatures::empty();
            let case_seed = g.case_seed;
            let rng = g.rng();
            let n = w * h * 3;
            let bg = random_int_frame(rng, n);
            let mut frame = bg.clone();
            for step in 0..14 {
                match rng.below(8) {
                    0 | 1 => {} // static frame (zero dirty tiles)
                    2 | 3 => mutate_rect(rng, &mut frame, w, h), // sparse motion
                    4 => {
                        // heavy motion: several rects at once
                        for _ in 0..4 {
                            mutate_rect(rng, &mut frame, w, h);
                        }
                    }
                    5 => frame = random_int_frame(rng, n), // forced scene cut
                    6 => {
                        // non-integer sensor noise → whole-frame fallback
                        for _ in 0..1 + rng.range(0, 5) {
                            let i = rng.range(0, n);
                            frame[i] = (frame[i] + 0.25).min(255.25);
                        }
                    }
                    _ => {
                        // re-quantize: recovery back onto the tile path
                        for v in frame.iter_mut() {
                            *v = v.round().clamp(0.0, 255.0);
                        }
                    }
                }
                engine.extract_into(&lut, &frame, &bg, None, &mut out);
                let oracle = compute_features(&frame, &bg, &ranges, fg_threshold);
                assert_eq!(out, oracle, "vs reference, step {step} seed {case_seed}");
                let fast = compute_features_fast(&lut, &frame, &bg);
                assert_eq!(out, fast, "vs fast, step {step} seed {case_seed}");
            }
        });
}

fn noise_free_video_rate(
    traffic_seed: u64,
    camera: u32,
    frames: usize,
    vehicle_rate: f64,
) -> Video {
    let mut vc = VideoConfig::new(7, traffic_seed, camera, frames);
    vc.pixel_noise = 0.0;
    vc.brightness_jitter = 0.0;
    vc.quantize_u8 = true;
    vc.traffic.vehicle_rate = vehicle_rate;
    vc.traffic.pedestrian_rate = vehicle_rate;
    Video::new(vc)
}

fn noise_free_video(traffic_seed: u64, camera: u32, frames: usize) -> Video {
    noise_free_video_rate(traffic_seed, camera, frames, 0.35)
}

#[test]
fn hinted_extraction_matches_oracle_and_engages_tile_path() {
    // Sparse traffic: the high-redundancy regime the engine targets.
    let videos = vec![noise_free_video_rate(77, 0, 150, 0.1)];
    let v = &videos[0];
    let model = train(&videos, &[0], &[NamedColor::Red], Combine::Single);
    let ranges = model.ranges();
    let ex = Extractor::native(model.clone()).with_incremental(IncrementalConfig::default());
    let mut rects = Vec::new();
    let mut feats = FrameFeatures::empty();
    let mut utils = UtilityValues::empty();
    for t in 0..v.len() {
        let f = v.render(t);
        let exhaustive = v.dirty_rects_into(t, &mut rects);
        assert_eq!(exhaustive, t > 0, "noise-free video is hintable after t=0");
        let hints = exhaustive.then_some(rects.as_slice());
        ex.extract_camera_hinted_into(
            0,
            f.width,
            f.height,
            &f.rgb,
            v.background(),
            hints,
            &mut feats,
            &mut utils,
        )
        .unwrap();
        let oracle = compute_features(&f.rgb, v.background(), &ranges, model.fg_threshold);
        assert_eq!(feats, oracle, "t={t}");
        assert_eq!(utils, model.utility(&oracle), "t={t}");
    }
    let s = ex.incremental_stats(0).unwrap();
    assert_eq!(s.frames, 150);
    assert_eq!(s.fallbacks, 0, "u8 camera must never fall back: {s:?}");
    assert!(s.incremental_frames >= 120, "tile path must dominate: {s:?}");
    // The whole point: steady-state dirty fraction is small.
    assert!(
        s.dirty_tiles * 2 < s.total_tiles,
        "sparse traffic must keep most tiles clean: {s:?}"
    );
}

#[test]
fn diffed_extraction_matches_oracle_on_noise_free_video() {
    let videos = vec![noise_free_video(91, 0, 80)];
    let v = &videos[0];
    let model = train(&videos, &[0], &[NamedColor::Red], Combine::Single);
    let ranges = model.ranges();
    let ex = Extractor::native(model.clone()).with_incremental(IncrementalConfig::default());
    let mut feats = FrameFeatures::empty();
    let mut utils = UtilityValues::empty();
    for t in 0..v.len() {
        let f = v.render(t);
        ex.extract_camera_into(0, f.width, f.height, &f.rgb, v.background(), &mut feats, &mut utils)
            .unwrap();
        let oracle = compute_features(&f.rgb, v.background(), &ranges, model.fg_threshold);
        assert_eq!(feats, oracle, "t={t}");
    }
    let s = ex.incremental_stats(0).unwrap();
    assert!(s.incremental_frames >= 40, "diff path must engage: {s:?}");
}

fn sweep_cameras(n: usize, frames: usize) -> Vec<Video> {
    (0..n).map(|i| noise_free_video(0xA11 + i as u64, i as u32, frames)).collect()
}

fn sweep_cfg() -> SimConfig {
    SimConfig {
        costs: CostConfig::default(),
        shedder: ShedderConfig::default(),
        query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed: 0x1AC,
        fps_total: 10.0,
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    }
}

#[test]
fn sharded_sim_with_incremental_matches_plain_exactly() {
    let videos = sweep_cameras(3, 120);
    let model = train(&videos, &[0, 1], &[NamedColor::Red], Combine::Single);
    let cfg = sweep_cfg();
    let (plain, per_plain) = run_sharded_sim(&videos, &cfg, &model, 1).unwrap();
    let (inc, per_inc) =
        run_sharded_sim_with(&videos, &cfg, &model, 3, Some(IncrementalConfig::default()))
            .unwrap();
    // Bit-identical extraction ⇒ identical decisions ⇒ identical metrics,
    // independent of worker count.
    assert_eq!(plain.ingress, inc.ingress);
    assert_eq!(plain.transmitted, inc.transmitted);
    assert_eq!(plain.shed, inc.shed);
    assert_eq!(plain.qor.overall(), inc.qor.overall());
    assert_eq!(plain.latency.count(), inc.latency.count());
    assert_eq!(plain.latency.max_ms(), inc.latency.max_ms());
    assert_eq!(plain.control_series, inc.control_series);
    for ((c1, r1), (c2, r2)) in per_plain.iter().zip(&per_inc) {
        assert_eq!(c1, c2);
        assert_eq!(r1.ingress, r2.ingress);
        assert_eq!(r1.shed, r2.shed);
        assert_eq!(r1.qor.overall(), r2.qor.overall());
    }
}

#[test]
fn serial_sim_with_incremental_extractor_matches_plain() {
    use uals::backend::{BackendQuery, CostModel, Detector};
    use uals::pipeline::{backgrounds_of, run_sim};
    use uals::video::Streamer;

    let videos = sweep_cameras(2, 100);
    let model = train(&videos, &[0], &[NamedColor::Red], Combine::Single);
    let cfg = sweep_cfg();
    let mk_backend = || {
        BackendQuery::new(
            cfg.query.clone(),
            Detector::native(12, model.fg_threshold),
            CostModel::new(cfg.costs.clone(), cfg.seed),
            model.fg_threshold,
        )
    };
    let bgs = backgrounds_of(&videos);

    let plain_ex = Extractor::native(model.clone());
    let mut backend = mk_backend();
    let plain = run_sim(Streamer::new(&videos), &bgs, &cfg, &plain_ex, &mut backend).unwrap();

    // The incremental extractor maintains one engine per camera even when
    // the two streams interleave through a single shared shedder.
    let inc_ex = Extractor::native(model.clone()).with_incremental(IncrementalConfig::default());
    let mut backend = mk_backend();
    let inc = run_sim(Streamer::new(&videos), &bgs, &cfg, &inc_ex, &mut backend).unwrap();

    assert_eq!(plain.ingress, inc.ingress);
    assert_eq!(plain.transmitted, inc.transmitted);
    assert_eq!(plain.shed, inc.shed);
    assert_eq!(plain.qor.overall(), inc.qor.overall());
    assert_eq!(plain.latency.max_ms(), inc.latency.max_ms());
    assert_eq!(plain.control_series, inc.control_series);
    for cam in [0u32, 1] {
        let s = inc_ex.incremental_stats(cam).unwrap();
        assert!(s.incremental_frames > 0, "camera {cam} never went incremental: {s:?}");
    }
}
