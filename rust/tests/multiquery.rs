//! Multi-query correctness properties:
//!
//! 1. **Standalone bit-match** — with K queries behind the
//!    standalone-budget arbiter (every query sees the full backend
//!    budget) and deterministic stage costs, each query's per-frame
//!    decision log, QoR, and control series bit-match an independent
//!    single-query pipeline run of that query (same seed, same stream,
//!    backend cost model seeded per `multi_backend_seed`). Checked over
//!    multiple content seeds and K = 8.
//! 2. **One extraction per frame** — the shared pipeline advances the
//!    extractor's extraction counter exactly once per ingress frame
//!    regardless of K, while K independent runs pay K× that.
//! 3. **Fair-share sanity** — per-query frame conservation, identical
//!    twins behave identically, and heavier weights shed less.
//! 4. **Clock invariance** — the multi-query wall-clock driver
//!    (`MultiThreadedBackend`) reproduces the discrete-event decisions.

use uals::backend::{BackendQuery, CostModel, Detector};
use uals::color::NamedColor;
use uals::config::{CostConfig, QueryConfig, ShedderConfig};
use uals::features::Extractor;
use uals::pipeline::realtime::{run_multi_realtime, RealtimeConfig};
use uals::pipeline::{
    backgrounds_of, multi_backend_seed, multi_backends, run_multi_sim, run_sim,
    MultiPipelineReport, MultiSimConfig, Policy, SimConfig,
};
use uals::experiments::scenarios::multiquery_pool;
use uals::shedder::{ArbiterPolicy, QuerySet, QuerySpec};
use uals::utility::Combine;
use uals::video::{streamer::aggregate_fps, Streamer, Video, VideoConfig};

fn cameras(n: usize, frames: usize, seed: u64) -> Vec<Video> {
    (0..n)
        .map(|i| {
            let content = seed.wrapping_mul(131) + i as u64;
            let mut vc = VideoConfig::new(0x30 ^ seed, content, i as u32, frames);
            vc.traffic.vehicle_rate = 0.4;
            Video::new(vc)
        })
        .collect()
}

/// Deterministic stage costs: the single-pipeline cost RNG interleaves
/// camera/net/stage draws per run, so the bit-match property is stated
/// (and pinned) at jitter = 0, where every cost is its configured
/// constant in both deployments.
fn deterministic_costs() -> CostConfig {
    CostConfig { jitter: 0.0, ..Default::default() }
}

fn run_multi(
    videos: &[Video],
    set: &QuerySet,
    seed: u64,
    arbiter: ArbiterPolicy,
    costs: CostConfig,
) -> (MultiPipelineReport, u64) {
    let fps = aggregate_fps(videos);
    let cfg = MultiSimConfig {
        costs,
        shedder: ShedderConfig::default(),
        backend_tokens: 1,
        arbiter,
        seed,
        fps_total: fps,
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
    };
    let extractor = Extractor::native(set.union_model().clone());
    let mut backends = multi_backends(set, &cfg.costs, cfg.seed);
    let r = run_multi_sim(
        Streamer::new(videos),
        &backgrounds_of(videos),
        set,
        &cfg,
        &extractor,
        &mut backends,
    )
    .expect("multi sim");
    let extractions = extractor.extractions();
    (r, extractions)
}

/// An independent single-query pipeline for query `q` of the set, seeded
/// exactly as the multi run seeds that query's backend.
fn run_single(
    videos: &[Video],
    set: &QuerySet,
    q: usize,
    seed: u64,
    costs: CostConfig,
) -> uals::pipeline::SimReport {
    let fps = aggregate_fps(videos);
    let cfg = SimConfig {
        costs: costs.clone(),
        shedder: ShedderConfig::default(),
        query: set.queries()[q].config.clone(),
        backend_tokens: 1,
        policy: Policy::UtilityControlLoop,
        seed,
        fps_total: fps,
        transport: uals::pipeline::TransportConfig::default(),
        faults: uals::pipeline::FaultPlan::default(),
        adaptation: uals::utility::AdaptationConfig::default(),
    };
    let extractor = Extractor::native(set.query_model(q));
    let mut backend = BackendQuery::new(
        cfg.query.clone(),
        Detector::native(12, 25.0),
        CostModel::new(costs, multi_backend_seed(seed, q)),
        25.0,
    );
    run_sim(
        Streamer::new(videos),
        &backgrounds_of(videos),
        &cfg,
        &extractor,
        &mut backend,
    )
    .expect("single sim")
}

#[test]
fn standalone_budget_bitmatches_independent_single_runs() {
    // The full 8-query pool (the scenario/bench pool, shared so the three
    // call sites cannot drift): each query's log must bit-match its own
    // independent single-query pipeline.
    for content_seed in [0x51u64, 0x77] {
        let videos = cameras(3, 100, content_seed);
        let idx: Vec<usize> = (0..videos.len()).collect();
        let specs = multiquery_pool();
        let set = QuerySet::train(&specs, &videos, &idx).unwrap();
        assert_eq!(set.len(), 8);
        let seed = 0xD1CE;
        let (multi, _) =
            run_multi(&videos, &set, seed, ArbiterPolicy::Standalone, deterministic_costs());

        assert_eq!(multi.frames, 300, "content seed {content_seed:x}");
        for q in 0..set.len() {
            let single = run_single(&videos, &set, q, seed, deterministic_costs());
            let mq = &multi.queries[q].report;
            let label = format!("seed {content_seed:x} query {q} ({})", multi.queries[q].name);
            assert_eq!(mq.ingress, single.ingress, "{label}: ingress");
            assert_eq!(mq.transmitted, single.transmitted, "{label}: transmitted");
            assert_eq!(mq.shed, single.shed, "{label}: shed");
            assert_eq!(
                mq.decisions.len(),
                single.decisions.len(),
                "{label}: decision counts"
            );
            for (i, (a, b)) in mq.decisions.iter().zip(&single.decisions).enumerate() {
                assert_eq!(a, b, "{label}: decision {i} diverges");
            }
            // Same decisions on the same ground truth ⇒ bit-identical QoR
            // and per-object recall.
            assert_eq!(mq.qor.overall(), single.qor.overall(), "{label}: QoR");
            assert_eq!(
                mq.qor.per_object_all(),
                single.qor.per_object_all(),
                "{label}: per-object QoR"
            );
            // The control loop walked the same trajectory.
            assert_eq!(mq.control_series, single.control_series, "{label}: control series");
            assert_eq!(mq.latency.count(), single.latency.count(), "{label}: completions");
            assert_eq!(mq.latency.max_ms(), single.latency.max_ms(), "{label}: max e2e");
        }
    }
}

#[test]
fn shared_pipeline_extracts_exactly_once_per_frame_for_8_queries() {
    let videos = cameras(2, 80, 0x8E);
    let idx: Vec<usize> = (0..videos.len()).collect();
    let set = QuerySet::train(&multiquery_pool(), &videos, &idx).unwrap();
    assert_eq!(set.len(), 8);
    let (multi, extractions) = run_multi(
        &videos,
        &set,
        0xBEEF,
        ArbiterPolicy::WeightedFair { work_conserving: true },
        CostConfig::default(),
    );
    assert_eq!(multi.frames, 160);
    assert_eq!(multi.extractions, multi.frames, "one extraction per frame, K = 8");
    assert_eq!(extractions, multi.frames, "extractor counter agrees");
    // Every query saw every frame and conserved it.
    for q in &multi.queries {
        assert_eq!(q.report.ingress, multi.frames);
        assert_eq!(q.report.ingress, q.report.transmitted + q.report.shed);
        assert_eq!(q.report.decisions.len() as u64, q.report.ingress);
    }
    // The independent deployment pays K× the extractions for the same
    // frames: here that's simply K single runs of the same stream.
    let mut independent_extractions = 0;
    for q in 0..2 {
        let extractor = Extractor::native(set.query_model(q));
        let cfg = SimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            query: set.queries()[q].config.clone(),
            backend_tokens: 1,
            policy: Policy::UtilityControlLoop,
            seed: 0xBEEF,
            fps_total: aggregate_fps(&videos),
            transport: uals::pipeline::TransportConfig::default(),
            faults: uals::pipeline::FaultPlan::default(),
            adaptation: uals::utility::AdaptationConfig::default(),
        };
        let mut backend = BackendQuery::new(
            cfg.query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), multi_backend_seed(0xBEEF, q)),
            25.0,
        );
        run_sim(
            Streamer::new(&videos),
            &backgrounds_of(&videos),
            &cfg,
            &extractor,
            &mut backend,
        )
        .unwrap();
        independent_extractions += extractor.extractions();
    }
    assert_eq!(independent_extractions, 2 * multi.frames);
}

#[test]
fn fair_share_conserves_and_identical_twins_agree() {
    // Two identical red queries with equal weights: the arbiter must
    // treat them identically — bit-equal decisions — and a third heavy
    // query must come out no worse than its light twins. Five cameras
    // against single-DNN backends: genuine overload, so the budget split
    // actually binds (pinned by the shed > 0 assert).
    use NamedColor::Red;
    let videos = cameras(5, 120, 0x44);
    let idx: Vec<usize> = (0..videos.len()).collect();
    let specs = vec![
        QuerySpec::new("red-a", QueryConfig::single(Red)),
        QuerySpec::new("red-b", QueryConfig::single(Red)),
        QuerySpec::new("red-heavy", QueryConfig::single(Red)).with_weight(8.0),
    ];
    let set = QuerySet::train(&specs, &videos, &idx).unwrap();
    let (multi, _) = run_multi(
        &videos,
        &set,
        0xFA1,
        ArbiterPolicy::WeightedFair { work_conserving: true },
        deterministic_costs(),
    );
    let (a, b, heavy) = (
        &multi.queries[0].report,
        &multi.queries[1].report,
        &multi.queries[2].report,
    );
    assert_eq!(a.ingress, a.transmitted + a.shed);
    assert!(a.shed > 0, "overloaded fair-share run must shed");
    assert_eq!(a.decisions, b.decisions, "identical twins diverged");
    assert_eq!(a.qor.overall(), b.qor.overall());
    // The heavy query holds a larger capacity slice: it must transmit at
    // least as much and drop no more than the equal-weight twins.
    assert!(
        heavy.transmitted >= a.transmitted,
        "weight 8 query transmitted less ({} vs {})",
        heavy.transmitted,
        a.transmitted
    );
    assert!(
        heavy.observed_drop_rate() <= a.observed_drop_rate() + 1e-12,
        "weight 8 query dropped more ({} vs {})",
        heavy.observed_drop_rate(),
        a.observed_drop_rate()
    );
    // Aggregate view merges per-query accounting.
    let agg = multi.aggregate();
    assert_eq!(agg.ingress, 3 * multi.frames);
    assert_eq!(
        agg.shed,
        a.shed + b.shed + heavy.shed,
        "aggregate shed must sum per-query sheds"
    );
}

#[test]
fn multi_sim_and_wallclock_driver_make_identical_decisions() {
    use NamedColor::{Red, Yellow};
    let videos = cameras(2, 80, 0x99);
    let idx: Vec<usize> = (0..videos.len()).collect();
    let specs = vec![
        QuerySpec::new("red", QueryConfig::single(Red)),
        QuerySpec::new(
            "either",
            QueryConfig::composite(Red, Yellow, Combine::Or),
        ),
    ];
    let set = QuerySet::train(&specs, &videos, &idx).unwrap();
    let seed = 0xC10C;
    let arbiter = ArbiterPolicy::WeightedFair { work_conserving: true };
    // Default (jittered) costs: clock invariance must not depend on
    // deterministic costs — both drivers share the same cost streams.
    let (sim, _) = run_multi(&videos, &set, seed, arbiter, CostConfig::default());

    let rt_cfg = RealtimeConfig {
        shedder: ShedderConfig::default(),
        costs: CostConfig::default(),
        cost_emulation_scale: 0.0, // pure compute speed
        time_scale: 1e-3,          // 1000× fast-forward
        backend_tokens: 1,
        use_artifacts: false,
        seed,
        arbiter,
        ..Default::default()
    };
    let wall = run_multi_realtime(&videos, &set, &rt_cfg).expect("wall driver");

    assert_eq!(sim.frames, wall.frames);
    for (qs, qw) in sim.queries.iter().zip(&wall.queries) {
        assert_eq!(qs.report.ingress, qw.report.ingress, "{}", qs.name);
        assert_eq!(qs.report.transmitted, qw.report.transmitted, "{}", qs.name);
        assert_eq!(qs.report.shed, qw.report.shed, "{}", qs.name);
        assert_eq!(qs.report.decisions.len(), qw.report.decisions.len(), "{}", qs.name);
        for (i, (a, b)) in qs.report.decisions.iter().zip(&qw.report.decisions).enumerate() {
            assert_eq!(a, b, "{}: decision {i}", qs.name);
        }
        assert_eq!(qs.report.qor.overall(), qw.report.qor.overall(), "{}", qs.name);
    }
}
