//! On-demand video rendering: `Video` = scene + trajectories + noise model.
//!
//! Frames are rendered lazily (`render(t)`) and deterministically, so
//! multi-hour experiment sweeps never materialize full videos in memory.

use super::drift::DriftPlan;
use super::frame::Frame;
use super::objects::{spawn_traffic, Kind, TrafficConfig, Trajectory};
use super::scene::Scene;
use crate::color::hsv::{hsv_to_rgb, rgb_to_hsv};
use crate::color::HUE_MAX;
use crate::util::rng::{splitmix64, Rng};

/// Object-id offset for surge-pool trajectories, so flash-crowd objects
/// never collide with base-traffic ids.
const SURGE_ID_OFFSET: u64 = 1_000_000;

/// Configuration of one synthetic camera video.
#[derive(Debug, Clone)]
pub struct VideoConfig {
    /// Scene seed (VisualRoad's camera-placement seed analogue).
    pub scene_seed: u64,
    /// Traffic seed — different videos from the same scene seed share the
    /// camera geometry but see different traffic (paper: "3 or 4 videos
    /// from each seed value").
    pub traffic_seed: u64,
    pub camera_id: u32,
    pub frames: usize,
    pub fps: f64,
    pub width: usize,
    pub height: usize,
    pub traffic: TrafficConfig,
    /// Per-frame global brightness jitter amplitude (lighting flicker).
    pub brightness_jitter: f32,
    /// Per-pixel uniform sensor-noise amplitude (±).
    pub pixel_noise: f32,
    /// Round rendered pixels to integers (what a real u8 camera ships).
    /// Integer frames take the LUT fast path in `features::fast`; off by
    /// default to keep the seed experiments' pixel streams unchanged.
    pub quantize_u8: bool,
    /// Scheduled content-drift windows (empty = the undrifted
    /// verification mode; see [`crate::video::drift`]).
    pub drift: DriftPlan,
}

impl VideoConfig {
    pub fn new(scene_seed: u64, traffic_seed: u64, camera_id: u32, frames: usize) -> Self {
        VideoConfig {
            scene_seed,
            traffic_seed,
            camera_id,
            frames,
            fps: 10.0,
            width: 96,
            height: 96,
            traffic: TrafficConfig::default_mix(),
            brightness_jitter: 2.0,
            pixel_noise: 2.5,
            quantize_u8: false,
            drift: DriftPlan::default(),
        }
    }
}

/// A synthetic camera video: render any frame on demand.
pub struct Video {
    pub config: VideoConfig,
    pub scene: Scene,
    trajectories: Vec<Trajectory>,
    /// Flash-crowd trajectory pool, drawn (and ground-truthed) only
    /// while an [`super::drift::DriftKind::ObjectSurge`] window covers
    /// the frame. Empty unless the drift plan has a surge window, and
    /// built from an *independent* RNG so base traffic is bit-unchanged.
    surge_trajectories: Vec<Trajectory>,
    /// Quantized background model (only under `quantize_u8`: a u8 camera's
    /// background-subtraction reference is itself u8).
    background_q: Option<Vec<f32>>,
}

impl Video {
    pub fn new(config: VideoConfig) -> Self {
        let scene = Scene::generate(config.scene_seed, config.width, config.height);
        let mut rng = Rng::new(config.traffic_seed ^ xtraffic_u64());
        let trajectories =
            spawn_traffic(&scene, &config.traffic, config.frames, config.fps, &mut rng);
        let surge_trajectories = if config.drift.has_object_surge() {
            // Pool sized by the plan's peak multiplier: extra arrivals at
            // (peak − 1)× the base rates, on a dedicated RNG stream.
            let extra = (config.drift.peak_surge_multiplier() - 1.0).max(0.0);
            let mut scfg = config.traffic.clone();
            scfg.vehicle_rate *= extra;
            scfg.pedestrian_rate *= extra;
            let mut srng = Rng::new(config.traffic_seed ^ 0xD21F_7001);
            let mut surge =
                spawn_traffic(&scene, &scfg, config.frames, config.fps, &mut srng);
            for tr in &mut surge {
                tr.object_id += SURGE_ID_OFFSET;
            }
            surge
        } else {
            Vec::new()
        };
        let background_q = config
            .quantize_u8
            .then(|| scene.background().iter().map(|x| x.round()).collect());
        Video { config, scene, trajectories, surge_trajectories, background_q }
    }

    pub fn len(&self) -> usize {
        self.config.frames
    }

    pub fn is_empty(&self) -> bool {
        self.config.frames == 0
    }

    pub fn camera_id(&self) -> u32 {
        self.config.camera_id
    }

    /// The camera's background model (clean scene, no noise) as H*W*3 —
    /// quantized to integers when the camera is a u8 camera.
    pub fn background(&self) -> &[f32] {
        match &self.background_q {
            Some(b) => b,
            None => self.scene.background(),
        }
    }

    pub fn trajectories(&self) -> &[Trajectory] {
        &self.trajectories
    }

    /// Is a surge window covering frame `tf` (frames, possibly
    /// fractional)? False whenever the pool is empty, so undrifted
    /// videos pay nothing.
    fn surge_active(&self, tf: f64) -> bool {
        !self.surge_trajectories.is_empty()
            && self.config.drift.surge_multiplier(tf / self.config.fps * 1e3) > 1.0
    }

    /// Deterministic dirt-patch rectangle of ~`frac` of the frame area,
    /// seeded per (scene, camera) — the same camera fouls in the same
    /// place every run.
    fn occlusion_rect(&self, frac: f64) -> (usize, usize, usize, usize) {
        let (w, h) = (self.config.width, self.config.height);
        let mut rng = Rng::new(
            self.config.scene_seed ^ ((self.config.camera_id as u64) << 32) ^ 0x0CC1,
        );
        let area = (frac * (w * h) as f64).max(4.0);
        let side = area.sqrt();
        let rw = ((side * rng.range_f64(0.8, 1.25)).round() as usize).clamp(2, w);
        let rh = ((area / rw as f64).round() as usize).clamp(2, h);
        let x0 = rng.below((w - rw + 1) as u64) as usize;
        let y0 = rng.below((h - rh + 1) as u64) as usize;
        (x0, y0, x0 + rw, y0 + rh)
    }

    /// Render frame `t` (with ground truth).
    pub fn render(&self, t: usize) -> Frame {
        let mut frame = Frame::empty();
        self.render_into(t, &mut frame);
        frame
    }

    /// Zero-allocation render: reuses the caller's [`Frame`] as an arena
    /// (its rgb/truth buffers keep their capacity across calls). Pixel
    /// output is identical to [`Self::render`].
    pub fn render_into(&self, t: usize, frame: &mut Frame) {
        assert!(t < self.config.frames, "frame {t} out of range");
        let (w, h) = (self.config.width, self.config.height);
        let tf = t as f64;
        frame.rgb.clear();
        frame.rgb.extend_from_slice(self.scene.background());
        let rgb = &mut frame.rgb;

        // Draw dynamic objects (pedestrians first: vehicles occlude them).
        frame.truth.clear();
        for tr in &self.trajectories {
            if let Some(vis) = tr.visible_at(tf, w, h) {
                tr.draw(rgb, tf, w, h);
                frame.truth.push(vis);
            }
        }
        // Flash-crowd objects: drawn and ground-truthed only while a
        // surge window covers the frame (keeps truth == rendered truth).
        if self.surge_active(tf) {
            for tr in &self.surge_trajectories {
                if let Some(vis) = tr.visible_at(tf, w, h) {
                    tr.draw(rgb, tf, w, h);
                    frame.truth.push(vis);
                }
            }
        }

        // Lighting jitter + sensor noise, deterministic per (video, frame).
        let mut state = self.config.traffic_seed ^ (t as u64).wrapping_mul(0x9E37_79B9_97F4_A7C1);
        let mut nrng = Rng::new(splitmix64(&mut state));
        let bright = (nrng.f32() - 0.5) * 2.0 * self.config.brightness_jitter;
        let amp = self.config.pixel_noise;
        if amp > 0.0 || bright != 0.0 {
            for v in rgb.iter_mut() {
                let noise = (nrng.f32() - 0.5) * 2.0 * amp;
                *v = (*v + bright + noise).clamp(0.0, 255.0);
            }
        }
        // Content drift: pure functions of the frame's virtual timestamp,
        // applied after sensor noise and before quantization. The empty
        // plan skips everything — bit-identical to an undrifted render.
        if !self.config.drift.is_empty() {
            let ts_ms = tf / self.config.fps * 1e3;
            let delta = self.config.drift.illumination_delta(ts_ms);
            if delta != 0.0 {
                for v in rgb.iter_mut() {
                    *v = (*v + delta).clamp(0.0, 255.0);
                }
            }
            let deg = self.config.drift.hue_shift_degrees(ts_ms);
            if deg != 0.0 {
                for px in rgb.chunks_exact_mut(3) {
                    let (h0, s, v) = rgb_to_hsv(px[0], px[1], px[2]);
                    // Full degrees → OpenCV half-units.
                    let hue = (h0 + deg * 0.5).rem_euclid(HUE_MAX);
                    let (r, g, b) = hsv_to_rgb(hue, s, v);
                    px[0] = r;
                    px[1] = g;
                    px[2] = b;
                }
            }
            let frac = self.config.drift.occlusion_frac(self.config.camera_id, ts_ms);
            if frac > 0.0 {
                let (x0, y0, x1, y1) = self.occlusion_rect(frac);
                // Heavy blend toward a dark smear; ground truth is NOT
                // edited — objects under the dirt stay in `truth`, which
                // is exactly what blinds a frozen utility model.
                const DIRT: [f32; 3] = [46.0, 41.0, 34.0];
                for y in y0..y1 {
                    for x in x0..x1 {
                        let i = (y * w + x) * 3;
                        for c in 0..3 {
                            rgb[i + c] = rgb[i + c] * 0.12 + DIRT[c] * 0.88;
                        }
                    }
                }
            }
        }
        if self.config.quantize_u8 {
            for v in rgb.iter_mut() {
                *v = v.round();
            }
        }

        frame.camera = self.config.camera_id;
        frame.index = t;
        frame.ts_ms = tf / self.config.fps * 1e3;
        frame.height = h;
        frame.width = w;
    }

    /// Generator-known dirty rectangles between frames `t-1` and `t`:
    /// the (clipped) bounding boxes of every object whose rasterization
    /// moved, at both its old and new position. Returns `true` when the
    /// rects are **exhaustive** — every pixel outside them is guaranteed
    /// identical across the two frames — which lets an incremental
    /// extractor skip even the frame diff. Returns `false` (rects
    /// cleared) when the whole frame must be considered dirty: the first
    /// frame, or any config with per-pixel noise / brightness jitter
    /// (those touch every pixel every frame).
    pub fn dirty_rects_into(
        &self,
        t: usize,
        rects: &mut Vec<(usize, usize, usize, usize)>,
    ) -> bool {
        rects.clear();
        if t == 0
            || t >= self.config.frames
            || self.config.brightness_jitter != 0.0
            || self.config.pixel_noise != 0.0
        {
            return false;
        }
        // An active pixel-level drift breaks the rect contract (global
        // transforms touch every pixel; surge objects are not in the
        // base trajectory list). Check t−1 too: the frame right after a
        // window closes still differs from its drifted predecessor.
        if !self.config.drift.is_empty() {
            let cam = self.config.camera_id;
            let ts = |t: usize| t as f64 / self.config.fps * 1e3;
            if self.config.drift.perturbs(cam, ts(t))
                || self.config.drift.perturbs(cam, ts(t - 1))
            {
                return false;
            }
        }
        let (w, h) = (self.config.width, self.config.height);
        let (t0, t1) = ((t - 1) as f64, t as f64);
        for tr in &self.trajectories {
            let a = tr.bbox_at(t0, w, h);
            let b = tr.bbox_at(t1, w, h);
            if a.is_none() && b.is_none() {
                continue;
            }
            // Pixel-identical rasterization: same clipped bbox and same
            // rounded left edge ⇒ the object draws the exact same pixels
            // (any overdraw by *other* moved objects is covered by their
            // own rects).
            if a == b && tr.x_at(t0).round() == tr.x_at(t1).round() {
                continue;
            }
            for (x0, y0, x1, y1) in [a, b].into_iter().flatten() {
                // Pedestrians draw a head row one above their bbox.
                let y0 = if tr.kind == Kind::Pedestrian { y0.saturating_sub(1) } else { y0 };
                rects.push((x0, y0, x1, y1));
            }
        }
        true
    }

    /// [`Self::render_into`] plus the dirty-rect report for the `t-1 → t`
    /// transition; the returned bool is [`Self::dirty_rects_into`]'s.
    pub fn render_into_with_dirty(
        &self,
        t: usize,
        frame: &mut Frame,
        rects: &mut Vec<(usize, usize, usize, usize)>,
    ) -> bool {
        self.render_into(t, frame);
        self.dirty_rects_into(t, rects)
    }

    /// Ground truth without rendering (fast path for labeling sweeps).
    pub fn truth(&self, t: usize) -> Vec<super::frame::VisibleObject> {
        let tf = t as f64;
        let mut out: Vec<_> = self
            .trajectories
            .iter()
            .filter_map(|tr| tr.visible_at(tf, self.config.width, self.config.height))
            .collect();
        if self.surge_active(tf) {
            out.extend(
                self.surge_trajectories
                    .iter()
                    .filter_map(|tr| tr.visible_at(tf, self.config.width, self.config.height)),
            );
        }
        out
    }

    /// Iterator over all frames.
    pub fn iter(&self) -> impl Iterator<Item = Frame> + '_ {
        (0..self.config.frames).map(move |t| self.render(t))
    }
}

// A readable constant for the traffic RNG domain separator.
#[inline]
fn xtraffic_u64() -> u64 {
    0x7261_6666_6963_0001 // "raffic" + tag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::video::frame::Paint;

    fn quick_video(traffic_seed: u64) -> Video {
        Video::new(VideoConfig::new(2, traffic_seed, 0, 200))
    }

    #[test]
    fn render_deterministic() {
        let v = quick_video(9);
        let a = v.render(37);
        let b = v.render(37);
        assert_eq!(a.rgb, b.rgb);
        assert_eq!(a.truth, b.truth);
        let c = v.render(38);
        assert_ne!(a.rgb, c.rgb);
    }

    #[test]
    fn truth_matches_render_truth() {
        let v = quick_video(10);
        for t in [0usize, 50, 123, 199] {
            assert_eq!(v.truth(t), v.render(t).truth);
        }
    }

    #[test]
    fn some_frames_have_vehicles() {
        let v = quick_video(11);
        let with_vehicles = (0..v.len())
            .filter(|&t| v.truth(t).iter().any(|o| o.is_vehicle))
            .count();
        assert!(with_vehicles > 50, "only {with_vehicles} frames with vehicles");
    }

    #[test]
    fn red_targets_appear_and_persist() {
        // Target objects must persist across multiple frames (the paper's
        // second premise: high frame rate ⇒ objects span many frames).
        let mut cfg = VideoConfig::new(3, 12, 0, 600);
        cfg.traffic.vehicle_rate = 0.5;
        cfg.traffic.paint_weights = vec![(Paint::VividRed, 0.5), (Paint::Gray, 0.5)];
        let v = Video::new(cfg);
        use std::collections::HashMap;
        let mut frames_per_object: HashMap<u64, usize> = HashMap::new();
        for t in 0..v.len() {
            for id in v.render(t).target_ids(NamedColor::Red, 40) {
                *frames_per_object.entry(id).or_default() += 1;
            }
        }
        assert!(!frames_per_object.is_empty(), "no red targets in video");
        let avg = frames_per_object.values().sum::<usize>() as f64
            / frames_per_object.len() as f64;
        assert!(avg >= 5.0, "targets too fleeting: avg {avg} frames");
    }

    #[test]
    fn noise_bounded() {
        let v = quick_video(13);
        let f = v.render(0);
        for &px in &f.rgb {
            assert!((0.0..=255.0).contains(&px));
        }
        // Noise must be small relative to content: diff vs clean bg bounded
        // on non-object pixels.
        let bg = v.background();
        let objs = &f.truth;
        let mut max_bg_diff = 0.0f32;
        for y in 0..96 {
            for x in 0..96 {
                let covered = objs.iter().any(|o| {
                    let (x0, y0, x1, y1) = o.bbox;
                    // pedestrians draw a head pixel one row above their bbox
                    x >= x0 && x < x1 && y + 1 >= y0 && y < y1
                });
                if !covered {
                    let i = (y * 96 + x) * 3;
                    for c in 0..3 {
                        max_bg_diff = max_bg_diff.max((f.rgb[i + c] - bg[i + c]).abs());
                    }
                }
            }
        }
        assert!(max_bg_diff <= 2.0 * (2.5 + 2.0) + 0.1, "diff {max_bg_diff}");
    }

    #[test]
    fn render_into_matches_render_and_reuses_buffers() {
        let v = quick_video(21);
        let mut arena = Frame::empty();
        v.render_into(0, &mut arena); // warm the arena capacity
        let cap = arena.rgb.capacity();
        for t in [0usize, 17, 100, 199] {
            v.render_into(t, &mut arena);
            let fresh = v.render(t);
            assert_eq!(arena.rgb, fresh.rgb);
            assert_eq!(arena.truth, fresh.truth);
            assert_eq!((arena.index, arena.ts_ms), (fresh.index, fresh.ts_ms));
            assert_eq!(arena.rgb.capacity(), cap, "arena must not reallocate");
        }
    }

    #[test]
    fn quantize_u8_yields_integer_pixels() {
        let mut cfg = VideoConfig::new(2, 9, 0, 200);
        cfg.quantize_u8 = true;
        let v = Video::new(cfg);
        let f = v.render(13);
        assert!(f.rgb.iter().all(|&x| x == x.round() && (0.0..=255.0).contains(&x)));
        // Same scene content as the float render, just rounded.
        let float_v = quick_video(9);
        let ff = float_v.render(13);
        for (a, b) in f.rgb.iter().zip(&ff.rgb) {
            assert!((a - b).abs() <= 0.5 + 1e-6);
        }
    }

    #[test]
    fn dirty_rects_cover_every_changed_pixel() {
        let mut cfg = VideoConfig::new(3, 17, 0, 120);
        cfg.pixel_noise = 0.0;
        cfg.brightness_jitter = 0.0;
        cfg.quantize_u8 = true;
        cfg.traffic.vehicle_rate = 0.5;
        let v = Video::new(cfg);
        let mut rects = Vec::new();
        let mut prev = v.render(0);
        let mut any_rects = 0usize;
        for t in 1..v.len() {
            let f = v.render(t);
            assert!(v.dirty_rects_into(t, &mut rects), "noise-free must be exhaustive");
            any_rects += rects.len();
            for y in 0..96 {
                for x in 0..96 {
                    let i = (y * 96 + x) * 3;
                    if f.rgb[i..i + 3] != prev.rgb[i..i + 3] {
                        let covered = rects
                            .iter()
                            .any(|&(x0, y0, x1, y1)| x >= x0 && x < x1 && y >= y0 && y < y1);
                        assert!(covered, "changed pixel ({x},{y}) at t={t} outside all rects");
                    }
                }
            }
            prev = f;
        }
        assert!(any_rects > 0, "moving traffic must report rects");
    }

    #[test]
    fn dirty_rects_refuse_noisy_configs() {
        let v = quick_video(9); // default config has noise + jitter
        let mut rects = vec![(1, 2, 3, 4)];
        assert!(!v.dirty_rects_into(5, &mut rects));
        assert!(rects.is_empty(), "refusal must clear stale rects");
        // First frame is never hintable even without noise.
        let mut cfg = VideoConfig::new(3, 17, 0, 10);
        cfg.pixel_noise = 0.0;
        cfg.brightness_jitter = 0.0;
        assert!(!Video::new(cfg).dirty_rects_into(0, &mut rects));
    }

    #[test]
    fn far_future_drift_is_bit_identical_to_empty_plan() {
        use crate::video::drift::DriftKind;
        let base = quick_video(9);
        let mut cfg = VideoConfig::new(2, 9, 0, 200);
        // Windows far past the video's horizon: scheduled but never
        // active — must render bit-identical pixels and truth.
        let far = 1e9;
        cfg.drift = crate::video::drift::DriftPlan::new()
            .with(far, far + 1e3, DriftKind::IlluminationRamp { delta: -80.0 })
            .with(far, far + 1e3, DriftKind::HueShift { degrees: 40.0 })
            .with(far, far + 1e3, DriftKind::Occlusion { camera: 0, frac: 0.3 })
            .with(far, far + 1e3, DriftKind::ObjectSurge { multiplier: 3.0 });
        let v = Video::new(cfg);
        for t in [0usize, 37, 123, 199] {
            let a = base.render(t);
            let b = v.render(t);
            assert_eq!(a.rgb, b.rgb, "t={t}");
            assert_eq!(a.truth, b.truth, "t={t}");
            assert_eq!(base.truth(t), v.truth(t));
        }
    }

    #[test]
    fn drift_transforms_fire_inside_their_windows() {
        use crate::video::drift::{DriftKind, DriftPlan};
        let base = quick_video(9);
        // 200 frames at 10 fps → ts ∈ [0, 20 000) ms.
        let mut cfg = VideoConfig::new(2, 9, 0, 200);
        cfg.drift = DriftPlan::new()
            .with(2_000.0, 6_000.0, DriftKind::IlluminationRamp { delta: -120.0 })
            .with(8_000.0, 12_000.0, DriftKind::Occlusion { camera: 0, frac: 0.3 })
            .with(14_000.0, 18_000.0, DriftKind::ObjectSurge { multiplier: 4.0 });
        let v = Video::new(cfg);
        // Before any window: identical.
        assert_eq!(base.render(5).rgb, v.render(5).rgb);
        // Illumination midpoint (t=40 → 4 000 ms): darker overall.
        let (a, b) = (base.render(40), v.render(40));
        let mean = |f: &Frame| f.rgb.iter().sum::<f32>() / f.rgb.len() as f32;
        assert!(mean(&b) < mean(&a) - 50.0, "{} vs {}", mean(&a), mean(&b));
        assert_eq!(a.truth, b.truth, "illumination leaves truth alone");
        // Occlusion (t=100 → 10 000 ms): pixels differ, truth unchanged.
        let (a, b) = (base.render(100), v.render(100));
        assert_ne!(a.rgb, b.rgb);
        assert_eq!(a.truth, b.truth);
        // Surge (t=160 → 16 000 ms): strictly more ground-truth objects
        // somewhere in the window, ids disjoint from base traffic.
        let extra: usize = (140..180)
            .map(|t| v.truth(t).len().saturating_sub(base.truth(t).len()))
            .sum();
        assert!(extra > 0, "no surge objects appeared");
        for t in 140..180 {
            let f = v.render(t);
            assert_eq!(f.truth, v.truth(t), "render truth == fast truth at t={t}");
            for o in f.truth.iter().filter(|o| o.object_id >= SURGE_ID_OFFSET) {
                assert!(
                    base.truth(t).iter().all(|b| b.object_id != o.object_id),
                    "surge ids must not collide"
                );
            }
        }
        // After every window: identical again.
        assert_eq!(base.render(195).rgb, v.render(195).rgb);
    }

    #[test]
    fn hue_shift_rotates_hue_and_preserves_value() {
        use crate::color::hsv::rgb_to_hsv;
        use crate::video::drift::{DriftKind, DriftPlan};
        let mut cfg = VideoConfig::new(2, 9, 0, 200);
        cfg.pixel_noise = 0.0;
        cfg.brightness_jitter = 0.0;
        let base = Video::new(cfg.clone());
        cfg.drift = DriftPlan::new().with(
            0.0,
            20_000.0,
            DriftKind::HueShift { degrees: 60.0 },
        );
        let v = Video::new(cfg);
        let mut checked = 0;
        for t in (0..200).step_by(13) {
            let (a, b) = (base.render(t), v.render(t));
            // Expected rotation at this frame (half-units), via the plan.
            let shift = v.config.drift.hue_shift_degrees(t as f64 / 10.0 * 1e3) * 0.5;
            for (pa, pb) in a.rgb.chunks_exact(3).zip(b.rgb.chunks_exact(3)) {
                let (ha, sa, va) = rgb_to_hsv(pa[0], pa[1], pa[2]);
                let (hb, sb, vb) = rgb_to_hsv(pb[0], pb[1], pb[2]);
                if sa > 40.0 {
                    let want = (ha + shift).rem_euclid(180.0);
                    // Circular hue distance (the domain wraps at 180).
                    let d = (hb - want).rem_euclid(180.0);
                    let d = d.min(180.0 - d);
                    assert!(d < 0.1, "t={t}: hue {ha} → {hb}, want {want}");
                    assert!((sb - sa).abs() < 0.1 && (vb - va).abs() < 0.1);
                    checked += 1;
                }
            }
        }
        assert!(checked > 50, "too few saturated pixels checked: {checked}");
    }

    #[test]
    fn dirty_rects_refuse_active_drift_windows_only() {
        use crate::video::drift::{DriftKind, DriftPlan};
        let mut cfg = VideoConfig::new(3, 17, 0, 120);
        cfg.pixel_noise = 0.0;
        cfg.brightness_jitter = 0.0;
        // 120 frames at 10 fps → ts ∈ [0, 12 000). Drift in [4 000, 6 000).
        cfg.drift = DriftPlan::new().with(
            4_000.0,
            6_000.0,
            DriftKind::IlluminationRamp { delta: -60.0 },
        );
        let v = Video::new(cfg.clone());
        let mut rects = Vec::new();
        assert!(v.dirty_rects_into(20, &mut rects), "before the window: hintable");
        assert!(!v.dirty_rects_into(50, &mut rects), "inside: refused");
        assert!(
            !v.dirty_rects_into(60, &mut rects),
            "first frame after close: t−1 was drifted"
        );
        assert!(v.dirty_rects_into(62, &mut rects), "well after: hintable again");
        // Occlusion on another camera never perturbs this one.
        cfg.drift =
            DriftPlan::new().with(0.0, 12_000.0, DriftKind::Occlusion { camera: 7, frac: 0.3 });
        assert!(Video::new(cfg).dirty_rects_into(50, &mut rects));
    }

    #[test]
    fn different_traffic_seeds_share_scene() {
        let a = quick_video(1);
        let b = quick_video(2);
        assert_eq!(a.background(), b.background());
        assert_ne!(
            a.trajectories().len() * 1_000_000 + a.trajectories().first().map(|t| t.w).unwrap_or(0),
            b.trajectories().len() * 1_000_000 + b.trajectories().first().map(|t| t.w).unwrap_or(0),
        );
    }
}
