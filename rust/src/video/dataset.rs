//! Labeled dataset construction — the paper's evaluation corpus analogue.
//!
//! Paper §V-A: 25 videos from 7 scene seeds (3–4 videos per seed), sunny
//! weather, 15 min @ 10 fps, with per-camera traffic variation "from cars
//! always present to rarely appearing". We reproduce that structure with
//! a configurable frame count so experiments run at tractable scale.

use super::generator::{Video, VideoConfig};
use super::objects::TrafficConfig;
use crate::color::NamedColor;
use crate::util::rng::Rng;

/// Minimum blob size (pixels) for an object to count as a query target —
/// the ground-truth analogue of the query's blob-size filter.
pub const MIN_TARGET_PX: usize = 40;

/// Dataset shape parameters.
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    pub num_seeds: usize,
    pub videos_per_seed: usize,
    pub frames_per_video: usize,
    pub base_seed: u64,
    /// Scale on the default target-color appearance probability, to tune
    /// positive-frame density.
    pub target_boost: f64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            num_seeds: 7,
            videos_per_seed: 4,     // 7*4 = 28 generated, paper used 25
            frames_per_video: 900,  // 90 s @ 10 fps (paper: 15 min)
            base_seed: 0xDA7A_5E7,
            target_boost: 1.0,
        }
    }
}

impl DatasetConfig {
    /// A small config for unit/integration tests.
    pub fn tiny() -> Self {
        DatasetConfig {
            num_seeds: 2,
            videos_per_seed: 2,
            frames_per_video: 150,
            base_seed: 42,
            target_boost: 2.0,
        }
    }
}

/// Build the video corpus. Traffic density varies per camera: a linear
/// sweep from heavy ("cars always present") to sparse ("rarely appearing").
pub fn build_dataset(cfg: &DatasetConfig) -> Vec<Video> {
    let mut rng = Rng::new(cfg.base_seed);
    let total = cfg.num_seeds * cfg.videos_per_seed;
    let mut videos = Vec::with_capacity(total);
    let mut camera_id = 0u32;
    for seed_idx in 0..cfg.num_seeds {
        let scene_seed = cfg.base_seed ^ (1000 + seed_idx as u64);
        for v in 0..cfg.videos_per_seed {
            let density_t = camera_id as f64 / (total.max(2) - 1) as f64;
            let mut traffic = TrafficConfig::default_mix();
            // Heavy → sparse sweep across cameras.
            traffic.vehicle_rate = 0.9 - 0.8 * density_t;
            traffic.pedestrian_rate = 0.4 - 0.2 * density_t;
            if cfg.target_boost != 1.0 {
                for (p, w) in traffic.paint_weights.iter_mut() {
                    if matches!(
                        p,
                        super::frame::Paint::VividRed | super::frame::Paint::VividYellow
                    ) {
                        *w *= cfg.target_boost;
                    }
                }
            }
            let mut vc = VideoConfig::new(
                scene_seed,
                rng.next_u64() ^ (v as u64),
                camera_id,
                cfg.frames_per_video,
            );
            vc.traffic = traffic;
            videos.push(Video::new(vc));
            camera_id += 1;
        }
    }
    videos
}

/// Summary statistics of a dataset for a query color (used to pick
/// "videos that contained a decent number of target objects", §V-A).
#[derive(Debug, Clone)]
pub struct VideoStats {
    pub camera_id: u32,
    pub frames: usize,
    pub positive_frames: usize,
    pub distinct_targets: usize,
}

/// Per-video positive-frame statistics for a single color query.
pub fn video_stats(video: &Video, color: NamedColor) -> VideoStats {
    let mut positive = 0usize;
    let mut targets = std::collections::HashSet::new();
    for t in 0..video.len() {
        let truth = video.truth(t);
        let mut any = false;
        for o in &truth {
            if o.counts_for(color, MIN_TARGET_PX) {
                any = true;
                targets.insert(o.object_id);
            }
        }
        positive += any as usize;
    }
    VideoStats {
        camera_id: video.camera_id(),
        frames: video.len(),
        positive_frames: positive,
        distinct_targets: targets.len(),
    }
}

/// Keep only videos with at least `min_targets` distinct target objects
/// (the paper reports metrics over such videos).
pub fn filter_interesting(
    videos: Vec<Video>,
    color: NamedColor,
    min_targets: usize,
) -> Vec<Video> {
    videos
        .into_iter()
        .filter(|v| video_stats(v, color).distinct_targets >= min_targets)
        .collect()
}

/// Leave-one-out style split for cross-validation (paper §V-D): fold `k`
/// puts video `k` in the test set and the rest in training.
pub fn cross_validation_folds(n_videos: usize) -> Vec<(Vec<usize>, usize)> {
    (0..n_videos)
        .map(|k| ((0..n_videos).filter(|&i| i != k).collect(), k))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shape() {
        let cfg = DatasetConfig::tiny();
        let vids = build_dataset(&cfg);
        assert_eq!(vids.len(), 4);
        // Same scene within a seed group, different across groups.
        assert_eq!(vids[0].background(), vids[1].background());
        assert_ne!(vids[0].background(), vids[2].background());
        // Distinct cameras.
        let ids: Vec<u32> = vids.iter().map(|v| v.camera_id()).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn density_sweep_monotone() {
        let cfg = DatasetConfig {
            num_seeds: 1,
            videos_per_seed: 4,
            frames_per_video: 400,
            base_seed: 7,
            target_boost: 1.0,
        };
        let vids = build_dataset(&cfg);
        let veh_counts: Vec<usize> = vids
            .iter()
            .map(|v| {
                v.trajectories()
                    .iter()
                    .filter(|t| t.kind == crate::video::objects::Kind::Vehicle)
                    .count()
            })
            .collect();
        // First (dense) camera should see clearly more vehicles than last.
        assert!(
            veh_counts[0] > veh_counts[3],
            "densities not decreasing: {veh_counts:?}"
        );
    }

    #[test]
    fn stats_and_filter() {
        let vids = build_dataset(&DatasetConfig::tiny());
        let n = vids.len();
        let stats: Vec<VideoStats> = vids
            .iter()
            .map(|v| video_stats(v, NamedColor::Red))
            .collect();
        for s in &stats {
            assert_eq!(s.frames, 150);
            assert!(s.positive_frames <= s.frames);
        }
        let kept = filter_interesting(vids, NamedColor::Red, 1);
        assert!(kept.len() <= n);
    }

    #[test]
    fn cv_folds_cover_everything() {
        let folds = cross_validation_folds(5);
        assert_eq!(folds.len(), 5);
        for (train, test) in &folds {
            assert_eq!(train.len(), 4);
            assert!(!train.contains(test));
        }
        // Every video is a test video exactly once.
        let mut tests: Vec<usize> = folds.iter().map(|(_, t)| *t).collect();
        tests.sort_unstable();
        assert_eq!(tests, vec![0, 1, 2, 3, 4]);
    }
}
