//! Wire encoding for edge→backend frame transmission: the byte format
//! whose **actual size** drives the transport link's serialization time
//! (see [`crate::pipeline::transport`]).
//!
//! The paper's premise — shedding lets a query meet its latency bound
//! "with fewer compute and network resources" — only bites if bytes on
//! the wire are modeled. Two encodings:
//!
//! * **Raw** — u8 planes when every channel is integer-valued (what real
//!   cameras ship), a lossless f32 little-endian fallback otherwise. The
//!   size is the frame geometry; no temporal state.
//! * **Delta** — the transport analogue of the incremental feature
//!   engine's dirty-tile diffing ([`crate::features::incremental`]): the
//!   encoder keeps the previously shipped quantized frame per camera,
//!   diffs the new frame tile by tile, and ships only the dirty tiles
//!   (tile index + pixels). A **keyframe** (full u8 frame that resets
//!   decoder state) is emitted on the first frame, after any fallback,
//!   and when the dirty fraction exceeds the [`WireEncoding::Delta`]
//!   threshold `max_dirty_frac` — a scene cut would cost more as a diff
//!   than as a keyframe.
//!
//! Decoding is exact: [`WireDecoder`] reproduces the encoder's input
//! bit-for-bit on every mode (u8 modes because the input was
//! integer-valued, f32 mode by byte identity) — property-pinned by
//! `rust/tests/transport.rs`.
//!
//! ## Format
//!
//! Little-endian throughout. Every message starts with a 10-byte header:
//!
//! ```text
//! [0]     magic 0x57 ('W')
//! [1]     mode: 0 raw-u8, 1 raw-f32, 2 keyframe-u8, 3 delta-u8
//! [2..6]  camera id (u32)
//! [6..8]  width  (u16)
//! [8..10] height (u16)
//! ```
//!
//! Payloads: raw-u8 / keyframe-u8 carry `w*h*3` bytes; raw-f32 carries
//! `w*h*3` f32s (4 bytes each); delta-u8 carries a u32 dirty-tile count
//! followed by, per tile in ascending index order, the u32 tile index and
//! the tile's pixels (row-major within the clipped tile rect).

use anyhow::{bail, Result};

/// Header length in bytes (see the module docs for the layout).
pub const WIRE_HEADER_LEN: usize = 10;

const WIRE_MAGIC: u8 = 0x57;

/// How frames are serialized for the link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireEncoding {
    /// Stateless full-frame encoding (u8 planes, f32 fallback).
    Raw,
    /// Dirty-tile diff against the previously shipped frame, with
    /// keyframe fallback (first frame, fallback recovery, scene cuts).
    Delta {
        /// Tile side length in pixels (16 matches the incremental
        /// feature engine's granularity).
        tile: usize,
        /// Above this fraction of dirty tiles a keyframe is cheaper than
        /// a diff (headers per tile plus full-tile payloads).
        max_dirty_frac: f64,
    },
}

impl WireEncoding {
    /// The delta encoding at its default operating point. The keyframe
    /// threshold is high: a delta message only overtakes a keyframe in
    /// size near 100% dirty (8 bytes of header per ~770-byte tile), so
    /// the fallback exists for scene cuts and decoder hygiene, not as a
    /// byte optimum — and shipped frames can be temporally far apart
    /// under heavy shedding, which inflates dirty fractions.
    pub fn delta_default() -> WireEncoding {
        WireEncoding::Delta { tile: 16, max_dirty_frac: 0.85 }
    }
}

/// What one encoded message actually was (stats / tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireMode {
    /// Quantized full frame (every pixel exactly u8-representable).
    RawU8 = 0,
    /// Lossless f32 full frame (fallback for unquantizable pixels).
    RawF32 = 1,
    /// Delta-stream keyframe: a full frame that (re)sets the reference.
    Key = 2,
    /// Delta frame: only the dirty tiles against the reference.
    Delta = 3,
}

impl WireMode {
    fn from_byte(b: u8) -> Option<WireMode> {
        match b {
            0 => Some(WireMode::RawU8),
            1 => Some(WireMode::RawF32),
            2 => Some(WireMode::Key),
            3 => Some(WireMode::Delta),
            _ => None,
        }
    }
}

/// Decoded message header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireHeader {
    /// How the payload was encoded.
    pub mode: WireMode,
    /// Source camera id.
    pub camera: u32,
    /// Frame width in pixels.
    pub width: usize,
    /// Frame height in pixels.
    pub height: usize,
}

/// Raw-u8 wire size for a frame geometry — the "no compression" yardstick
/// (and the byte accounting of the ideal link, which never encodes).
pub fn raw_wire_size(width: usize, height: usize) -> usize {
    WIRE_HEADER_LEN + width * height * 3
}

// The feature layer's exact-representability quantizer: one definition
// of "integer frame" shared by the wire encoder and the LUT/incremental
// fast paths, so the two notions can never diverge.
use crate::features::fast::quantize as quantize_u8;

fn push_header(out: &mut Vec<u8>, mode: WireMode, camera: u32, width: usize, height: usize) {
    out.push(WIRE_MAGIC);
    out.push(mode as u8);
    out.extend_from_slice(&camera.to_le_bytes());
    out.extend_from_slice(&(width as u16).to_le_bytes());
    out.extend_from_slice(&(height as u16).to_le_bytes());
}

/// Stateful per-camera encoder. One encoder per camera: the delta state
/// is the last frame *shipped for that camera*, which is exactly what the
/// matching [`WireDecoder`] has reconstructed on the other end.
#[derive(Debug, Clone)]
pub struct WireEncoder {
    encoding: WireEncoding,
    width: usize,
    height: usize,
    /// Last shipped quantized frame (delta reference); valid only when
    /// `valid` is set.
    prev: Vec<u8>,
    /// Current-frame quantization scratch (swapped with `prev`).
    cur: Vec<u8>,
    /// Dirty-tile scratch, cleared per frame (keeps the encode path
    /// allocation-free after warmup, like the rest of the hot path).
    dirty: Vec<u32>,
    valid: bool,
    /// Messages emitted per mode: [raw_u8, raw_f32, key, delta].
    mode_counts: [u64; 4],
}

impl WireEncoder {
    /// A fresh encoder with no delta reference (first delta-mode frame
    /// will be a keyframe).
    pub fn new(encoding: WireEncoding) -> WireEncoder {
        if let WireEncoding::Delta { tile, .. } = encoding {
            assert!(tile > 0, "tile size must be positive");
        }
        WireEncoder {
            encoding,
            width: 0,
            height: 0,
            prev: Vec::new(),
            cur: Vec::new(),
            dirty: Vec::new(),
            valid: false,
            mode_counts: [0; 4],
        }
    }

    /// Messages emitted so far per mode: `[raw_u8, raw_f32, key, delta]`.
    pub fn mode_counts(&self) -> [u64; 4] {
        self.mode_counts
    }

    /// Drop the delta reference. The transport layer calls this when the
    /// link *loses* a message: the decoder never saw the frame this
    /// encoder diffed against, so the next message must be a keyframe to
    /// keep the two ends bit-coherent.
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    /// Encode one frame into `out` (cleared first); returns the mode
    /// actually used. The wire size is `out.len()`.
    pub fn encode_into(
        &mut self,
        camera: u32,
        width: usize,
        height: usize,
        rgb: &[f32],
        out: &mut Vec<u8>,
    ) -> WireMode {
        assert_eq!(rgb.len(), width * height * 3, "frame geometry mismatch");
        assert!(width <= u16::MAX as usize && height <= u16::MAX as usize);
        out.clear();
        if width != self.width || height != self.height {
            // Geometry change: the delta reference is meaningless.
            self.width = width;
            self.height = height;
            self.valid = false;
        }
        let mode = match self.encoding {
            WireEncoding::Raw => self.encode_raw(camera, rgb, out),
            WireEncoding::Delta { tile, max_dirty_frac } => {
                self.encode_delta(camera, rgb, tile, max_dirty_frac, out)
            }
        };
        self.mode_counts[mode as usize] += 1;
        mode
    }

    fn encode_raw(&mut self, camera: u32, rgb: &[f32], out: &mut Vec<u8>) -> WireMode {
        if quantize_u8(rgb, &mut self.cur) {
            push_header(out, WireMode::RawU8, camera, self.width, self.height);
            out.extend_from_slice(&self.cur);
            WireMode::RawU8
        } else {
            self.push_f32(camera, rgb, out);
            WireMode::RawF32
        }
    }

    fn push_f32(&mut self, camera: u32, rgb: &[f32], out: &mut Vec<u8>) {
        push_header(out, WireMode::RawF32, camera, self.width, self.height);
        out.reserve(rgb.len() * 4);
        for &x in rgb {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn encode_delta(
        &mut self,
        camera: u32,
        rgb: &[f32],
        tile: usize,
        max_dirty_frac: f64,
        out: &mut Vec<u8>,
    ) -> WireMode {
        if !quantize_u8(rgb, &mut self.cur) {
            // Non-integer frame: lossless f32 escape; the decoder drops
            // its delta state just like we do.
            self.valid = false;
            self.push_f32(camera, rgb, out);
            return WireMode::RawF32;
        }
        if !self.valid {
            return self.emit_key(camera, out);
        }

        let tiles_x = self.width.div_ceil(tile);
        let tiles_y = self.height.div_ceil(tile);
        let n_tiles = tiles_x * tiles_y;
        // Tile diff through the SIMD rect compare (shared with the
        // incremental feature engine, so the two scans cannot drift).
        let level = crate::simd::level();
        self.dirty.clear();
        for ti in 0..n_tiles {
            let rect = self.tile_rect(ti, tile, tiles_x);
            if crate::simd::rect_differs(level, &self.cur, &self.prev, self.width, rect) {
                self.dirty.push(ti as u32);
            }
        }
        if (self.dirty.len() as f64) > max_dirty_frac * n_tiles as f64 {
            // Scene cut: a keyframe is smaller and resets cleanly.
            return self.emit_key(camera, out);
        }

        push_header(out, WireMode::Delta, camera, self.width, self.height);
        out.extend_from_slice(&(self.dirty.len() as u32).to_le_bytes());
        for &ti in &self.dirty {
            out.extend_from_slice(&ti.to_le_bytes());
            let tx = ti as usize % tiles_x;
            let ty = ti as usize / tiles_x;
            let x0 = tx * tile;
            let y0 = ty * tile;
            let (x1, y1) = ((x0 + tile).min(self.width), (y0 + tile).min(self.height));
            for y in y0..y1 {
                let a = 3 * (y * self.width + x0);
                let b = 3 * (y * self.width + x1);
                out.extend_from_slice(&self.cur[a..b]);
            }
        }
        std::mem::swap(&mut self.prev, &mut self.cur);
        WireMode::Delta
    }

    fn emit_key(&mut self, camera: u32, out: &mut Vec<u8>) -> WireMode {
        push_header(out, WireMode::Key, camera, self.width, self.height);
        out.extend_from_slice(&self.cur);
        std::mem::swap(&mut self.prev, &mut self.cur);
        self.valid = true;
        WireMode::Key
    }

    #[inline]
    fn tile_rect(&self, ti: usize, tile: usize, tiles_x: usize) -> (usize, usize, usize, usize) {
        let tx = ti % tiles_x;
        let ty = ti / tiles_x;
        let x0 = tx * tile;
        let y0 = ty * tile;
        (x0, y0, (x0 + tile).min(self.width), (y0 + tile).min(self.height))
    }
}

/// Stateful per-camera decoder: mirrors the encoder's delta reference so
/// `decode(encode(frame))` reproduces `frame` exactly along any shipped
/// sequence.
#[derive(Debug, Clone, Default)]
pub struct WireDecoder {
    prev: Vec<u8>,
    width: usize,
    height: usize,
    valid: bool,
    /// The delta tile side — part of the stream's encoder config, not
    /// the message header, so it must be supplied via [`Self::with_tile`]
    /// before the first delta message (raw/f32/keyframe messages decode
    /// without it; a delta message without it is an error).
    tile: usize,
}

impl WireDecoder {
    /// A fresh decoder with no reconstructed reference frame.
    pub fn new() -> WireDecoder {
        WireDecoder::default()
    }

    /// Set the delta tile size (must match the encoder's). Raw/key/f32
    /// messages decode without it.
    pub fn with_tile(mut self, tile: usize) -> WireDecoder {
        self.tile = tile;
        self
    }

    /// Decode one message into `out` (H*W*3 f32, cleared first).
    pub fn decode_into(&mut self, bytes: &[u8], out: &mut Vec<f32>) -> Result<WireHeader> {
        if bytes.len() < WIRE_HEADER_LEN {
            bail!("wire message shorter than header ({} bytes)", bytes.len());
        }
        if bytes[0] != WIRE_MAGIC {
            bail!("bad wire magic {:#x}", bytes[0]);
        }
        let mode = WireMode::from_byte(bytes[1])
            .ok_or_else(|| anyhow::anyhow!("unknown wire mode {}", bytes[1]))?;
        let camera = u32::from_le_bytes(bytes[2..6].try_into().unwrap());
        let width = u16::from_le_bytes(bytes[6..8].try_into().unwrap()) as usize;
        let height = u16::from_le_bytes(bytes[8..10].try_into().unwrap()) as usize;
        let n = width * height * 3;
        let payload = &bytes[WIRE_HEADER_LEN..];
        let header = WireHeader { mode, camera, width, height };

        match mode {
            WireMode::RawU8 => {
                if payload.len() != n {
                    bail!("raw-u8 payload {} bytes, want {n}", payload.len());
                }
                out.clear();
                out.extend(payload.iter().map(|&b| b as f32));
            }
            WireMode::RawF32 => {
                if payload.len() != n * 4 {
                    bail!("raw-f32 payload {} bytes, want {}", payload.len(), n * 4);
                }
                out.clear();
                out.extend(
                    payload
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
                );
                // The encoder dropped its delta state on this escape.
                self.valid = false;
            }
            WireMode::Key => {
                if payload.len() != n {
                    bail!("keyframe payload {} bytes, want {n}", payload.len());
                }
                self.prev.clear();
                self.prev.extend_from_slice(payload);
                self.width = width;
                self.height = height;
                self.valid = true;
                out.clear();
                out.extend(payload.iter().map(|&b| b as f32));
            }
            WireMode::Delta => {
                if !self.valid || self.width != width || self.height != height {
                    bail!("delta message without a matching keyframe reference");
                }
                if self.tile == 0 {
                    bail!("delta decoding needs the encoder's tile size (with_tile)");
                }
                if payload.len() < 4 {
                    bail!("delta payload truncated");
                }
                let n_dirty = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
                let tiles_x = width.div_ceil(self.tile);
                let tiles_y = height.div_ceil(self.tile);
                let mut off = 4;
                for _ in 0..n_dirty {
                    if payload.len() < off + 4 {
                        bail!("delta payload truncated at tile index");
                    }
                    let ti =
                        u32::from_le_bytes(payload[off..off + 4].try_into().unwrap()) as usize;
                    off += 4;
                    if ti >= tiles_x * tiles_y {
                        bail!("delta tile index {ti} out of range");
                    }
                    let tx = ti % tiles_x;
                    let ty = ti / tiles_x;
                    let x0 = tx * self.tile;
                    let y0 = ty * self.tile;
                    let x1 = (x0 + self.tile).min(width);
                    let y1 = (y0 + self.tile).min(height);
                    for y in y0..y1 {
                        let a = 3 * (y * width + x0);
                        let b = 3 * (y * width + x1);
                        if payload.len() < off + (b - a) {
                            bail!("delta payload truncated inside tile {ti}");
                        }
                        self.prev[a..b].copy_from_slice(&payload[off..off + (b - a)]);
                        off += b - a;
                    }
                }
                if off != payload.len() {
                    bail!("delta payload has {} trailing bytes", payload.len() - off);
                }
                out.clear();
                out.extend(self.prev.iter().map(|&b| b as f32));
            }
        }
        Ok(header)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn int_frame(rng: &mut Rng, n_px: usize) -> Vec<f32> {
        (0..n_px * 3).map(|_| rng.below(256) as f32).collect()
    }

    #[test]
    fn raw_u8_roundtrip_and_size() {
        let mut rng = Rng::new(0x31);
        let (w, h) = (24, 16);
        let rgb = int_frame(&mut rng, w * h);
        let mut enc = WireEncoder::new(WireEncoding::Raw);
        let mut buf = Vec::new();
        let mode = enc.encode_into(3, w, h, &rgb, &mut buf);
        assert_eq!(mode, WireMode::RawU8);
        assert_eq!(buf.len(), raw_wire_size(w, h));
        let mut dec = WireDecoder::new();
        let mut out = Vec::new();
        let hdr = dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(hdr, WireHeader { mode, camera: 3, width: w, height: h });
        assert_eq!(out, rgb);
    }

    #[test]
    fn float_frames_escape_to_f32_losslessly() {
        let (w, h) = (8, 8);
        let mut rng = Rng::new(0x32);
        let mut rgb = int_frame(&mut rng, w * h);
        rgb[5] += 0.25;
        rgb[100] = 1e-3;
        let mut enc = WireEncoder::new(WireEncoding::delta_default());
        let mut buf = Vec::new();
        assert_eq!(enc.encode_into(0, w, h, &rgb, &mut buf), WireMode::RawF32);
        let mut dec = WireDecoder::new().with_tile(16);
        let mut out = Vec::new();
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, rgb); // bit-exact f32 round trip
    }

    #[test]
    fn delta_stream_key_then_diffs_then_key_on_cut() {
        let mut rng = Rng::new(0x33);
        let (w, h) = (48, 32);
        let base = int_frame(&mut rng, w * h);
        let mut enc = WireEncoder::new(WireEncoding::Delta { tile: 16, max_dirty_frac: 0.4 });
        let mut dec = WireDecoder::new().with_tile(16);
        let (mut buf, mut out) = (Vec::new(), Vec::new());

        // First frame: keyframe, full size.
        assert_eq!(enc.encode_into(1, w, h, &base, &mut buf), WireMode::Key);
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, base);

        // Small change: delta, much smaller than raw.
        let mut moved = base.clone();
        for p in 0..10 {
            moved[3 * p] = (moved[3 * p] + 7.0) % 256.0;
        }
        assert_eq!(enc.encode_into(1, w, h, &moved, &mut buf), WireMode::Delta);
        assert!(buf.len() < raw_wire_size(w, h) / 4, "delta {} bytes", buf.len());
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, moved);

        // Unchanged frame: header + count only.
        assert_eq!(enc.encode_into(1, w, h, &moved, &mut buf), WireMode::Delta);
        assert_eq!(buf.len(), WIRE_HEADER_LEN + 4);
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, moved);

        // Scene cut: everything dirty → keyframe fallback.
        let cut = int_frame(&mut rng, w * h);
        assert_eq!(enc.encode_into(1, w, h, &cut, &mut buf), WireMode::Key);
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, cut);
        assert_eq!(enc.mode_counts(), [0, 0, 2, 2]);
    }

    #[test]
    fn delta_recovers_after_float_escape() {
        let mut rng = Rng::new(0x34);
        let (w, h) = (16, 16);
        let a = int_frame(&mut rng, w * h);
        let mut b = a.clone();
        b[0] = 0.5; // forces the f32 escape
        let c = a.clone();
        let mut enc = WireEncoder::new(WireEncoding::delta_default());
        let mut dec = WireDecoder::new().with_tile(16);
        let (mut buf, mut out) = (Vec::new(), Vec::new());
        assert_eq!(enc.encode_into(0, w, h, &a, &mut buf), WireMode::Key);
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(enc.encode_into(0, w, h, &b, &mut buf), WireMode::RawF32);
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, b);
        // State was invalidated on both ends → keyframe, not delta.
        assert_eq!(enc.encode_into(0, w, h, &c, &mut buf), WireMode::Key);
        dec.decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, c);
    }

    #[test]
    fn delta_without_keyframe_is_rejected() {
        let mut rng = Rng::new(0x35);
        let (w, h) = (16, 16);
        let a = int_frame(&mut rng, w * h);
        let mut enc = WireEncoder::new(WireEncoding::delta_default());
        let (mut buf, mut out) = (Vec::new(), Vec::new());
        enc.encode_into(0, w, h, &a, &mut buf);
        let mut delta_msg = Vec::new();
        // Force a real delta message…
        let mut tiny = a.clone();
        tiny[0] = (tiny[0] + 1.0) % 256.0;
        assert_eq!(enc.encode_into(0, w, h, &tiny, &mut delta_msg), WireMode::Delta);
        // …and decode it on a decoder that never saw the keyframe.
        let mut fresh = WireDecoder::new().with_tile(16);
        assert!(fresh.decode_into(&delta_msg, &mut out).is_err());
    }
}
