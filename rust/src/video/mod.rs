//! Synthetic video substrate — the VisualRoad/CARLA substitution.
//!
//! Deterministic, seedable road-scene videos with per-frame ground truth
//! (object ids, paints, bounding boxes) so QoR (paper Eq. 2/3) can be
//! computed exactly. See DESIGN.md §2 for the substitution argument.

#[allow(missing_docs)] // item docs pending; module docs present
pub mod dataset;
pub mod drift;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod frame;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod generator;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod objects;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod scene;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod segments;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod streamer;
pub mod wire;

pub use dataset::{build_dataset, DatasetConfig, MIN_TARGET_PX};
pub use drift::{DriftKind, DriftPlan, DriftWindow};
pub use frame::{Frame, Paint, VisibleObject};
pub use generator::{Video, VideoConfig};
pub use objects::{Kind, TrafficConfig, Trajectory};
pub use scene::Scene;
pub use segments::{SegmentKind, SegmentedVideo};
pub use streamer::Streamer;
pub use wire::{raw_wire_size, WireDecoder, WireEncoder, WireEncoding, WireHeader, WireMode};
