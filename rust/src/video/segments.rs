//! Segment-stitched videos for the synthetic worst-case scenario
//! (paper §V-E.1, Fig. 13a): a 3-segment video —
//!
//!   1. low-utility frames, no target objects (light dull traffic),
//!   2. high-utility frames *with* target objects (burst of vivid targets),
//!   3. high-utility frames with *no* targets: a swarm of small vivid-red
//!      objects (red-clothed pedestrians). Utility is high (vivid target-
//!      hue pixels in high-sat bins) but every blob is below the query's
//!      minimum size, so the backend's first filter drops these frames
//!      cheaply — the paper's expectation that segment 3 "has an execution
//!      profile similar to the first segment".
//!
//! The paper obtained these by stitching VisualRoad excerpts "known
//! a-priori to have those properties"; we synthesize each segment's
//! traffic mix directly.

use super::frame::{Frame, Paint};
use super::generator::{Video, VideoConfig};
use super::objects::TrafficConfig;

/// Which burst profile a segment uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// Sparse dull traffic: low utility, cheap backend (filter drops all).
    LowUtilityNoObjects,
    /// Dense vivid *target* traffic: high utility, expensive backend.
    HighUtilityWithObjects,
    /// Swarm of small vivid target-hue objects (pedestrians): high utility
    /// but no query targets and sub-min-blob sizes ⇒ cheap backend.
    HighUtilityNoTargets,
}

impl SegmentKind {
    fn traffic(self, target: Paint) -> TrafficConfig {
        let mut t = TrafficConfig::default_mix();
        match self {
            SegmentKind::LowUtilityNoObjects => {
                t.vehicle_rate = 0.12;
                t.pedestrian_rate = 0.2;
                t.paint_weights = vec![
                    (Paint::Gray, 0.35),
                    (Paint::Black, 0.25),
                    (Paint::Silver, 0.2),
                    (Paint::Brown, 0.1),
                    (Paint::DullRed, 0.1),
                ];
            }
            SegmentKind::HighUtilityWithObjects => {
                t.vehicle_rate = 0.8;
                t.pedestrian_rate = 0.3;
                t.paint_weights = vec![
                    (target, 0.45),
                    (Paint::Gray, 0.2),
                    (Paint::Silver, 0.15),
                    (Paint::Black, 0.1),
                    (Paint::DullRed, 0.1),
                ];
            }
            SegmentKind::HighUtilityNoTargets => {
                t.vehicle_rate = 0.02; // near-empty road
                // Sparse enough that pedestrian blobs stay below the
                // query's min blob size (a dense crowd would merge into
                // one large blob and defeat the cheap-filter premise).
                t.pedestrian_rate = 0.8;
                t.paint_weights = vec![(Paint::Gray, 1.0)];
                t.pedestrian_weights = vec![(target, 1.0)]; // all target-colored
            }
        }
        t
    }
}

/// A video made of consecutive segments sharing one scene.
pub struct SegmentedVideo {
    segments: Vec<(Video, usize)>, // (video, frames)
    fps: f64,
    camera_id: u32,
}

impl SegmentedVideo {
    /// Build the Fig-13a scenario: each segment `frames_per_segment` long.
    /// `target` is the query color's vivid paint.
    pub fn fig13a(scene_seed: u64, frames_per_segment: usize, target: Paint) -> Self {
        let kinds = [
            SegmentKind::LowUtilityNoObjects,
            SegmentKind::HighUtilityWithObjects,
            SegmentKind::HighUtilityNoTargets,
        ];
        let mut segments = Vec::new();
        for (i, kind) in kinds.iter().enumerate() {
            let mut cfg = VideoConfig::new(scene_seed, 0xF13A + i as u64, 0, frames_per_segment);
            cfg.traffic = kind.traffic(target);
            segments.push((Video::new(cfg), frames_per_segment));
        }
        SegmentedVideo { segments, fps: 10.0, camera_id: 0 }
    }

    pub fn len(&self) -> usize {
        self.segments.iter().map(|(_, n)| n).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn fps(&self) -> f64 {
        self.fps
    }

    /// The background model (shared scene across segments).
    pub fn background(&self) -> &[f32] {
        self.segments[0].0.background()
    }

    /// Which segment index a global frame t falls into.
    pub fn segment_of(&self, t: usize) -> usize {
        let mut acc = 0;
        for (i, (_, n)) in self.segments.iter().enumerate() {
            acc += n;
            if t < acc {
                return i;
            }
        }
        self.segments.len() - 1
    }

    /// Render global frame `t`, remapping timestamp and object ids so the
    /// stitched video looks like one continuous camera.
    pub fn render(&self, t: usize) -> Frame {
        let mut offset = 0usize;
        for (si, (video, n)) in self.segments.iter().enumerate() {
            if t < offset + n {
                let local = t - offset;
                let mut f = video.render(local);
                f.index = t;
                f.ts_ms = t as f64 / self.fps * 1e3;
                f.camera = self.camera_id;
                // Namespace object ids per segment to keep them unique.
                for o in f.truth.iter_mut() {
                    o.object_id += (si as u64) << 32;
                }
                return f;
            }
            offset += n;
        }
        unreachable!("frame {t} out of range")
    }

    pub fn iter(&self) -> impl Iterator<Item = Frame> + '_ {
        (0..self.len()).map(move |t| self.render(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::video::dataset::MIN_TARGET_PX;

    #[test]
    fn three_segments_structure() {
        let sv = SegmentedVideo::fig13a(5, 100, Paint::VividRed);
        assert_eq!(sv.len(), 300);
        assert_eq!(sv.segment_of(0), 0);
        assert_eq!(sv.segment_of(100), 1);
        assert_eq!(sv.segment_of(299), 2);
    }

    #[test]
    fn segment_content_properties() {
        let sv = SegmentedVideo::fig13a(5, 150, Paint::VividRed);
        let positives = |lo: usize, hi: usize| -> usize {
            (lo..hi)
                .filter(|&t| sv.render(t).is_positive(NamedColor::Red, MIN_TARGET_PX))
                .count()
        };
        let seg1 = positives(0, 150);
        let seg2 = positives(150, 300);
        let seg3 = positives(300, 450);
        // Middle segment is where the red targets live.
        assert!(seg2 > 40, "segment 2 has too few positives: {seg2}");
        assert!(seg1 == 0, "segment 1 should have no targets: {seg1}");
        assert!(seg3 == 0, "segment 3 should have no red targets: {seg3}");
        // Segment 3 still carries plenty of vivid-red *pixels* (small
        // pedestrian blobs) — high utility, no targets.
        let mut red_px = 0usize;
        for t in (300..450).step_by(10) {
            let f = sv.render(t);
            red_px += f
                .truth
                .iter()
                .filter(|o| !o.is_vehicle && o.paint == Paint::VividRed)
                .map(|o| o.visible_px)
                .sum::<usize>();
        }
        assert!(red_px > 200, "segment 3 lacks vivid-red pedestrians: {red_px}");
    }

    #[test]
    fn timestamps_continuous() {
        let sv = SegmentedVideo::fig13a(5, 50, Paint::VividRed);
        let frames: Vec<Frame> = sv.iter().collect();
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.index, i);
            assert!((f.ts_ms - i as f64 * 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn object_ids_unique_across_segments() {
        let sv = SegmentedVideo::fig13a(6, 80, Paint::VividRed);
        use std::collections::HashMap;
        // id -> segment set; an id must never appear in two segments.
        let mut seen: HashMap<u64, usize> = HashMap::new();
        for t in 0..sv.len() {
            let seg = sv.segment_of(t);
            for o in sv.render(t).truth {
                if let Some(&s) = seen.get(&o.object_id) {
                    assert_eq!(s, seg, "object {} in segments {} and {}", o.object_id, s, seg);
                } else {
                    seen.insert(o.object_id, seg);
                }
            }
        }
    }
}
