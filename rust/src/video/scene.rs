//! Static scene synthesis: the per-seed camera backdrop.
//!
//! Substitutes VisualRoad/CARLA's rendered city (DESIGN.md §2): a road band
//! with lanes, a building skyline (including dull-red/brown facades — the
//! hue confounders of paper Fig. 5a), sky and sidewalk. The *clean* render
//! is also what the camera's background-subtraction stage uses as its
//! background model.

use crate::util::rng::Rng;

/// Per-seed scene geometry and palette.
#[derive(Debug, Clone)]
pub struct Scene {
    pub width: usize,
    pub height: usize,
    /// Road band rows [road_y0, road_y1).
    pub road_y0: usize,
    pub road_y1: usize,
    /// Lane row spans, top to bottom: (y0, y1, direction) with direction
    /// +1 = left→right, -1 = right→left.
    pub lanes: Vec<(usize, usize, i8)>,
    /// Sidewalk band rows for pedestrians.
    pub walk_y0: usize,
    pub walk_y1: usize,
    /// The clean (noise-free) background image, row-major H*W*3.
    background: Vec<f32>,
}

impl Scene {
    /// Build a scene from a camera seed. Layout parameters (horizon, road
    /// position, lane count, building palette) are seed-derived, mirroring
    /// VisualRoad's camera-placement `seed` knob.
    pub fn generate(seed: u64, width: usize, height: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x5ce0_5ce0);
        let h = height as f64;
        let horizon = (h * rng.range_f64(0.22, 0.34)) as usize;
        let road_y0 = (h * rng.range_f64(0.40, 0.50)) as usize;
        let road_h = (h * rng.range_f64(0.30, 0.40)) as usize;
        let road_y1 = (road_y0 + road_h).min(height - 8);
        let n_lanes = rng.range(2, 5); // 2..4 lanes
        let lane_h = (road_y1 - road_y0) / n_lanes;
        let mut lanes = Vec::new();
        for l in 0..n_lanes {
            let y0 = road_y0 + l * lane_h;
            let y1 = if l == n_lanes - 1 { road_y1 } else { y0 + lane_h };
            // Top lanes flow right→left, bottom lanes left→right (two-way road).
            let dir = if l < n_lanes / 2 { -1 } else { 1 };
            lanes.push((y0, y1, dir));
        }
        let walk_y0 = road_y1 + 1;
        let walk_y1 = height;

        let mut background = vec![0.0f32; width * height * 3];
        paint_scene(
            &mut background,
            width,
            height,
            horizon,
            road_y0,
            road_y1,
            &lanes,
            &mut rng,
        );

        Scene { width, height, road_y0, road_y1, lanes, walk_y0, walk_y1, background }
    }

    /// The clean background image (the camera's background model).
    pub fn background(&self) -> &[f32] {
        &self.background
    }

    pub fn lane_height(&self) -> usize {
        let (y0, y1, _) = self.lanes[0];
        y1 - y0
    }
}

#[allow(clippy::too_many_arguments)]
fn paint_scene(
    img: &mut [f32],
    width: usize,
    height: usize,
    horizon: usize,
    road_y0: usize,
    road_y1: usize,
    lanes: &[(usize, usize, i8)],
    rng: &mut Rng,
) {
    let put = |img: &mut [f32], x: usize, y: usize, c: [f32; 3]| {
        let i = (y * width + x) * 3;
        img[i] = c[0];
        img[i + 1] = c[1];
        img[i + 2] = c[2];
    };

    // Sky: pale blue-gray gradient.
    for y in 0..horizon {
        let t = y as f32 / horizon.max(1) as f32;
        let c = [168.0 + 20.0 * t, 186.0 + 14.0 * t, 205.0 + 8.0 * t];
        for x in 0..width {
            put(img, x, y, c);
        }
    }

    // Ground / verge between horizon and road, and below road.
    for y in horizon..height {
        for x in 0..width {
            put(img, x, y, [138.0, 134.0, 126.0]);
        }
    }

    // Building skyline: rectangles with dull facades. Crucially some are
    // *red-hued but unsaturated* (brick/brown), so negative frames still
    // carry red-hue pixels — the overlap that defeats HF-only shedding.
    let facade_palette: [[f32; 3]; 6] = [
        [142.0, 98.0, 88.0],   // dull brick
        [126.0, 84.0, 72.0],   // darker brick
        [150.0, 140.0, 124.0], // tan
        [120.0, 126.0, 134.0], // blue-gray
        [140.0, 128.0, 110.0], // sandstone
        [110.0, 104.0, 98.0],  // concrete
    ];
    let n_buildings = rng.range(4, 9);
    let mut x = 0usize;
    for _ in 0..n_buildings {
        if x >= width {
            break;
        }
        let bw = rng.range(width / 10, width / 4 + 1);
        let top = rng.range(horizon / 3, horizon.max(1));
        let color = *rng.choose(&facade_palette);
        let x1 = (x + bw).min(width);
        for yy in top..road_y0 {
            for xx in x..x1 {
                put(img, xx, yy, color);
            }
        }
        // Windows: darker inset pixels on a grid.
        let win = [color[0] * 0.45, color[1] * 0.45, color[2] * 0.55];
        for yy in (top + 2..road_y0.saturating_sub(2)).step_by(4) {
            for xx in (x + 2..x1.saturating_sub(1)).step_by(4) {
                put(img, xx, yy, win);
                if xx + 1 < x1 {
                    put(img, xx + 1, yy, win);
                }
            }
        }
        x = x1 + rng.range(0, 3);
    }

    // Road: asphalt with subtle per-pixel texture.
    for y in road_y0..road_y1 {
        for x in 0..width {
            let tex = (rng.f32() - 0.5) * 6.0;
            put(img, x, y, [96.0 + tex, 96.0 + tex, 100.0 + tex]);
        }
    }

    // Lane separators: dashed pale lines on interior boundaries.
    for w in lanes.windows(2) {
        let y = w[0].1;
        if y >= road_y1 {
            continue;
        }
        for x in (0..width).step_by(8) {
            for dx in 0..4 {
                if x + dx < width {
                    put(img, x + dx, y, [205.0, 203.0, 188.0]);
                }
            }
        }
    }

    // Sidewalk below the road.
    for y in road_y1..height {
        for x in 0..width {
            let tex = (rng.f32() - 0.5) * 4.0;
            put(img, x, y, [158.0 + tex, 155.0 + tex, 148.0 + tex]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::hsv::rgb_to_hsv;
    use crate::color::NamedColor;

    #[test]
    fn deterministic_per_seed() {
        let a = Scene::generate(3, 96, 96);
        let b = Scene::generate(3, 96, 96);
        assert_eq!(a.background(), b.background());
        let c = Scene::generate(4, 96, 96);
        assert_ne!(a.background(), c.background());
    }

    #[test]
    fn geometry_sane() {
        for seed in 0..20 {
            let s = Scene::generate(seed, 96, 96);
            assert!(s.road_y0 < s.road_y1 && s.road_y1 < s.height);
            assert!(s.lanes.len() >= 2 && s.lanes.len() <= 4);
            assert!(s.lane_height() >= 6, "lanes too thin: {}", s.lane_height());
            assert!(s.lanes.iter().any(|&(_, _, d)| d == 1));
            assert!(s.lanes.iter().any(|&(_, _, d)| d == -1));
            assert_eq!(s.background().len(), 96 * 96 * 3);
        }
    }

    #[test]
    fn background_contains_red_hue_confounders() {
        // The skyline must put *some* red-hue low-sat pixels in frame —
        // the paper's Fig 5a overlap depends on it.
        let red = NamedColor::Red.ranges();
        let mut red_hue = 0usize;
        let mut red_hue_low_sat = 0usize;
        for seed in 0..7 {
            let s = Scene::generate(seed, 96, 96);
            for px in s.background().chunks_exact(3) {
                let (h, sat, _) = rgb_to_hsv(px[0], px[1], px[2]);
                if red.contains(h) {
                    red_hue += 1;
                    if sat < 128.0 {
                        red_hue_low_sat += 1;
                    }
                }
            }
        }
        assert!(red_hue > 500, "too few red-hue background pixels: {red_hue}");
        // They should be predominantly unsaturated (dull).
        assert!(red_hue_low_sat as f64 > 0.9 * red_hue as f64);
    }

    #[test]
    fn pixel_values_in_range() {
        let s = Scene::generate(11, 96, 96);
        for &v in s.background() {
            assert!((0.0..=255.0).contains(&v), "pixel {v}");
        }
    }
}
