//! Deterministic content-drift transforms for the synthetic generator.
//!
//! A [`DriftPlan`] schedules **virtual-time drift windows** — a global
//! illumination ramp (day/night), a hue shift (weather / white-balance
//! drift), a per-camera occlusion mask (lens fouling), and an
//! object-surge rate multiplier (flash crowds) — that
//! [`crate::video::Video`] consults at render time. Every transform is a
//! pure function of the frame's virtual timestamp and the plan's seed,
//! so a drifted stream renders identically under `SimClock` and
//! `WallClock`, mirroring [`crate::pipeline::faults::FaultPlan`]'s
//! window design.
//!
//! The **empty plan is the verification mode**: every query
//! short-circuits on `windows.is_empty()`, so a video built with
//! `DriftPlan::default()` performs zero extra RNG draws and renders
//! bit-identical pixels to an undrifted build — pinned by
//! `rust/tests/drift.rs` the same way `faults.rs` pins the empty
//! `FaultPlan`.
//!
//! Ramp semantics: `IlluminationRamp` and `HueShift` apply their full
//! magnitude scaled by a triangular profile over the window (0 at the
//! edges, 1 at the midpoint) — drift arrives and recedes gradually, the
//! regime the online adaptation loop must track. `Occlusion` and
//! `ObjectSurge` are step transforms: full effect while covered.

use crate::util::rng::Rng;

/// One drift mode, active over a window's `[start_ms, end_ms)` span.
#[derive(Debug, Clone, PartialEq)]
pub enum DriftKind {
    /// Every channel of every pixel shifts by `delta` (scaled by the
    /// window's triangular ramp, clamped to [0, 255] after). Negative
    /// delta darkens (dusk), positive washes out (glare).
    IlluminationRamp { delta: f32 },
    /// Every pixel's hue rotates by `degrees` (full degrees, scaled by
    /// the ramp) around the hue circle; saturation/value are preserved.
    HueShift { degrees: f32 },
    /// A seeded dirt patch covers ~`frac` of camera `camera`'s frame
    /// area; pixels under it blend heavily toward a dark smear while
    /// ground truth is unchanged — the utility model goes blind there.
    Occlusion { camera: u32, frac: f64 },
    /// Extra seeded traffic at `multiplier`× the base vehicle rate
    /// appears (and counts as ground truth) while the window covers the
    /// frame — a flash crowd.
    ObjectSurge { multiplier: f64 },
}

/// A half-open virtual-time window `[start_ms, end_ms)` of one drift.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftWindow {
    /// Window start (virtual ms, inclusive).
    pub start_ms: f64,
    /// Window end (virtual ms, exclusive).
    pub end_ms: f64,
    /// The drift active inside the window.
    pub kind: DriftKind,
}

impl DriftWindow {
    /// Is virtual time `t` inside this window?
    pub fn covers(&self, t: f64) -> bool {
        t >= self.start_ms && t < self.end_ms
    }

    /// Triangular ramp profile: 0 at the window edges, 1 at the
    /// midpoint, 0 outside. Gradual drift is the hard case for an
    /// online adapter (no sharp change point to detect).
    pub fn ramp(&self, t: f64) -> f64 {
        if !self.covers(t) || self.end_ms <= self.start_ms {
            return 0.0;
        }
        let x = (t - self.start_ms) / (self.end_ms - self.start_ms);
        (1.0 - (2.0 * x - 1.0).abs()).clamp(0.0, 1.0)
    }
}

/// A schedule of drift windows. `DriftPlan::default()` is the empty
/// plan — the verification mode, bit-identical to an undrifted stream.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DriftPlan {
    windows: Vec<DriftWindow>,
    has_surge: bool,
}

impl DriftPlan {
    /// The empty plan (same as `DriftPlan::default()`).
    pub fn new() -> Self {
        DriftPlan::default()
    }

    /// Builder: add a drift window. Windows may overlap freely.
    pub fn with(mut self, start_ms: f64, end_ms: f64, kind: DriftKind) -> Self {
        self.push(start_ms, end_ms, kind);
        self
    }

    /// Add a drift window in place.
    pub fn push(&mut self, start_ms: f64, end_ms: f64, kind: DriftKind) {
        debug_assert!(
            start_ms.is_finite() && end_ms.is_finite() && start_ms <= end_ms,
            "drift window must be finite and ordered: [{start_ms}, {end_ms})"
        );
        if matches!(kind, DriftKind::ObjectSurge { .. }) {
            self.has_surge = true;
        }
        self.windows.push(DriftWindow { start_ms, end_ms, kind });
    }

    /// True when no drift windows are scheduled.
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// All scheduled windows, in insertion order.
    pub fn windows(&self) -> &[DriftWindow] {
        &self.windows
    }

    /// Summed ramped illumination delta at `t` (0.0 outside every
    /// illumination window).
    pub fn illumination_delta(&self, t: f64) -> f32 {
        if self.windows.is_empty() {
            return 0.0;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                DriftKind::IlluminationRamp { delta } => {
                    Some(delta * w.ramp(t) as f32)
                }
                _ => None,
            })
            .sum()
    }

    /// Summed ramped hue rotation (full degrees) at `t`.
    pub fn hue_shift_degrees(&self, t: f64) -> f32 {
        if self.windows.is_empty() {
            return 0.0;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                DriftKind::HueShift { degrees } => Some(degrees * w.ramp(t) as f32),
                _ => None,
            })
            .sum()
    }

    /// Occluded area fraction for camera `camera` at `t` (the largest
    /// covering occlusion wins; 0.0 outside every window).
    pub fn occlusion_frac(&self, camera: u32, t: f64) -> f64 {
        if self.windows.is_empty() {
            return 0.0;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                DriftKind::Occlusion { camera: c, frac } if c == camera && w.covers(t) => {
                    Some(frac)
                }
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    /// Object-surge rate multiplier at `t` (the largest covering surge
    /// wins; 1.0 outside every surge window).
    pub fn surge_multiplier(&self, t: f64) -> f64 {
        if !self.has_surge {
            return 1.0;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                DriftKind::ObjectSurge { multiplier } if w.covers(t) => Some(multiplier),
                _ => None,
            })
            .fold(1.0, f64::max)
    }

    /// Does the plan contain any surge window at all? Gates the surge
    /// trajectory pool so the empty plan draws zero extra RNG.
    pub fn has_object_surge(&self) -> bool {
        self.has_surge
    }

    /// The plan's largest surge multiplier across all windows (1.0 when
    /// there are none). Sizes the precomputed surge trajectory pool.
    pub fn peak_surge_multiplier(&self) -> f64 {
        if !self.has_surge {
            return 1.0;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                DriftKind::ObjectSurge { multiplier } => Some(multiplier),
                _ => None,
            })
            .fold(1.0, f64::max)
    }

    /// Does any window perturb camera `camera`'s *pixels* at `t`?
    /// Pixel-level transforms break the generator's dirty-rect
    /// contract, so [`crate::video::Video::dirty_rects_into`] must
    /// refuse hints while (or adjacent to) an active window. Occlusion
    /// is camera-scoped; everything else is global.
    pub fn perturbs(&self, camera: u32, t: f64) -> bool {
        if self.windows.is_empty() {
            return false;
        }
        self.windows.iter().any(|w| {
            w.covers(t)
                && match w.kind {
                    DriftKind::Occlusion { camera: c, .. } => c == camera,
                    _ => true,
                }
        })
    }

    /// A seeded random drift schedule over `[0, horizon_ms)` across
    /// `cameras` cameras: 2–4 windows of uniformly-drawn kinds, each
    /// starting in `[0.1, 0.6]·horizon` and lasting
    /// `[0.1, 0.3]·horizon`. Same seed → same plan; the chaos
    /// composition test overlays many of these on random fault storms.
    pub fn randomized(seed: u64, horizon_ms: f64, cameras: u32) -> DriftPlan {
        let mut rng = Rng::new(seed ^ 0xD21F_7000);
        let mut plan = DriftPlan::new();
        let n = 2 + rng.below(3);
        for _ in 0..n {
            let start = rng.range_f64(0.1, 0.6) * horizon_ms;
            let dur = rng.range_f64(0.1, 0.3) * horizon_ms;
            let cam = rng.below(cameras.max(1) as u64) as u32;
            let kind = match rng.below(4) {
                0 => DriftKind::IlluminationRamp { delta: rng.range_f64(-90.0, 90.0) as f32 },
                1 => DriftKind::HueShift { degrees: rng.range_f64(10.0, 60.0) as f32 },
                2 => DriftKind::Occlusion { camera: cam, frac: rng.range_f64(0.1, 0.4) },
                _ => DriftKind::ObjectSurge { multiplier: rng.range_f64(2.0, 4.0) },
            };
            plan.push(start, start + dur, kind);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_answers_identity_everywhere() {
        let p = DriftPlan::default();
        assert!(p.is_empty());
        assert_eq!(p.illumination_delta(1e5), 0.0);
        assert_eq!(p.hue_shift_degrees(0.0), 0.0);
        assert_eq!(p.occlusion_frac(3, 500.0), 0.0);
        assert_eq!(p.surge_multiplier(500.0), 1.0);
        assert!(!p.has_object_surge());
        assert!(!p.perturbs(0, 500.0));
    }

    #[test]
    fn ramp_is_triangular_and_windows_half_open() {
        let w = DriftWindow {
            start_ms: 100.0,
            end_ms: 300.0,
            kind: DriftKind::IlluminationRamp { delta: -80.0 },
        };
        assert_eq!(w.ramp(99.9), 0.0);
        assert_eq!(w.ramp(100.0), 0.0);
        assert!((w.ramp(200.0) - 1.0).abs() < 1e-12, "midpoint peaks");
        assert!((w.ramp(150.0) - 0.5).abs() < 1e-12);
        assert_eq!(w.ramp(300.0), 0.0, "end is exclusive");
        assert!(w.covers(100.0) && !w.covers(300.0));
    }

    #[test]
    fn queries_are_kind_and_camera_scoped() {
        let p = DriftPlan::new()
            .with(0.0, 200.0, DriftKind::IlluminationRamp { delta: -80.0 })
            .with(0.0, 200.0, DriftKind::HueShift { degrees: 40.0 })
            .with(100.0, 300.0, DriftKind::Occlusion { camera: 1, frac: 0.2 })
            .with(100.0, 300.0, DriftKind::Occlusion { camera: 1, frac: 0.35 })
            .with(400.0, 500.0, DriftKind::ObjectSurge { multiplier: 3.0 });
        assert!((p.illumination_delta(100.0) - -80.0).abs() < 1e-5);
        assert!((p.hue_shift_degrees(100.0) - 40.0).abs() < 1e-5);
        assert_eq!(p.illumination_delta(350.0), 0.0);
        // The largest covering occlusion wins; camera-scoped.
        assert_eq!(p.occlusion_frac(1, 150.0), 0.35);
        assert_eq!(p.occlusion_frac(0, 150.0), 0.0);
        assert_eq!(p.surge_multiplier(450.0), 3.0);
        assert_eq!(p.surge_multiplier(399.0), 1.0);
        assert!(p.has_object_surge());
        // perturbs: occlusion is camera-scoped, illumination is global,
        // surge perturbs (extra objects are pixels too).
        assert!(p.perturbs(0, 50.0));
        assert!(p.perturbs(1, 250.0));
        assert!(!p.perturbs(0, 250.0));
        assert!(p.perturbs(0, 450.0));
        assert!(!p.perturbs(0, 350.0));
    }

    #[test]
    fn randomized_plans_are_seeded_and_bounded() {
        let a = DriftPlan::randomized(7, 10_000.0, 4);
        let b = DriftPlan::randomized(7, 10_000.0, 4);
        assert_eq!(a, b, "same seed, same plan");
        let c = DriftPlan::randomized(8, 10_000.0, 4);
        assert_ne!(a, c, "different seeds diverge");
        assert!((2..=4).contains(&a.windows().len()));
        for w in a.windows() {
            assert!(w.start_ms >= 0.0 && w.end_ms <= 0.9 * 10_000.0 + 1e-9);
            assert!(w.end_ms > w.start_ms);
            if let DriftKind::Occlusion { camera, frac } = w.kind {
                assert!(camera < 4);
                assert!((0.1..=0.4).contains(&frac));
            }
        }
    }
}
