//! Dynamic objects: vehicle / pedestrian trajectories and rasterization.
//!
//! Trajectories are precomputed at video construction (cheap, analytic),
//! so any frame can be rendered or ground-truth-queried on demand without
//! materializing the whole video in memory.

use super::frame::{Paint, VisibleObject};
use super::scene::Scene;
use crate::util::rng::Rng;

/// Object kind (affects rasterization and ground-truth flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Vehicle,
    Pedestrian,
}

/// A straight-line trajectory through the scene.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub object_id: u64,
    pub kind: Kind,
    pub paint: Paint,
    /// Spawn time in frames (may be negative: object already mid-scene at t=0).
    pub spawn_frame: f64,
    /// x of the *leading edge* at spawn (off-screen).
    pub x0: f64,
    /// Signed speed in px/frame (+ = left→right).
    pub vx: f64,
    /// Top row of the object.
    pub y: usize,
    /// Object size in pixels.
    pub w: usize,
    pub h: usize,
}

impl Trajectory {
    /// Left edge x at frame t (float; rasterization rounds).
    pub fn x_at(&self, t: f64) -> f64 {
        self.x0 + self.vx * (t - self.spawn_frame)
    }

    /// Visible bounding box at frame `t`, clipped to the image, if any.
    pub fn bbox_at(
        &self,
        t: f64,
        width: usize,
        height: usize,
    ) -> Option<(usize, usize, usize, usize)> {
        let x = self.x_at(t);
        let x0 = x.round() as i64;
        let x1 = x0 + self.w as i64;
        let cx0 = x0.max(0) as usize;
        let cx1 = (x1.min(width as i64)).max(0) as usize;
        if cx0 >= cx1 {
            return None;
        }
        let y0 = self.y.min(height);
        let y1 = (self.y + self.h).min(height);
        if y0 >= y1 {
            return None;
        }
        Some((cx0, y0, cx1, y1))
    }

    /// Ground-truth record at frame `t`, if visible.
    pub fn visible_at(&self, t: f64, width: usize, height: usize) -> Option<VisibleObject> {
        let bbox = self.bbox_at(t, width, height)?;
        let visible_px = (bbox.2 - bbox.0) * (bbox.3 - bbox.1);
        Some(VisibleObject {
            object_id: self.object_id,
            paint: self.paint,
            bbox,
            visible_px,
            is_vehicle: self.kind == Kind::Vehicle,
        })
    }

    /// Rasterize onto `img` at frame `t`.
    pub fn draw(&self, img: &mut [f32], t: f64, width: usize, height: usize) {
        let Some((cx0, y0, cx1, y1)) = self.bbox_at(t, width, height) else {
            return;
        };
        let x_left = self.x_at(t).round() as i64;
        let body = self.paint.rgb();
        match self.kind {
            Kind::Vehicle => {
                draw_vehicle(img, width, body, x_left, (cx0, y0, cx1, y1), self.w, self.h)
            }
            Kind::Pedestrian => {
                for y in y0..y1 {
                    for x in cx0..cx1 {
                        put(img, width, x, y, body);
                    }
                }
                // Head: a skin-tone pixel row on top (if room above).
                if y0 > 0 {
                    for x in cx0..cx1 {
                        put(img, width, x, y0 - 1, [196.0, 160.0, 130.0]);
                    }
                }
            }
        }
        let _ = height;
    }
}

#[inline]
fn put(img: &mut [f32], width: usize, x: usize, y: usize, c: [f32; 3]) {
    let i = (y * width + x) * 3;
    img[i] = c[0];
    img[i + 1] = c[1];
    img[i + 2] = c[2];
}

/// Vehicle rasterization: body, darker glass band, dark wheels.
/// Proportions keep the *dominant* blob the body color so the color
/// features behave like the paper's CARLA vehicles.
fn draw_vehicle(
    img: &mut [f32],
    width: usize,
    body: [f32; 3],
    x_left: i64,
    clip: (usize, usize, usize, usize),
    w: usize,
    h: usize,
) {
    let (cx0, y0, cx1, y1) = clip;
    let glass = [body[0] * 0.35 + 20.0, body[1] * 0.35 + 26.0, body[2] * 0.35 + 34.0];
    let wheel = [18.0, 18.0, 20.0];
    let glass_y0 = y0 + (h / 5).max(1);
    let glass_y1 = glass_y0 + (h / 4).max(1);
    for y in y0..y1 {
        for x in cx0..cx1 {
            // x relative to the (possibly off-screen) left edge.
            let rx = (x as i64 - x_left) as usize;
            let ry = y - y0;
            let c = if y >= glass_y0 && y < glass_y1 && rx > w / 5 && rx < w - w / 5 {
                glass
            } else if ry + 2 >= h && (rx % (w.saturating_sub(2).max(2)) < 2 || rx + 3 >= w) {
                wheel
            } else {
                body
            };
            put(img, width, x, y, c);
        }
    }
}

/// Traffic model parameters for one video.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Mean vehicle arrivals per lane per second.
    pub vehicle_rate: f64,
    /// Mean pedestrian arrivals per second (whole sidewalk).
    pub pedestrian_rate: f64,
    /// Paint sampling weights for vehicles.
    pub paint_weights: Vec<(Paint, f64)>,
    /// Paint weights for pedestrians (clothing).
    pub pedestrian_weights: Vec<(Paint, f64)>,
}

impl TrafficConfig {
    /// Default smart-city mix: targets (vivid red/yellow) are uncommon;
    /// most traffic is achromatic or dull-colored (the paper's premise:
    /// "appearance of the object-of-interest … is not frequent").
    pub fn default_mix() -> Self {
        TrafficConfig {
            vehicle_rate: 0.25,
            pedestrian_rate: 0.3,
            paint_weights: vec![
                (Paint::VividRed, 0.06),
                (Paint::VividYellow, 0.05),
                (Paint::VividGreen, 0.03),
                (Paint::VividBlue, 0.06),
                (Paint::White, 0.16),
                (Paint::Gray, 0.18),
                (Paint::Black, 0.14),
                (Paint::Silver, 0.14),
                (Paint::DullRed, 0.08),
                (Paint::Brown, 0.06),
                (Paint::DullYellow, 0.04),
            ],
            pedestrian_weights: vec![
                (Paint::DullRed, 0.2),
                (Paint::Brown, 0.2),
                (Paint::Gray, 0.25),
                (Paint::Black, 0.2),
                (Paint::DullYellow, 0.15),
            ],
        }
    }

    /// Sample a paint from weights.
    pub fn sample_paint(rng: &mut Rng, weights: &[(Paint, f64)]) -> Paint {
        let total: f64 = weights.iter().map(|(_, w)| w).sum();
        let mut x = rng.f64() * total;
        for &(p, w) in weights {
            if x < w {
                return p;
            }
            x -= w;
        }
        weights.last().unwrap().0
    }
}

/// Precompute all trajectories for a video of `frames` frames at `fps`.
///
/// Per lane: Poisson arrivals with a per-lane speed and minimum headway so
/// vehicles in a lane never overlap. Arrivals start *before* t=0 so the
/// road is in steady state at the first frame.
pub fn spawn_traffic(
    scene: &Scene,
    cfg: &TrafficConfig,
    frames: usize,
    fps: f64,
    rng: &mut Rng,
) -> Vec<Trajectory> {
    let mut out = Vec::new();
    let mut next_id: u64 = 1;
    let width = scene.width as f64;

    for (lane_idx, &(ly0, ly1, dir)) in scene.lanes.iter().enumerate() {
        let mut lane_rng = rng.fork(lane_idx as u64 + 1);
        let lane_h = ly1 - ly0;
        // Per-lane speed: 25–70 px/s.
        let speed_px_s = lane_rng.range_f64(25.0, 70.0);
        let vx = dir as f64 * speed_px_s / fps; // px/frame
        let veh_h = lane_h.saturating_sub(2).max(4);
        // Arrivals from a warmup lead-in long enough to cross the screen.
        let crossing_frames = (width + 30.0) / vx.abs();
        let mut t = -crossing_frames;
        let end = frames as f64;
        while t < end {
            let gap_s = lane_rng.exponential(1.0 / cfg.vehicle_rate.max(1e-6));
            // Min headway: a car length + margin, in seconds.
            let veh_w = lane_rng.range(12, 20);
            let min_gap_s = (veh_w as f64 + 6.0) / speed_px_s;
            t += (gap_s.max(min_gap_s)) * fps;
            if t >= end {
                break;
            }
            let paint = TrafficConfig::sample_paint(&mut lane_rng, &cfg.paint_weights);
            let x0 = if dir > 0 { -(veh_w as f64) } else { width };
            out.push(Trajectory {
                object_id: next_id,
                kind: Kind::Vehicle,
                paint,
                spawn_frame: t,
                x0,
                vx,
                y: ly0 + 1,
                w: veh_w,
                h: veh_h,
            });
            next_id += 1;
        }
    }

    // Pedestrians on the sidewalk.
    if scene.walk_y1 > scene.walk_y0 + 4 {
        let mut ped_rng = rng.fork(0x9ed);
        let mut t = -200.0f64;
        let end = frames as f64;
        while t < end {
            t += ped_rng.exponential(1.0 / cfg.pedestrian_rate.max(1e-6)) * fps;
            if t >= end {
                break;
            }
            let dir: i8 = if ped_rng.chance(0.5) { 1 } else { -1 };
            let speed = ped_rng.range_f64(3.0, 8.0) / fps;
            let paint = TrafficConfig::sample_paint(&mut ped_rng, &cfg.pedestrian_weights);
            let y = ped_rng
                .range(scene.walk_y0 + 1, scene.walk_y1.saturating_sub(4).max(scene.walk_y0 + 2));
            out.push(Trajectory {
                object_id: next_id,
                kind: Kind::Pedestrian,
                paint,
                spawn_frame: t,
                x0: if dir > 0 { -3.0 } else { scene.width as f64 },
                vx: dir as f64 * speed,
                y,
                w: 3,
                h: 4,
            });
            next_id += 1;
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_scene() -> Scene {
        Scene::generate(1, 96, 96)
    }

    #[test]
    fn trajectory_motion() {
        let tr = Trajectory {
            object_id: 1,
            kind: Kind::Vehicle,
            paint: Paint::VividRed,
            spawn_frame: 10.0,
            x0: -15.0,
            vx: 3.0,
            y: 50,
            w: 15,
            h: 7,
        };
        assert!(tr.bbox_at(10.0, 96, 96).is_none()); // fully off-screen
        let b = tr.bbox_at(20.0, 96, 96).unwrap(); // x = -15 + 30 = 15
        assert_eq!(b, (15, 50, 30, 57));
        assert_eq!(tr.visible_at(20.0, 96, 96).unwrap().visible_px, 15 * 7);
        // Partially visible while entering.
        let b = tr.bbox_at(12.0, 96, 96).unwrap(); // x = -9
        assert_eq!(b.0, 0);
        assert_eq!(b.2, 6);
    }

    #[test]
    fn spawn_traffic_deterministic_and_nonempty() {
        let scene = test_scene();
        let cfg = TrafficConfig::default_mix();
        let a = spawn_traffic(&scene, &cfg, 600, 10.0, &mut Rng::new(5));
        let b = spawn_traffic(&scene, &cfg, 600, 10.0, &mut Rng::new(5));
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        // Unique ids.
        let mut ids: Vec<u64> = a.iter().map(|t| t.object_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.len());
    }

    #[test]
    fn no_same_lane_overlap() {
        let scene = test_scene();
        let cfg = TrafficConfig { vehicle_rate: 2.0, ..TrafficConfig::default_mix() };
        let trajs = spawn_traffic(&scene, &cfg, 300, 10.0, &mut Rng::new(7));
        let vehicles: Vec<&Trajectory> =
            trajs.iter().filter(|t| t.kind == Kind::Vehicle).collect();
        for t in (0..300).step_by(13) {
            let t = t as f64;
            for lane_y in scene.lanes.iter().map(|&(y0, _, _)| y0 + 1) {
                let mut spans: Vec<(f64, f64)> = vehicles
                    .iter()
                    .filter(|v| v.y == lane_y)
                    .filter_map(|v| {
                        v.bbox_at(t, 96, 96).map(|_| {
                            let x = v.x_at(t);
                            (x, x + v.w as f64)
                        })
                    })
                    .collect();
                spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for w in spans.windows(2) {
                    assert!(
                        w[1].0 >= w[0].1 - 1.0,
                        "overlap at t={t}: {:?} vs {:?}",
                        w[0],
                        w[1]
                    );
                }
            }
        }
    }

    #[test]
    fn draw_changes_pixels_inside_bbox_only() {
        let scene = test_scene();
        let mut img = scene.background().to_vec();
        let before = img.clone();
        let tr = Trajectory {
            object_id: 1,
            kind: Kind::Vehicle,
            paint: Paint::VividBlue,
            spawn_frame: 0.0,
            x0: 30.0,
            vx: 0.0,
            y: scene.lanes[0].0 + 1,
            w: 14,
            h: scene.lane_height() - 2,
            // drawn at t=0
        };
        tr.draw(&mut img, 0.0, 96, 96);
        let (x0, y0, x1, y1) = tr.bbox_at(0.0, 96, 96).unwrap();
        let mut changed_outside = 0;
        for y in 0..96 {
            for x in 0..96 {
                let i = (y * 96 + x) * 3;
                let inside = x >= x0 && x < x1 && y >= y0 && y < y1;
                if !inside && img[i..i + 3] != before[i..i + 3] {
                    changed_outside += 1;
                }
            }
        }
        assert_eq!(changed_outside, 0);
        // Body pixels actually took the paint.
        let ci = ((y1 - 1) * 96 + (x0 + 2)) * 3;
        assert_ne!(img[ci..ci + 3], before[ci..ci + 3]);
    }

    #[test]
    fn paint_sampling_follows_weights() {
        let mut rng = Rng::new(3);
        let weights = vec![(Paint::VividRed, 0.9), (Paint::Gray, 0.1)];
        let n = 10_000;
        let reds = (0..n)
            .filter(|_| TrafficConfig::sample_paint(&mut rng, &weights) == Paint::VividRed)
            .count();
        let frac = reds as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.02, "frac={frac}");
    }
}
