//! Video Streamer: interleaves frames from multiple cameras into one
//! timestamp-ordered stream (paper Fig. 8's "Video Streamer" component,
//! which "emulat[es] multiple cameras … by interleaving their frames").

use super::frame::Frame;
use super::generator::Video;

/// Merge-by-timestamp iterator over multiple videos.
pub struct Streamer<'a> {
    videos: &'a [Video],
    /// Next frame index per video.
    next: Vec<usize>,
}

impl<'a> Streamer<'a> {
    pub fn new(videos: &'a [Video]) -> Self {
        Streamer { videos, next: vec![0; videos.len()] }
    }

    /// Total frames that will be emitted.
    pub fn total_frames(&self) -> usize {
        self.videos.iter().map(|v| v.len()).sum()
    }

    /// Peek the timestamp of the next frame, if any.
    pub fn peek_ts(&self) -> Option<f64> {
        self.videos
            .iter()
            .zip(&self.next)
            .filter(|(v, &n)| n < v.len())
            .map(|(v, &n)| n as f64 / v.config.fps * 1e3)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

impl Iterator for Streamer<'_> {
    type Item = Frame;

    fn next(&mut self) -> Option<Frame> {
        // Pick the camera whose next frame has the smallest timestamp;
        // ties break by camera order (stable interleave).
        let mut best: Option<(usize, f64)> = None;
        for (i, v) in self.videos.iter().enumerate() {
            let n = self.next[i];
            if n >= v.len() {
                continue;
            }
            let ts = n as f64 / v.config.fps * 1e3;
            if best.is_none_or(|(_, bts)| ts < bts) {
                best = Some((i, ts));
            }
        }
        let (i, _) = best?;
        let frame = self.videos[i].render(self.next[i]);
        self.next[i] += 1;
        Some(frame)
    }
}

/// Aggregate ingress frame rate of a camera set (frames/sec).
pub fn aggregate_fps(videos: &[Video]) -> f64 {
    videos.iter().map(|v| v.config.fps).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::generator::VideoConfig;

    fn videos(n: usize, frames: usize) -> Vec<Video> {
        (0..n)
            .map(|i| Video::new(VideoConfig::new(1, i as u64 + 10, i as u32, frames)))
            .collect()
    }

    #[test]
    fn emits_all_frames_in_ts_order() {
        let vids = videos(3, 40);
        let s = Streamer::new(&vids);
        assert_eq!(s.total_frames(), 120);
        let frames: Vec<Frame> = s.collect();
        assert_eq!(frames.len(), 120);
        for w in frames.windows(2) {
            assert!(w[0].ts_ms <= w[1].ts_ms, "ts regression");
        }
        // Each camera contributes all of its frames.
        for cam in 0..3u32 {
            assert_eq!(frames.iter().filter(|f| f.camera == cam).count(), 40);
        }
    }

    #[test]
    fn same_fps_round_robin() {
        let vids = videos(2, 5);
        let cams: Vec<u32> = Streamer::new(&vids).map(|f| f.camera).collect();
        assert_eq!(cams, vec![0, 1, 0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn aggregate_rate() {
        let vids = videos(5, 3);
        assert!((aggregate_fps(&vids) - 50.0).abs() < 1e-9);
    }
}
