//! Frame and ground-truth types produced by the synthetic video substrate.

use crate::color::NamedColor;

/// Paint finishes for dynamic objects. The crucial statistical structure
/// (paper Fig. 5a/6): *vivid* paints are query targets with high saturation;
/// *dull* paints share the same hue ranges but low saturation, so Hue
/// Fraction alone cannot separate them — only the saturation/value bins can.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Paint {
    VividRed,
    VividYellow,
    VividGreen,
    VividBlue,
    White,
    Gray,
    Black,
    DullRed,    // maroon/brown-red: red hue, low saturation
    Brown,      // red-orange hue, low-mid saturation
    DullYellow, // khaki: yellow hue, low saturation
    Silver,
}

impl Paint {
    /// Body RGB of the paint.
    pub fn rgb(self) -> [f32; 3] {
        match self {
            Paint::VividRed => [208.0, 22.0, 28.0],
            Paint::VividYellow => [228.0, 200.0, 24.0],
            Paint::VividGreen => [30.0, 185.0, 45.0],
            Paint::VividBlue => [28.0, 58.0, 198.0],
            Paint::White => [232.0, 232.0, 230.0],
            Paint::Gray => [120.0, 122.0, 124.0],
            Paint::Black => [24.0, 24.0, 26.0],
            Paint::DullRed => [122.0, 72.0, 70.0],
            Paint::Brown => [130.0, 92.0, 64.0],
            Paint::DullYellow => [150.0, 138.0, 96.0],
            Paint::Silver => [180.0, 182.0, 186.0],
        }
    }

    /// Does this paint make the object a *target* for a query color?
    /// Only vivid paints count: the paper's queries are for (vividly)
    /// colored target objects; dull same-hue paints are the confounders.
    pub fn is_target_of(self, color: NamedColor) -> bool {
        matches!(
            (self, color),
            (Paint::VividRed, NamedColor::Red)
                | (Paint::VividYellow, NamedColor::Yellow)
                | (Paint::VividGreen, NamedColor::Green)
                | (Paint::VividBlue, NamedColor::Blue)
                | (Paint::White, NamedColor::White)
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            Paint::VividRed => "vivid_red",
            Paint::VividYellow => "vivid_yellow",
            Paint::VividGreen => "vivid_green",
            Paint::VividBlue => "vivid_blue",
            Paint::White => "white",
            Paint::Gray => "gray",
            Paint::Black => "black",
            Paint::DullRed => "dull_red",
            Paint::Brown => "brown",
            Paint::DullYellow => "dull_yellow",
            Paint::Silver => "silver",
        }
    }
}

/// A dynamic object visible in a specific frame (ground truth).
#[derive(Debug, Clone, PartialEq)]
pub struct VisibleObject {
    /// Stable identity across frames (camera-unique).
    pub object_id: u64,
    pub paint: Paint,
    /// Bounding box in pixels: (x0, y0, x1, y1), half-open.
    pub bbox: (usize, usize, usize, usize),
    /// Number of pixels of the object actually on screen.
    pub visible_px: usize,
    /// True for vehicles, false for pedestrians.
    pub is_vehicle: bool,
}

impl VisibleObject {
    /// Blob-size gate used by ground-truth labeling: objects smaller than
    /// the query's min blob size don't count as targets (paper's filter
    /// stage drops frames without a sufficiently large blob).
    pub fn counts_for(&self, color: NamedColor, min_px: usize) -> bool {
        self.is_vehicle && self.paint.is_target_of(color) && self.visible_px >= min_px
    }
}

/// One rendered video frame plus ground truth.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Camera (video) this frame belongs to.
    pub camera: u32,
    /// Frame index within the video.
    pub index: usize,
    /// Capture timestamp in milliseconds (index / fps).
    pub ts_ms: f64,
    /// Row-major RGB, H*W*3 f32 in [0, 255].
    pub rgb: Vec<f32>,
    pub height: usize,
    pub width: usize,
    /// Ground-truth visible objects (used for labels/QoR, never by the
    /// shedder itself).
    pub truth: Vec<VisibleObject>,
}

impl Frame {
    /// An empty frame for reuse with [`crate::video::Video::render_into`]
    /// (the rgb/truth buffers act as the caller's frame arena).
    pub fn empty() -> Frame {
        Frame {
            camera: 0,
            index: 0,
            ts_ms: 0.0,
            rgb: Vec::new(),
            height: 0,
            width: 0,
            truth: Vec::new(),
        }
    }

    /// Does this frame contain a target object of `color`? (label `l`)
    pub fn is_positive(&self, color: NamedColor, min_px: usize) -> bool {
        self.truth.iter().any(|o| o.counts_for(color, min_px))
    }

    /// IDs of target objects of `color` present in this frame.
    pub fn target_ids(&self, color: NamedColor, min_px: usize) -> Vec<u64> {
        self.truth
            .iter()
            .filter(|o| o.counts_for(color, min_px))
            .map(|o| o.object_id)
            .collect()
    }

    /// Deduplicated union of target ids across `colors`, written into a
    /// caller-owned buffer — the non-allocating twin of
    /// [`Self::target_ids`], shared by the pipeline hot loops.
    pub fn target_ids_into(&self, colors: &[NamedColor], min_px: usize, ids: &mut Vec<u64>) {
        ids.clear();
        for &color in colors {
            for o in &self.truth {
                if o.counts_for(color, min_px) && !ids.contains(&o.object_id) {
                    ids.push(o.object_id);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vivid_paints_match_their_query_color() {
        assert!(Paint::VividRed.is_target_of(NamedColor::Red));
        assert!(!Paint::DullRed.is_target_of(NamedColor::Red));
        assert!(!Paint::VividRed.is_target_of(NamedColor::Yellow));
        assert!(Paint::VividYellow.is_target_of(NamedColor::Yellow));
    }

    #[test]
    fn dull_paints_share_hue_with_targets() {
        // The confounder property: DullRed must fall inside the *red hue
        // ranges* (so HF can't separate it) but with low saturation (so the
        // sat/val bins can).
        use crate::color::hsv::rgb_to_hsv;
        let [r, g, b] = Paint::DullRed.rgb();
        let (h, s, _) = rgb_to_hsv(r, g, b);
        assert!(NamedColor::Red.ranges().contains(h), "hue {h}");
        let [r2, g2, b2] = Paint::VividRed.rgb();
        let (_, s2, _) = rgb_to_hsv(r2, g2, b2);
        assert!(s < 0.6 * s2, "dull sat {s} vs vivid {s2}");
    }

    #[test]
    fn min_blob_gate() {
        let o = VisibleObject {
            object_id: 1,
            paint: Paint::VividRed,
            bbox: (0, 0, 5, 4),
            visible_px: 20,
            is_vehicle: true,
        };
        assert!(o.counts_for(NamedColor::Red, 10));
        assert!(!o.counts_for(NamedColor::Red, 21));
        assert!(!o.counts_for(NamedColor::Yellow, 10));
    }

    #[test]
    fn frame_labels() {
        let mk = |paint, px| VisibleObject {
            object_id: 7,
            paint,
            bbox: (0, 0, 1, 1),
            visible_px: px,
            is_vehicle: true,
        };
        let f = Frame {
            camera: 0,
            index: 0,
            ts_ms: 0.0,
            rgb: vec![],
            height: 0,
            width: 0,
            truth: vec![mk(Paint::DullRed, 100), mk(Paint::VividRed, 100)],
        };
        assert!(f.is_positive(NamedColor::Red, 50));
        assert_eq!(f.target_ids(NamedColor::Red, 50), vec![7]);
        assert!(!f.is_positive(NamedColor::Blue, 50));
        // The non-allocating union twin clears its buffer and dedups.
        let mut ids = vec![99];
        f.target_ids_into(&[NamedColor::Red, NamedColor::Blue], 50, &mut ids);
        assert_eq!(ids, vec![7]);
        f.target_ids_into(&[NamedColor::Blue], 50, &mut ids);
        assert!(ids.is_empty());
    }
}
