//! The Backend Query Executor (paper Fig. 8): blob-size filter → color
//! filter → DNN detection → label/color check → sink. Returns which stage
//! each frame reached plus the (cost-model) execution time, which is what
//! drives the control loop's `proc_Q`.

use super::blob::{color_mask, foreground_mask, largest_blob};
use super::cost_model::CostModel;
use super::detector::{Detections, Detector};
use crate::color::HueRanges;
use crate::config::QueryConfig;
use crate::metrics::Stage;
use crate::utility::Combine;
use anyhow::Result;

/// Outcome of running the query on one frame.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// Deepest stage the frame reached.
    pub last_stage: Stage,
    /// Simulated execution time across traversed stages (ms).
    pub exec_ms: f64,
    /// DNN detections (only when the DNN ran).
    pub detections: Option<Detections>,
    /// Did the frame satisfy the query (reach the sink with a match)?
    pub matched: bool,
}

/// The application query executor.
pub struct BackendQuery {
    query: QueryConfig,
    ranges: Vec<HueRanges>,
    detector: Detector,
    cost: CostModel,
    fg_threshold: f32,
}

impl BackendQuery {
    pub fn new(query: QueryConfig, detector: Detector, cost: CostModel, fg_threshold: f32) -> Self {
        let ranges = query.colors.iter().map(|c| c.ranges()).collect();
        BackendQuery { query, ranges, detector, cost, fg_threshold }
    }

    pub fn query(&self) -> &QueryConfig {
        &self.query
    }

    /// Process one frame through the operator chain.
    pub fn process(
        &mut self,
        rgb: &[f32],
        background: &[f32],
        width: usize,
        height: usize,
    ) -> Result<QueryResult> {
        self.run(rgb, background, width, height, true)
    }

    /// Like [`Self::process`] but *skips executing the DNN* while still
    /// traversing the same stages and sampling the same cost sequence:
    /// the returned `last_stage` / `exec_ms` are identical to `process`,
    /// with `detections = None` and `matched = false` on DNN-bound frames.
    /// Used by drivers that run the detector elsewhere (e.g. the
    /// real-time pipeline's worker thread) but must keep the cost-model
    /// RNG in lockstep with the simulator.
    pub fn plan(
        &mut self,
        rgb: &[f32],
        background: &[f32],
        width: usize,
        height: usize,
    ) -> Result<QueryResult> {
        self.run(rgb, background, width, height, false)
    }

    fn run(
        &mut self,
        rgb: &[f32],
        background: &[f32],
        width: usize,
        height: usize,
        run_dnn: bool,
    ) -> Result<QueryResult> {
        let mut exec_ms = 0.0;

        // Stage 1: blob-size filter — contiguous foreground groups.
        exec_ms += self.cost.blob_filter_ms();
        let fg = foreground_mask(rgb, background, width, height, self.fg_threshold);
        if largest_blob(&fg) < self.query.min_blob_px {
            return Ok(QueryResult {
                last_stage: Stage::BlobFilter,
                exec_ms,
                detections: None,
                matched: false,
            });
        }

        // Stage 2: color filter — a large-enough blob of a target color.
        exec_ms += self.cost.color_filter_ms();
        let mut any_color = false;
        for r in &self.ranges {
            let cm = color_mask(rgb, background, width, height, self.fg_threshold, r);
            if largest_blob(&cm) >= self.query.min_blob_px {
                any_color = true;
                break;
            }
        }
        if !any_color {
            return Ok(QueryResult {
                last_stage: Stage::ColorFilter,
                exec_ms,
                detections: None,
                matched: false,
            });
        }

        // Stage 3: DNN object detection (the heavyweight stage). Cost is
        // always charged; the detector itself only runs when requested
        // (it never touches the cost RNG, so plan/process stay in step).
        exec_ms += self.cost.dnn_ms();
        if !run_dnn {
            exec_ms += self.cost.sink_ms();
            return Ok(QueryResult {
                last_stage: Stage::Sink,
                exec_ms,
                detections: None,
                matched: false,
            });
        }
        let detections = self
            .detector
            .detect(rgb, background, width, height, &self.ranges)?;

        // Stage 4: label/color check + sink.
        exec_ms += self.cost.sink_ms();
        let matched = match self.query.combine {
            Combine::Single => detections.found(0),
            Combine::Or => (0..self.ranges.len()).any(|c| detections.found(c)),
            Combine::And => (0..self.ranges.len()).all(|c| detections.found(c)),
        };
        Ok(QueryResult {
            last_stage: Stage::Sink,
            exec_ms,
            detections: Some(detections),
            matched,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::config::CostConfig;

    fn mk_query(combine: Combine) -> BackendQuery {
        let q = match combine {
            Combine::Single => QueryConfig::single(NamedColor::Red),
            c => QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, c),
        };
        BackendQuery::new(
            q,
            Detector::native(12, 25.0),
            CostModel::new(CostConfig { jitter: 0.0, ..Default::default() }, 1),
            25.0,
        )
    }

    fn frame(blocks: &[(usize, usize, [f32; 3])]) -> (Vec<f32>, Vec<f32>) {
        let (w, h) = (96, 96);
        let bg = vec![96.0f32; w * h * 3];
        let mut rgb = bg.clone();
        for &(x0, y0, c) in blocks {
            for y in y0..y0 + 12 {
                for x in x0..x0 + 16 {
                    let i = (y * w + x) * 3;
                    rgb[i..i + 3].copy_from_slice(&c);
                }
            }
        }
        (rgb, bg)
    }

    const RED: [f32; 3] = [208.0, 22.0, 28.0];
    const YELLOW: [f32; 3] = [228.0, 200.0, 24.0];
    const GRAY: [f32; 3] = [150.0, 150.0, 150.0];

    #[test]
    fn empty_frame_exits_at_blob_filter_cheaply() {
        let mut q = mk_query(Combine::Single);
        let (rgb, bg) = frame(&[]);
        let r = q.process(&rgb, &bg, 96, 96).unwrap();
        assert_eq!(r.last_stage, Stage::BlobFilter);
        assert!(!r.matched);
        let costs = CostConfig::default();
        assert!(r.exec_ms <= costs.blob_ms + 1e-9);
    }

    #[test]
    fn gray_object_exits_at_color_filter() {
        let mut q = mk_query(Combine::Single);
        let (rgb, bg) = frame(&[(10, 30, GRAY)]);
        let r = q.process(&rgb, &bg, 96, 96).unwrap();
        assert_eq!(r.last_stage, Stage::ColorFilter);
        assert!(!r.matched);
    }

    #[test]
    fn red_object_reaches_sink_and_matches() {
        let mut q = mk_query(Combine::Single);
        let (rgb, bg) = frame(&[(10, 30, RED)]);
        let r = q.process(&rgb, &bg, 96, 96).unwrap();
        assert_eq!(r.last_stage, Stage::Sink);
        assert!(r.matched);
        let costs = CostConfig::default();
        assert!(r.exec_ms >= costs.dnn_ms, "DNN cost not charged");
    }

    #[test]
    fn or_query_matches_either_color() {
        let mut q = mk_query(Combine::Or);
        for c in [RED, YELLOW] {
            let (rgb, bg) = frame(&[(10, 30, c)]);
            let r = q.process(&rgb, &bg, 96, 96).unwrap();
            assert!(r.matched, "OR should match {c:?}");
        }
    }

    #[test]
    fn and_query_requires_both() {
        let mut q = mk_query(Combine::And);
        let (rgb, bg) = frame(&[(10, 30, RED)]);
        let r = q.process(&rgb, &bg, 96, 96).unwrap();
        assert_eq!(r.last_stage, Stage::Sink); // red blob got it past filters
        assert!(!r.matched, "AND needs both colors");
        let (rgb, bg) = frame(&[(10, 30, RED), (50, 60, YELLOW)]);
        let r = q.process(&rgb, &bg, 96, 96).unwrap();
        assert!(r.matched);
    }

    #[test]
    fn plan_matches_process_stage_and_cost_sequence() {
        // Two executors with the same cost seed (and jitter ON): planning
        // must traverse the same stages and sample the identical cost
        // sequence as full processing, frame after frame.
        let mk = || {
            BackendQuery::new(
                QueryConfig::single(NamedColor::Red),
                Detector::native(12, 25.0),
                CostModel::new(CostConfig { jitter: 0.1, ..Default::default() }, 99),
                25.0,
            )
        };
        let (mut full, mut planner) = (mk(), mk());
        let cases = [vec![], vec![(10, 30, GRAY)], vec![(10, 30, RED)], vec![(50, 60, RED)]];
        for blocks in &cases {
            let (rgb, bg) = frame(blocks);
            let p = full.process(&rgb, &bg, 96, 96).unwrap();
            let q = planner.plan(&rgb, &bg, 96, 96).unwrap();
            assert_eq!(p.last_stage, q.last_stage);
            assert_eq!(p.exec_ms, q.exec_ms);
            assert!(q.detections.is_none(), "plan must not run the DNN");
        }
    }

    #[test]
    fn small_target_blocked_by_min_blob() {
        let mut q = mk_query(Combine::Single);
        // A 4x4 red dot (16 px < 40 min blob) over empty background.
        let (mut rgb, bg) = frame(&[]);
        for y in 30..34 {
            for x in 10..14 {
                let i = (y * 96 + x) * 3;
                rgb[i..i + 3].copy_from_slice(&RED);
            }
        }
        let r = q.process(&rgb, &bg, 96, 96).unwrap();
        assert_eq!(r.last_stage, Stage::BlobFilter);
    }
}
