//! Backend application query (paper Fig. 8): filters → DNN → sink, plus
//! the cost model that calibrates simulated stage latencies.

pub mod blob;
pub mod cost_model;
pub mod detector;
pub mod query;

pub use blob::{blob_sizes, color_mask, foreground_mask, largest_blob, Mask};
pub use cost_model::CostModel;
pub use detector::{Detections, Detector};
pub use query::{BackendQuery, QueryResult};
