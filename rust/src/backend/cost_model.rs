//! Per-stage execution-cost model: gives the discrete-event simulator the
//! latency profile of the paper's testbed (efficientdet-d4-class DNN on a
//! K80, Jetson-class camera ops) with seeded jitter.
//!
//! The *shape* of the paper's load dynamics comes from which stages a
//! frame traverses (cheap filter exit vs. full DNN pass); this model
//! supplies the per-stage magnitudes. DESIGN.md documents the calibration.

use crate::config::CostConfig;
use crate::util::rng::Rng;

/// Stage cost sampler with multiplicative jitter.
#[derive(Debug, Clone)]
pub struct CostModel {
    cfg: CostConfig,
    rng: Rng,
}

impl CostModel {
    pub fn new(cfg: CostConfig, seed: u64) -> Self {
        CostModel { cfg, rng: Rng::new(seed ^ 0xC057) }
    }

    pub fn config(&self) -> &CostConfig {
        &self.cfg
    }

    fn jittered(&mut self, base_ms: f64) -> f64 {
        if self.cfg.jitter <= 0.0 {
            return base_ms;
        }
        let f = 1.0 + (self.rng.f64() * 2.0 - 1.0) * self.cfg.jitter;
        (base_ms * f).max(0.0)
    }

    /// Camera-side processing (RGB→HSV + bg-sub + feature extraction).
    pub fn camera_ms(&mut self) -> f64 {
        self.jittered(self.cfg.cam_ms)
    }

    pub fn blob_filter_ms(&mut self) -> f64 {
        self.jittered(self.cfg.blob_ms)
    }

    pub fn color_filter_ms(&mut self) -> f64 {
        self.jittered(self.cfg.color_ms)
    }

    pub fn dnn_ms(&mut self) -> f64 {
        self.jittered(self.cfg.dnn_ms)
    }

    pub fn sink_ms(&mut self) -> f64 {
        self.jittered(self.cfg.sink_ms)
    }

    pub fn net_cam_ls_ms(&mut self) -> f64 {
        self.jittered(self.cfg.net_cam_ls_ms)
    }

    pub fn net_ls_q_ms(&mut self) -> f64 {
        self.jittered(self.cfg.net_ls_q_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_bounded_and_deterministic() {
        let cfg = CostConfig { jitter: 0.1, ..Default::default() };
        let mut a = CostModel::new(cfg.clone(), 7);
        let mut b = CostModel::new(cfg.clone(), 7);
        for _ in 0..1000 {
            let x = a.dnn_ms();
            assert_eq!(x, b.dnn_ms());
            assert!(x >= cfg.dnn_ms * 0.9 - 1e-9 && x <= cfg.dnn_ms * 1.1 + 1e-9);
        }
    }

    #[test]
    fn zero_jitter_exact() {
        let cfg = CostConfig { jitter: 0.0, ..Default::default() };
        let mut m = CostModel::new(cfg.clone(), 1);
        assert_eq!(m.blob_filter_ms(), cfg.blob_ms);
        assert_eq!(m.camera_ms(), cfg.cam_ms);
    }

    #[test]
    fn dnn_dominates_filters() {
        // Structural property the experiments rely on: a DNN-bound frame
        // costs an order of magnitude more than a filter-exit frame.
        let cfg = CostConfig::default();
        assert!(cfg.dnn_ms > 10.0 * (cfg.blob_ms + cfg.color_ms));
    }
}
