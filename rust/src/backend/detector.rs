//! The backend query's object-detection stage — the efficientdet-d4
//! substitution (DESIGN.md §2): a deterministic color-blob detector over a
//! G×G grid. Two backends with identical semantics:
//!
//! * `Artifact` — the AOT `detector.hlo.txt` module via PJRT (production);
//! * `Native` — pure Rust mirror (fast path for long simulations).
//!
//! The heavy *cost* of the real DNN is modeled by `CostModel::dnn_ms`, not
//! by this computation.

use crate::color::hsv::rgb_to_hsv;
use crate::color::HueRanges;
use crate::runtime::{fill_cached, Engine, Executable, Tensor};
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::rc::Rc;

/// Detection output: fired cells per query color.
#[derive(Debug, Clone, PartialEq)]
pub struct Detections {
    /// Number of grid cells fired per color.
    pub cell_counts: Vec<u32>,
}

impl Detections {
    /// Any detection for color `c`?
    pub fn found(&self, c: usize) -> bool {
        self.cell_counts.get(c).copied().unwrap_or(0) > 0
    }
}

/// Reusable PJRT input tensors (frame + background), allocated once so
/// the artifact path stops copying both images on every call.
#[derive(Default)]
struct DetScratch {
    rgb_t: Option<Tensor>,
    bg_t: Option<Tensor>,
}

/// Detector backend.
pub enum Detector {
    Native {
        grid: usize,
        fg_threshold: f32,
    },
    Artifact {
        exe: Rc<Executable>,
        frame_h: usize,
        frame_w: usize,
        scratch: RefCell<DetScratch>,
    },
}

/// Cell-density firing fraction (matches python/compile/model.py).
const FIRE_FRACTION: f32 = 0.25;
/// Vividness gates (saturation ≥ 4 bins, value ≥ 2 bins), same as
/// model.py: excludes dull same-hue confounders (maroon s≈109).
const VIVID_SAT_MIN: f32 = 128.0;
const VIVID_VAL_MIN: f32 = 64.0;

impl Detector {
    pub fn native(grid: usize, fg_threshold: f32) -> Self {
        Detector::Native { grid, fg_threshold }
    }

    pub fn artifact(engine: &Engine) -> Result<Self> {
        let exe = engine.load("detector")?;
        let m = engine.manifest();
        Ok(Detector::Artifact {
            exe,
            frame_h: m.frame_h,
            frame_w: m.frame_w,
            scratch: RefCell::new(DetScratch::default()),
        })
    }

    /// Detect target-colored objects. `ranges` has K ≤ 2 colors.
    pub fn detect(
        &self,
        rgb: &[f32],
        background: &[f32],
        width: usize,
        height: usize,
        ranges: &[HueRanges],
    ) -> Result<Detections> {
        if ranges.is_empty() || ranges.len() > 2 {
            bail!("detector supports 1 or 2 colors, got {}", ranges.len());
        }
        match self {
            Detector::Native { grid, fg_threshold } => Ok(native_detect(
                rgb,
                background,
                width,
                height,
                *grid,
                *fg_threshold,
                ranges,
            )),
            Detector::Artifact { exe, frame_h, frame_w, scratch } => {
                if width != *frame_w || height != *frame_h {
                    bail!("frame {width}x{height} != artifact {frame_w}x{frame_h}");
                }
                // The artifact is compiled for 2 colors; pad with an empty
                // hue interval, which can never fire.
                let mut r = Vec::with_capacity(8);
                for c in 0..2 {
                    let hr = ranges.get(c).copied().unwrap_or(HueRanges::single(0.0, 0.0));
                    r.extend_from_slice(&hr.to_array());
                }
                let mut scratch = scratch.borrow_mut();
                let shape = [height, width, 3];
                fill_cached(&mut scratch.rgb_t, rgb, &shape)?;
                fill_cached(&mut scratch.bg_t, background, &shape)?;
                let rgb_t = scratch.rgb_t.as_ref().unwrap();
                let bg_t = scratch.bg_t.as_ref().unwrap();
                let r_t = Tensor::new(r, vec![2, 4])?;
                let outs = exe.run(&[rgb_t, bg_t, &r_t])?;
                let counts = &outs[1];
                let mut cell_counts: Vec<u32> =
                    counts.data().iter().map(|&x| x as u32).collect();
                cell_counts.truncate(ranges.len());
                Ok(Detections { cell_counts })
            }
        }
    }
}

/// Pure-Rust mirror of the artifact's detection graph.
fn native_detect(
    rgb: &[f32],
    background: &[f32],
    width: usize,
    height: usize,
    grid: usize,
    fg_threshold: f32,
    ranges: &[HueRanges],
) -> Detections {
    let pool_y = height / grid;
    let pool_x = width / grid;
    let fire_at = FIRE_FRACTION * (pool_x * pool_y) as f32;
    let mut cell_counts = vec![0u32; ranges.len()];
    for (c, range) in ranges.iter().enumerate() {
        for gy in 0..grid {
            for gx in 0..grid {
                let mut density = 0.0f32;
                for y in gy * pool_y..(gy + 1) * pool_y {
                    for x in gx * pool_x..(gx + 1) * pool_x {
                        let p = y * width + x;
                        let d = (rgb[3 * p] - background[3 * p])
                            .abs()
                            .max((rgb[3 * p + 1] - background[3 * p + 1]).abs())
                            .max((rgb[3 * p + 2] - background[3 * p + 2]).abs());
                        if d <= fg_threshold {
                            continue;
                        }
                        let (h, s, v) = rgb_to_hsv(rgb[3 * p], rgb[3 * p + 1], rgb[3 * p + 2]);
                        if range.contains(h) && s >= VIVID_SAT_MIN && v >= VIVID_VAL_MIN {
                            density += 1.0;
                        }
                    }
                }
                if density >= fire_at {
                    cell_counts[c] += 1;
                }
            }
        }
    }
    Detections { cell_counts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::NamedColor;

    fn frame_with_block(c: [f32; 3]) -> (Vec<f32>, Vec<f32>) {
        let (w, h) = (96, 96);
        let bg = vec![96.0f32; w * h * 3];
        let mut rgb = bg.clone();
        for y in 24..40 {
            for x in 8..40 {
                let i = (y * w + x) * 3;
                rgb[i..i + 3].copy_from_slice(&c);
            }
        }
        (rgb, bg)
    }

    #[test]
    fn native_fires_on_vivid_red_only() {
        let det = Detector::native(12, 25.0);
        let ranges = [NamedColor::Red.ranges(), NamedColor::Yellow.ranges()];
        let (rgb, bg) = frame_with_block([208.0, 22.0, 28.0]);
        let d = det.detect(&rgb, &bg, 96, 96, &ranges).unwrap();
        assert!(d.found(0));
        assert!(!d.found(1));
        // Dull red must NOT fire (below vividness gate).
        let (rgb, bg) = frame_with_block([122.0, 72.0, 70.0]);
        let d = det.detect(&rgb, &bg, 96, 96, &ranges).unwrap();
        assert!(!d.found(0));
    }

    #[test]
    fn single_color_query_supported() {
        let det = Detector::native(12, 25.0);
        let (rgb, bg) = frame_with_block([228.0, 200.0, 24.0]);
        let d = det
            .detect(&rgb, &bg, 96, 96, &[NamedColor::Yellow.ranges()])
            .unwrap();
        assert_eq!(d.cell_counts.len(), 1);
        assert!(d.found(0));
    }

    #[test]
    fn empty_frame_no_detections() {
        let det = Detector::native(12, 25.0);
        let bg = vec![96.0f32; 96 * 96 * 3];
        let d = det
            .detect(&bg, &bg, 96, 96, &[NamedColor::Red.ranges()])
            .unwrap();
        assert_eq!(d.cell_counts, vec![0]);
    }

    #[test]
    fn arity_validated() {
        let det = Detector::native(12, 25.0);
        let bg = vec![96.0f32; 96 * 96 * 3];
        assert!(det.detect(&bg, &bg, 96, 96, &[]).is_err());
    }
}
