//! Blob detection: connected components over foreground / color masks —
//! the query's first two filter stages (paper Fig. 8: a size filter on
//! contiguous pixel groups, then a target-color blob filter).

use crate::color::hsv::rgb_to_hsv;
use crate::color::HueRanges;

/// Binary mask over a frame (row-major, width*height).
#[derive(Debug, Clone)]
pub struct Mask {
    pub width: usize,
    pub height: usize,
    pub bits: Vec<bool>,
}

impl Mask {
    pub fn count(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }
}

/// Foreground mask via max-channel background difference.
pub fn foreground_mask(
    rgb: &[f32],
    background: &[f32],
    width: usize,
    height: usize,
    threshold: f32,
) -> Mask {
    let mut bits = vec![false; width * height];
    for p in 0..width * height {
        let d = (rgb[3 * p] - background[3 * p])
            .abs()
            .max((rgb[3 * p + 1] - background[3 * p + 1]).abs())
            .max((rgb[3 * p + 2] - background[3 * p + 2]).abs());
        bits[p] = d > threshold;
    }
    Mask { width, height, bits }
}

/// Foreground pixels whose hue falls in `ranges`, with only a *minimal*
/// saturation floor to exclude achromatic pixels (whose hue is degenerate).
///
/// Deliberately NOT vividness-gated: the query's stage-2 filter is a cheap
/// color-range test, so dull same-hue confounders (maroon, s≈109) *pass*
/// and load the DNN — exactly the overload dynamic the Load Shedder exists
/// to absorb (paper Fig. 13). Discrimination happens at the DNN + label
/// check, which does gate on vividness.
pub fn color_mask(
    rgb: &[f32],
    background: &[f32],
    width: usize,
    height: usize,
    threshold: f32,
    ranges: &HueRanges,
) -> Mask {
    let mut m = foreground_mask(rgb, background, width, height, threshold);
    for p in 0..width * height {
        if !m.bits[p] {
            continue;
        }
        let (h, s, _v) = rgb_to_hsv(rgb[3 * p], rgb[3 * p + 1], rgb[3 * p + 2]);
        m.bits[p] = ranges.contains(h) && s >= 40.0;
    }
    m
}

/// Sizes of all 4-connected components in a mask, descending.
pub fn blob_sizes(mask: &Mask) -> Vec<usize> {
    let (w, h) = (mask.width, mask.height);
    let mut seen = vec![false; w * h];
    let mut sizes = Vec::new();
    let mut stack = Vec::new();
    for start in 0..w * h {
        if !mask.bits[start] || seen[start] {
            continue;
        }
        let mut size = 0usize;
        stack.push(start);
        seen[start] = true;
        while let Some(p) = stack.pop() {
            size += 1;
            let (x, y) = (p % w, p / w);
            let mut push = |q: usize| {
                if mask.bits[q] && !seen[q] {
                    seen[q] = true;
                    stack.push(q);
                }
            };
            if x > 0 {
                push(p - 1);
            }
            if x + 1 < w {
                push(p + 1);
            }
            if y > 0 {
                push(p - w);
            }
            if y + 1 < h {
                push(p + w);
            }
        }
        sizes.push(size);
    }
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    sizes
}

/// Largest connected component size (0 if mask empty).
pub fn largest_blob(mask: &Mask) -> usize {
    blob_sizes(mask).first().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::NamedColor;

    fn frame_with_rects(rects: &[(usize, usize, usize, usize, [f32; 3])]) -> (Vec<f32>, Vec<f32>) {
        let (w, h) = (32, 32);
        let bg = vec![96.0; w * h * 3];
        let mut rgb = bg.clone();
        for &(x0, y0, x1, y1, c) in rects {
            for y in y0..y1 {
                for x in x0..x1 {
                    let i = (y * w + x) * 3;
                    rgb[i..i + 3].copy_from_slice(&c);
                }
            }
        }
        (rgb, bg)
    }

    #[test]
    fn fg_mask_counts() {
        let (rgb, bg) = frame_with_rects(&[(0, 0, 4, 4, [208.0, 22.0, 28.0])]);
        let m = foreground_mask(&rgb, &bg, 32, 32, 25.0);
        assert_eq!(m.count(), 16);
    }

    #[test]
    fn blob_separation() {
        // Two disjoint blobs: 4x4=16 and 2x2=4 (diagonal adjacency is NOT
        // connected under 4-connectivity).
        let (rgb, bg) = frame_with_rects(&[
            (0, 0, 4, 4, [208.0, 22.0, 28.0]),
            (10, 10, 12, 12, [208.0, 22.0, 28.0]),
        ]);
        let m = foreground_mask(&rgb, &bg, 32, 32, 25.0);
        assert_eq!(blob_sizes(&m), vec![16, 4]);
        assert_eq!(largest_blob(&m), 16);
    }

    #[test]
    fn diagonal_not_connected() {
        let (rgb, bg) = frame_with_rects(&[
            (0, 0, 2, 2, [208.0, 22.0, 28.0]),
            (2, 2, 4, 4, [208.0, 22.0, 28.0]),
        ]);
        let m = foreground_mask(&rgb, &bg, 32, 32, 25.0);
        assert_eq!(blob_sizes(&m), vec![4, 4]);
    }

    #[test]
    fn color_mask_is_hue_only() {
        let (rgb, bg) = frame_with_rects(&[
            (0, 0, 4, 4, [208.0, 22.0, 28.0]),   // vivid red 16px
            (8, 8, 12, 12, [122.0, 72.0, 70.0]), // dull red (low sat) 16px
            (16, 16, 20, 20, [228.0, 200.0, 24.0]), // vivid yellow 16px
        ]);
        // Both red-hue rects pass the stage-2 filter (dull confounders
        // load the DNN — see doc comment), yellow does not.
        let m = color_mask(&rgb, &bg, 32, 32, 25.0, &NamedColor::Red.ranges());
        assert_eq!(m.count(), 32);
        let my = color_mask(&rgb, &bg, 32, 32, 25.0, &NamedColor::Yellow.ranges());
        assert_eq!(my.count(), 16);
    }

    #[test]
    fn empty_mask() {
        let (rgb, bg) = frame_with_rects(&[]);
        let m = foreground_mask(&rgb, &bg, 32, 32, 25.0);
        assert_eq!(largest_blob(&m), 0);
        assert!(blob_sizes(&m).is_empty());
    }
}
