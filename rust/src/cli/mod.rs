//! Minimal subcommand/flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! repeated flags (collected in order). Unknown-flag and missing-value
//! errors carry the offending token.

use anyhow::Result;
use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--" {
                // `cargo run --example x -- --flag` forwards a bare `--`;
                // treat it as a separator and skip it.
                continue;
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // A following token that isn't itself a flag is
                        // this flag's value; otherwise boolean.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                args.flags.entry(key).or_default().push(val);
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Last value of a flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeated flag.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Typed access with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} wants a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = p("figures --fig 9a --scale small --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("fig"), Some("9a"));
        assert_eq!(a.get("scale"), Some("small"));
        assert_eq!(a.get("quiet"), Some("true"));
        assert!(a.has("quiet"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = p("figures --fig=5a --fig 5b --fig=6");
        assert_eq!(a.get_all("fig"), vec!["5a", "5b", "6"]);
        assert_eq!(a.get("fig"), Some("6")); // last wins for single access
    }

    #[test]
    fn typed_access() {
        let a = p("run --frames 500 --rate 0.5");
        assert_eq!(a.get_usize("frames", 0).unwrap(), 500);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("other", 7).unwrap(), 7);
        assert!(p("x --frames abc").get_usize("frames", 0).is_err());
    }

    #[test]
    fn positionals() {
        let a = p("train model.json extra");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["model.json", "extra"]);
    }
}
