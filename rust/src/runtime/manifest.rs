//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime: which artifacts exist, their input shapes, output
//! names, and the frame geometry they were compiled for.

use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Declared input of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub sha256: String,
}

/// Parsed manifest + the directory it was loaded from.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub frame_h: usize,
    pub frame_w: usize,
    pub detect_grid: usize,
    pub train_batch: usize,
    pub num_bins: usize,
    pub entries: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let v = json::read_file(&path)
            .with_context(|| format!("loading manifest {}", path.display()))?;
        Self::from_value(dir, &v)
    }

    /// Default location: `$UALS_ARTIFACT_DIR` or `./artifacts` relative to
    /// the crate root (works from `cargo test`/`cargo run` and examples).
    pub fn load_default() -> Result<Self> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            bail!(
                "artifacts not found at {} — run `make artifacts` first \
                 (or set UALS_ARTIFACT_DIR)",
                dir.display()
            );
        }
        Self::load(&dir)
    }

    fn from_value(dir: &Path, v: &Value) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_object()? {
            let inputs = e
                .get("inputs")?
                .as_array()?
                .iter()
                .map(|i| {
                    Ok(InputSpec {
                        shape: i
                            .get("shape")?
                            .as_array()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_, _>>()?,
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")?
                .as_array()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: e.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                    sha256: e.get("sha256")?.as_str()?.to_string(),
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            frame_h: v.get("frame_h")?.as_usize()?,
            frame_w: v.get("frame_w")?.as_usize()?,
            detect_grid: v.get("detect_grid")?.as_usize()?,
            train_batch: v.get("train_batch")?.as_usize()?,
            num_bins: v.get("num_bins")?.as_usize()?,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.entry(name)?.file))
    }
}

/// Resolve the artifact directory (env override → crate-root default).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("UALS_ARTIFACT_DIR") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> &'static str {
        r#"{
          "frame_h": 96, "frame_w": 96, "detect_grid": 12,
          "train_batch": 8, "num_bins": 8,
          "entries": {
            "shedder_k1": {
              "file": "shedder_k1.hlo.txt",
              "inputs": [
                {"shape": [96, 96, 3], "dtype": "float32"},
                {"shape": [96, 96, 3], "dtype": "float32"},
                {"shape": [1, 4], "dtype": "float32"},
                {"shape": [1, 8, 8], "dtype": "float32"}
              ],
              "outputs": ["utility", "hf", "pf", "fg_frac"],
              "sha256": "ab"
            }
          }
        }"#
    }

    #[test]
    fn parses_sample() {
        let v = json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_value(Path::new("/tmp/a"), &v).unwrap();
        assert_eq!(m.frame_h, 96);
        let e = m.entry("shedder_k1").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[2].shape, vec![1, 4]);
        assert_eq!(e.outputs[0], "utility");
        assert_eq!(
            m.hlo_path("shedder_k1").unwrap(),
            Path::new("/tmp/a/shedder_k1.hlo.txt")
        );
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["shedder_k1", "shedder_k2", "features_batch8", "detector"] {
            let e = m.entry(name).unwrap();
            assert!(m.hlo_path(name).unwrap().exists(), "{name} hlo missing");
            assert!(!e.outputs.is_empty());
        }
    }
}
