//! Dense f32 tensor: the host-side value type crossing the PJRT boundary.

use anyhow::{bail, Result};

/// A host tensor (row-major f32) with an explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { data, shape })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn scalar(x: f32) -> Self {
        Tensor { data: vec![x], shape: vec![] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        let n = data.len();
        Tensor { data, shape: vec![n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor of {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Row-major index of a multi-dimensional coordinate.
    pub fn index_of(&self, coords: &[usize]) -> Result<usize> {
        if coords.len() != self.shape.len() {
            bail!("rank mismatch: coords {:?} vs shape {:?}", coords, self.shape);
        }
        let mut idx = 0;
        for (c, s) in coords.iter().zip(&self.shape) {
            if c >= s {
                bail!("coord {:?} out of bounds for {:?}", coords, self.shape);
            }
            idx = idx * s + c;
        }
        Ok(idx)
    }

    pub fn at(&self, coords: &[usize]) -> Result<f32> {
        Ok(self.data[self.index_of(coords)?])
    }

    /// Refill from a slice of identical length (zero-allocation reuse).
    pub fn fill_from(&mut self, data: &[f32]) -> Result<()> {
        if data.len() != self.data.len() {
            bail!(
                "fill_from length {} != tensor shape {:?}",
                data.len(),
                self.shape
            );
        }
        self.data.copy_from_slice(data);
        Ok(())
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

/// Initialize-or-refill a cached input-tensor slot from a slice, with
/// length validation (no per-call allocations once warm). A warm slot is
/// reused only when the requested shape matches; otherwise the tensor is
/// rebuilt, so shape changes can never alias a stale geometry. Shared by
/// the PJRT extractor and detector paths.
pub fn fill_cached(slot: &mut Option<Tensor>, data: &[f32], shape: &[usize]) -> Result<()> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        bail!("input length {} != shape {:?}", data.len(), shape);
    }
    match slot {
        Some(t) if t.shape() == shape => t.fill_from(data),
        s => {
            *s = Some(Tensor::new(data.to_vec(), shape.to_vec())?);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::new(vec![1.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::new(vec![1.0; 5], vec![2, 3]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new((0..24).map(|x| x as f32).collect(), vec![2, 3, 4]).unwrap();
        assert_eq!(t.at(&[0, 0, 0]).unwrap(), 0.0);
        assert_eq!(t.at(&[1, 2, 3]).unwrap(), 23.0);
        assert_eq!(t.at(&[1, 0, 2]).unwrap(), 14.0);
        assert!(t.at(&[2, 0, 0]).is_err());
        assert!(t.at(&[0, 0]).is_err());
    }

    #[test]
    fn scalar_and_item() {
        assert_eq!(Tensor::scalar(3.5).item().unwrap(), 3.5);
        assert!(Tensor::zeros(&[2]).item().is_err());
    }

    #[test]
    fn fill_cached_validates_and_reuses() {
        let mut slot: Option<Tensor> = None;
        fill_cached(&mut slot, &[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(slot.as_ref().unwrap().data(), &[1.0, 2.0, 3.0, 4.0]);
        // Warm refill: same shape, new data, no panic.
        fill_cached(&mut slot, &[5.0; 4], &[2, 2]).unwrap();
        assert_eq!(slot.as_ref().unwrap().data(), &[5.0; 4]);
        // Mismatched input must be a recoverable error even when warm.
        assert!(fill_cached(&mut slot, &[1.0; 3], &[2, 2]).is_err());
        assert!(fill_cached(&mut slot, &[1.0; 4], &[4, 2]).is_err());
        // Same element count but new shape: rebuilt, not silently stale.
        fill_cached(&mut slot, &[7.0; 4], &[1, 4]).unwrap();
        assert_eq!(slot.as_ref().unwrap().shape(), &[1, 4]);
        let mut cold: Option<Tensor> = None;
        assert!(fill_cached(&mut cold, &[1.0; 3], &[2, 2]).is_err());
        assert!(cold.is_none());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0]).reshape(vec![2, 2]).unwrap();
        assert_eq!(t.at(&[1, 0]).unwrap(), 3.0);
        assert!(t.clone().reshape(vec![3, 2]).is_err());
    }
}
