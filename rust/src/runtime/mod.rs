//! Runtime layer: PJRT client + AOT artifact loading and execution.
//!
//! The only module touching the `xla` crate. Everything above it deals in
//! host [`Tensor`]s and manifest names (`"shedder_k1"`, `"detector"`, …).
//! Pattern adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Engine, Executable};
pub use manifest::{default_artifact_dir, ArtifactSpec, InputSpec, Manifest};
pub use tensor::Tensor;
