//! Runtime layer: PJRT client + AOT artifact loading and execution.
//!
//! The only module touching the `xla` crate. Everything above it deals in
//! host [`Tensor`]s and manifest names (`"shedder_k1"`, `"detector"`, …).
//! Pattern adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Engine, Executable};
pub use manifest::{default_artifact_dir, ArtifactSpec, InputSpec, Manifest};
pub use tensor::{fill_cached, Tensor};

/// True when the AOT artifacts are built *and* a working PJRT runtime is
/// linked (false under the offline `xla` stub). Artifact-dependent tests
/// and pipelines gate on this instead of erroring.
pub fn artifacts_available() -> bool {
    Engine::from_default_artifacts().is_ok()
}
