//! PJRT execution engine: loads AOT HLO-text artifacts and runs them.
//!
//! This is the only module that touches the `xla` crate. The pattern is the
//! reference one from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`, with
//! the tupled result decomposed back into host [`Tensor`]s.
//!
//! Thread model: `PjRtClient` wraps a raw pointer and is not `Send`; each
//! pipeline thread that needs compute owns its own [`Engine`] (CPU client
//! creation is cheap, compilation is one-time per operator). Executables
//! validate their inputs against the manifest's shapes before every call,
//! so shape drift between `make artifacts` and the Rust side fails loudly.

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// One PJRT client plus a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

impl Engine {
    /// Create a CPU engine over the given artifact manifest.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Create an engine over the default artifact directory.
    pub fn from_default_artifacts() -> Result<Self> {
        Engine::new(Manifest::load_default()?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let exe = Rc::new(Executable { exe, spec });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn output_names(&self) -> &[String] {
        &self.spec.outputs
    }

    pub fn input_shapes(&self) -> Vec<&[usize]> {
        self.spec.inputs.iter().map(|i| i.shape.as_slice()).collect()
    }

    /// Execute with host tensors; returns one tensor per (named) output.
    ///
    /// Inputs are validated against the manifest's declared shapes. The
    /// artifact was lowered with `return_tuple=True`, so the single device
    /// output is a tuple that we decompose in output order.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' wants {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact '{}' input {}: shape {:?} != declared {:?}",
                    self.spec.name,
                    i,
                    t.shape(),
                    spec.shape
                );
            }
            let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(t.data())
                .reshape(&dims)
                .map_err_reshape(&self.spec.name, i)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let literal = result[0][0].to_literal_sync()?;
        let parts = literal.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest declares {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            let shape = part.shape()?;
            let dims: Vec<usize> = match &shape {
                xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                other => bail!("unexpected output shape {other:?}"),
            };
            let data = part.to_vec::<f32>()?;
            out.push(Tensor::new(data, dims)?);
        }
        Ok(out)
    }

    /// Run and return outputs keyed by their manifest names.
    pub fn run_named(&self, inputs: &[&Tensor]) -> Result<HashMap<String, Tensor>> {
        let outs = self.run(inputs)?;
        Ok(self
            .spec
            .outputs
            .iter()
            .cloned()
            .zip(outs)
            .collect())
    }
}

// Small helper to keep reshape error context without a closure per call.
trait ReshapeCtx {
    fn map_err_reshape(self, name: &str, idx: usize) -> Result<xla::Literal>;
}

impl ReshapeCtx for std::result::Result<xla::Literal, xla::Error> {
    fn map_err_reshape(self, name: &str, idx: usize) -> Result<xla::Literal> {
        self.with_context(|| format!("reshaping input {idx} of artifact '{name}'"))
    }
}
