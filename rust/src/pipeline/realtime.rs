//! Real-time driver over the shared streaming core
//! ([`crate::pipeline::core`]): actual threads and wall-clock pacing, with
//! the PJRT artifact path on the hot loop (the production configuration).
//! Used by the examples and the wall-clock benchmarks.
//!
//! The frame lifecycle, admission / control-loop wiring and metrics sink
//! are the *same code* the discrete-event simulator runs —
//! `pipeline::core::run_pipeline` — under a [`WallClock`] instead of a
//! [`SimClock`](crate::pipeline::SimClock). Decisions depend only on the
//! virtual-time event order, so the two drivers shed and transmit exactly
//! the same frames for the same seed and stream (pinned by
//! `rust/tests/core_equivalence.rs`); the wall clock adds pacing and
//! *measured* end-to-end latency on top.
//!
//! Thread topology (tokio is unavailable offline — std threads + mpsc):
//!
//! ```text
//!   [main: arrivals + extractor + Load Shedder + filter planner]
//!        │ DNN jobs (frames passing the filters)  ▲ completions
//!        ▼                                         │
//!   [backend worker: DNN surrogate (PJRT artifact or native oracle)]
//! ```
//!
//! The driver side runs the cheap filter stages (and samples the stage
//! cost model in dispatch order — the same sequence the simulator sees);
//! only DNN-bound frames ship to the worker, which executes the real
//! detector. The PJRT client is not `Send`, so the worker builds its own
//! `Engine` (cheap CPU client + one-time artifact compile).

use crate::backend::{BackendQuery, CostModel, Detector};
use crate::color::HueRanges;
use crate::config::{CostConfig, QueryConfig, ShedderConfig};
use crate::features::Extractor;
use crate::metrics::{LatencyTracker, QorTracker, Stage, StageCounts};
use crate::pipeline::core::{
    backgrounds_of, run_pipeline, ArrivalModel, BackendExecutor, FrameDecision, FramePayload,
    PipelineConfig, Policy, SimConfig, WallClock,
};
use crate::pipeline::faults::{FaultPlan, FaultStats};
use crate::pipeline::multi::{
    multi_backend_seed, run_multi_pipeline, MultiBackendExecutor, MultiPipelineReport,
    MultiSimConfig,
};
use crate::pipeline::supervise::{RunnerFactory, SupervisedWorker, SupervisorConfig};
use crate::pipeline::transport::TransportConfig;
use crate::pipeline::workloads::IterArrivals;
use crate::runtime::Engine;
use crate::shedder::{ArbiterPolicy, QuerySet};
use crate::utility::UtilityModel;
use crate::video::Video;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Real-time run parameters.
pub struct RealtimeConfig {
    /// The query: colors of interest, filter thresholds, latency bound.
    pub query: QueryConfig,
    /// Load-shedder tuning (admission CDF, queue capacity, control gains).
    pub shedder: ShedderConfig,
    /// Per-stage execution/transfer cost distributions (paper Table I).
    pub costs: CostConfig,
    /// Emulate the heavy-DNN latency by pacing backend completions to
    /// their virtual due time. 0.0 disables cost emulation (pure compute
    /// speed); any positive value enables it.
    pub cost_emulation_scale: f64,
    /// Wall-clock pacing: stream time × scale (1.0 = real time, 0.1 = 10×
    /// fast-forward). Cost emulation scales identically so the control
    /// loop sees a consistent world.
    pub time_scale: f64,
    /// Backend concurrency (token capacity).
    pub backend_tokens: u32,
    /// Use the AOT artifact path (false = native oracle; for A/B benches).
    pub use_artifacts: bool,
    /// Shedding policy (defaults to the paper's full control loop).
    pub policy: Policy,
    /// Seed for the stage cost model and policy coin — match the sim
    /// driver's seed to reproduce its exact decision sequence.
    pub seed: u64,
    /// Backend-budget split across queries for the multi-query entry
    /// points ([`run_multi_realtime`]); ignored by the single-query runs.
    pub arbiter: ArbiterPolicy,
    /// Modeled shedder→backend link + wire encoding (ideal by default;
    /// decisions stay clock-invariant with the sim driver either way).
    pub transport: TransportConfig,
    /// Rendezvous timeout (ms) for the backend worker: a hung detector
    /// produces a diagnosable error instead of blocking forever.
    pub backend_recv_timeout_ms: f64,
    /// Restart budget for a crashed backend worker (supervised respawn
    /// with exponential backoff); 0 disables restarts.
    pub worker_restart_max: u32,
    /// Base backoff (ms) before a worker respawn; doubles per restart.
    pub worker_restart_backoff_ms: f64,
    /// Scheduled fault windows (empty = the faultless verification mode;
    /// see [`crate::pipeline::faults`]).
    pub faults: FaultPlan,
    /// Online utility-model adaptation (off by default; see
    /// [`crate::utility::adapt`]). Decisions stay clock-invariant with the
    /// sim driver because adaptation is keyed to virtual label due times.
    pub adaptation: crate::utility::AdaptationConfig,
}

impl Default for RealtimeConfig {
    fn default() -> Self {
        RealtimeConfig::from_pipeline(&PipelineConfig::default(), RealtimeOpts::default())
    }
}

/// The wall-clock-only knobs of a realtime run — everything
/// [`RealtimeConfig`] carries beyond the shared
/// [`PipelineConfig`](crate::pipeline::PipelineConfig) slice (pacing,
/// cost emulation, artifact choice, worker supervision). This is the
/// argument of the builder's `.realtime(...)` mode selector.
#[derive(Debug, Clone)]
pub struct RealtimeOpts {
    /// See [`RealtimeConfig::cost_emulation_scale`].
    pub cost_emulation_scale: f64,
    /// See [`RealtimeConfig::time_scale`].
    pub time_scale: f64,
    /// See [`RealtimeConfig::use_artifacts`].
    pub use_artifacts: bool,
    /// See [`RealtimeConfig::backend_recv_timeout_ms`].
    pub backend_recv_timeout_ms: f64,
    /// See [`RealtimeConfig::worker_restart_max`].
    pub worker_restart_max: u32,
    /// See [`RealtimeConfig::worker_restart_backoff_ms`].
    pub worker_restart_backoff_ms: f64,
}

impl Default for RealtimeOpts {
    /// The historical `RealtimeConfig::default()` wall-clock values:
    /// real-time pacing with cost emulation, the AOT artifact path, a
    /// 30 s rendezvous timeout and a 2-restart worker budget.
    fn default() -> Self {
        RealtimeOpts {
            cost_emulation_scale: 1.0,
            time_scale: 1.0,
            use_artifacts: true,
            backend_recv_timeout_ms: 30_000.0,
            worker_restart_max: 2,
            worker_restart_backoff_ms: 50.0,
        }
    }
}

impl RealtimeOpts {
    /// The common test/demo configuration: native oracle (no artifacts),
    /// no cost emulation, `time_scale`× fast-forward pacing.
    pub fn fast_forward(time_scale: f64) -> Self {
        RealtimeOpts {
            cost_emulation_scale: 0.0,
            time_scale,
            use_artifacts: false,
            ..RealtimeOpts::default()
        }
    }
}

impl RealtimeConfig {
    /// Compose the shared lifecycle template with the wall-clock extras.
    /// `p.fps_total` is ignored — the realtime drivers always take the
    /// rate from the arrival model; the arbiter keeps its default
    /// (work-conserving weighted fair share) and only matters for the
    /// multi-query entry points.
    pub fn from_pipeline(p: &PipelineConfig, opts: RealtimeOpts) -> Self {
        RealtimeConfig {
            query: p.query.clone(),
            shedder: p.shedder.clone(),
            costs: p.costs.clone(),
            cost_emulation_scale: opts.cost_emulation_scale,
            time_scale: opts.time_scale,
            backend_tokens: p.backend_tokens,
            use_artifacts: opts.use_artifacts,
            policy: p.policy.clone(),
            seed: p.seed,
            arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
            transport: p.transport,
            backend_recv_timeout_ms: opts.backend_recv_timeout_ms,
            worker_restart_max: opts.worker_restart_max,
            worker_restart_backoff_ms: opts.worker_restart_backoff_ms,
            faults: p.faults.clone(),
            adaptation: p.adaptation.clone(),
        }
    }

    /// The shared lifecycle slice of this config, with `fps_total` from
    /// the arrival model — what the core engine actually runs on. The
    /// historical field-by-field hand-copies into `SimConfig` /
    /// `MultiSimConfig` route through here now.
    pub fn pipeline(&self, fps_total: f64) -> PipelineConfig {
        PipelineConfig {
            costs: self.costs.clone(),
            shedder: self.shedder.clone(),
            query: self.query.clone(),
            backend_tokens: self.backend_tokens,
            policy: self.policy.clone(),
            seed: self.seed,
            fps_total,
            transport: self.transport,
            faults: self.faults.clone(),
            adaptation: self.adaptation.clone(),
        }
    }
}

/// Supervisor policy derived from the run parameters.
fn supervisor_cfg(cfg: &RealtimeConfig) -> SupervisorConfig {
    SupervisorConfig {
        recv_timeout: Duration::from_secs_f64(
            (cfg.backend_recv_timeout_ms / 1e3).max(1e-3),
        ),
        max_restarts: cfg.worker_restart_max,
        backoff: Duration::from_secs_f64((cfg.worker_restart_backoff_ms / 1e3).max(0.0)),
    }
}

/// Results of a real-time run.
pub struct RealtimeReport {
    /// Quality-of-result accounting (detected vs missed targets).
    pub qor: QorTracker,
    /// Measured end-to-end frame latency distribution (stream-time ms).
    pub latency: LatencyTracker,
    /// Per-stage frame counts.
    pub stages: StageCounts,
    /// Terminal shed/transmit decision per ingress frame (event order).
    pub decisions: Vec<FrameDecision>,
    /// Frames that arrived at the Load Shedder.
    pub ingress: u64,
    /// Frames delivered to the backend.
    pub transmitted: u64,
    /// Frames shed (admission gate, queue eviction, or deadline check).
    pub shed: u64,
    /// Frames lost on the modeled link (0 under the ideal default).
    pub link_dropped: u64,
    /// Bytes serialized onto the shedder→backend link.
    pub bytes_on_wire: u64,
    /// Wall-clock duration of the run.
    pub wall: Duration,
    /// Mean extractor latency per frame (ms) — the camera-side overhead.
    pub extract_ms_mean: f64,
    /// Fault / degradation counters (all zero on a faultless run).
    /// `ingress == transmitted + shed + link_dropped + faults.fault_dropped`.
    pub faults: FaultStats,
    /// Online-adaptation counters (all zero with adaptation disabled).
    pub adaptation: crate::utility::AdaptationStats,
    /// Times the supervised backend worker was respawned after a crash.
    pub worker_restarts: u32,
}

/// A DNN-bound frame shipped to the backend worker. `Clone` so the
/// supervisor can keep a replay copy until the job is acked.
#[derive(Clone)]
struct DnnJob {
    camera: u32,
    rgb: Vec<f32>,
    width: usize,
    height: usize,
}

/// Threaded [`BackendExecutor`]: filter stages + cost sampling on the
/// driver thread (keeping the cost-model sequence identical to the sim
/// driver), real DNN execution on a supervised worker thread —
/// restart-on-crash within a bounded budget, `recv_timeout` rendezvous
/// (see [`crate::pipeline::supervise`]).
pub struct ThreadedBackend {
    planner: BackendQuery,
    worker: SupervisedWorker<DnnJob>,
    /// Dispatch ordinal of the next `submit` call (mirrors the core's
    /// `seq` numbering — both count submits in the same order).
    submit_seq: u64,
    /// Dispatch seq → 0-based DNN job index, for submissions that shipped
    /// a worker job. The worker runs jobs FIFO, so job `k` is finished
    /// once `k + 1` done signals have been received.
    dnn_job_of: HashMap<u64, u64>,
    jobs_submitted: u64,
}

impl ThreadedBackend {
    /// Spawn the supervised backend worker. The runner factory owns
    /// shared per-camera backgrounds (one copy per camera, not per
    /// frame) and builds the detector *inside* each worker incarnation —
    /// the PJRT handle is not `Send`.
    pub fn spawn(videos: &[Video], cfg: &RealtimeConfig) -> Result<Self> {
        let bgs: Arc<HashMap<u32, Vec<f32>>> = Arc::new(
            videos
                .iter()
                .map(|v| (v.camera_id(), v.background().to_vec()))
                .collect(),
        );
        let ranges: Arc<Vec<HueRanges>> =
            Arc::new(cfg.query.colors.iter().map(|c| c.ranges()).collect());
        let use_artifacts = cfg.use_artifacts;
        let factory: RunnerFactory<DnnJob> = Arc::new(move || {
            let detector = if use_artifacts {
                let engine = Engine::from_default_artifacts()?;
                Detector::artifact(&engine)?
            } else {
                Detector::native(12, 25.0)
            };
            let bgs = Arc::clone(&bgs);
            let ranges = Arc::clone(&ranges);
            Ok(Box::new(move |job: &DnnJob| {
                let bg = bgs
                    .get(&job.camera)
                    .ok_or_else(|| anyhow!("no background for camera {}", job.camera))?;
                let _ = detector.detect(&job.rgb, bg, job.width, job.height, &ranges)?;
                Ok(())
            }))
        });
        let worker = SupervisedWorker::spawn(factory, supervisor_cfg(cfg))?;
        let planner = BackendQuery::new(
            cfg.query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), cfg.seed),
            25.0,
        );
        Ok(ThreadedBackend {
            planner,
            worker,
            submit_seq: 0,
            dnn_job_of: HashMap::new(),
            jobs_submitted: 0,
        })
    }

    /// Times the supervised worker was respawned after a crash.
    pub fn worker_restarts(&self) -> u32 {
        self.worker.restarts()
    }
}

impl BackendExecutor for ThreadedBackend {
    fn submit(&mut self, payload: FramePayload, background: &[f32]) -> Result<(Stage, f64)> {
        let seq = self.submit_seq;
        self.submit_seq += 1;
        // Filter stages + cost sampling in dispatch order (the DNN itself
        // is skipped here and executed for real on the worker).
        let r = self
            .planner
            .plan(&payload.rgb, background, payload.width, payload.height)?;
        if r.last_stage == Stage::Sink {
            let job = DnnJob {
                camera: payload.camera,
                rgb: payload.rgb,
                width: payload.width,
                height: payload.height,
            };
            // A dead channel triggers a supervised restart (with replay);
            // only an exhausted restart budget surfaces as an error — the
            // worker's *actual* failure cause, not a generic disconnect.
            self.worker.submit(job)?;
            self.dnn_job_of.insert(seq, self.jobs_submitted);
            self.jobs_submitted += 1;
        }
        Ok((r.last_stage, r.exec_ms))
    }

    fn on_complete(&mut self, seq: u64, dnn: bool) -> Result<()> {
        if !dnn {
            return Ok(());
        }
        // Rendezvous: this completion is only real once the worker's
        // detector finished *this submission's* job. The worker is FIFO,
        // so job k is done once k + 1 done signals have arrived — correct
        // even when `backend_tokens > 1` pops completions out of dispatch
        // order (a later-dispatched job may already have been drained by
        // an earlier-popping completion, in which case this returns
        // without waiting). The supervisor bounds the wait with
        // `recv_timeout` and restarts through crashes.
        let job = self
            .dnn_job_of
            .remove(&seq)
            .ok_or_else(|| anyhow!("completion for unknown dispatch seq {seq}"))?;
        self.worker.wait_for(job)
    }

    fn finish(&mut self) -> Result<()> {
        self.worker.finish()
    }
}

/// Run the multi-camera stream through the real-time pipeline.
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.realtime(opts)`
/// [`.run(videos, model)`](crate::pipeline::RealtimeBuilder::run); this
/// free function is kept as a thin compatibility wrapper.
pub fn run_realtime(
    videos: &[Video],
    model: &UtilityModel,
    cfg: &RealtimeConfig,
) -> Result<RealtimeReport> {
    let fps_total = crate::video::streamer::aggregate_fps(videos);
    run_realtime_with(
        videos,
        model,
        cfg,
        IterArrivals::new(crate::video::Streamer::new(videos), fps_total),
    )
}

/// [`run_realtime`] over any [`ArrivalModel`] — the wall-clock driver
/// against a pluggable workload (bursty Poisson ingress, camera churn, …).
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.realtime(opts)`
/// [`.run_with(videos, model, arrivals)`](crate::pipeline::RealtimeBuilder::run_with);
/// this free function is kept as a thin compatibility wrapper.
pub fn run_realtime_with<A: ArrivalModel>(
    videos: &[Video],
    model: &UtilityModel,
    cfg: &RealtimeConfig,
    arrivals: A,
) -> Result<RealtimeReport> {
    let start = Instant::now();
    let core_cfg: SimConfig = cfg.pipeline(arrivals.fps_total()).into();

    let extractor = if cfg.use_artifacts {
        let engine = Engine::from_default_artifacts()?;
        Extractor::artifact(&engine, model.clone())?
    } else {
        Extractor::native(model.clone())
    };

    let backgrounds = backgrounds_of(videos);
    let mut executor = ThreadedBackend::spawn(videos, cfg)?;
    let mut clock =
        WallClock::new(cfg.time_scale).with_completion_pacing(cfg.cost_emulation_scale > 0.0);
    let report = run_pipeline(
        arrivals,
        &backgrounds,
        &core_cfg,
        &extractor,
        &mut executor,
        &mut clock,
    )?;

    let extract_ms_mean = report.extract_ms_mean();
    Ok(RealtimeReport {
        qor: report.qor,
        latency: report.latency,
        stages: report.stages,
        decisions: report.decisions,
        ingress: report.ingress,
        transmitted: report.transmitted,
        shed: report.shed,
        link_dropped: report.link_dropped,
        bytes_on_wire: report.bytes_on_wire,
        wall: start.elapsed(),
        extract_ms_mean,
        faults: report.faults,
        adaptation: report.adaptation,
        worker_restarts: executor.worker_restarts(),
    })
}

// ---------------------------------------------------------------------------
// Multi-query wall-clock driver
// ---------------------------------------------------------------------------

/// A DNN-bound (frame, query) shipped to the shared backend worker.
/// `Clone` so the supervisor can replay unacked jobs after a restart.
#[derive(Clone)]
struct MultiDnnJob {
    query: usize,
    camera: u32,
    rgb: Vec<f32>,
    width: usize,
    height: usize,
}

/// Threaded [`MultiBackendExecutor`]: per-query filter planners (each
/// with its own cost model, seeded as [`multi_backend_seed`] prescribes,
/// so decisions match the discrete-event multi driver) on the driver
/// thread; one shared supervised worker thread runs the real detector
/// for every query's DNN-bound frames — only the admitted queries ever
/// reach it.
pub struct MultiThreadedBackend {
    planners: Vec<BackendQuery>,
    worker: SupervisedWorker<MultiDnnJob>,
    /// Next dispatch ordinal per query (mirrors the engine's per-query
    /// `seq` numbering — both count that query's submits in order).
    submit_seq: Vec<u64>,
    /// (query, per-query dispatch seq) → global FIFO job index.
    dnn_job_of: HashMap<(usize, u64), u64>,
    jobs_submitted: u64,
}

impl MultiThreadedBackend {
    /// Spawn the shared supervised worker. The runner factory owns one
    /// background clone per camera and per-query hue ranges; the
    /// detector is built inside each worker incarnation (the PJRT handle
    /// is not `Send`).
    pub fn spawn(videos: &[Video], set: &QuerySet, cfg: &RealtimeConfig) -> Result<Self> {
        let bgs: Arc<HashMap<u32, Vec<f32>>> = Arc::new(
            videos
                .iter()
                .map(|v| (v.camera_id(), v.background().to_vec()))
                .collect(),
        );
        let ranges_by_query: Arc<Vec<Vec<HueRanges>>> = Arc::new(
            set.queries()
                .iter()
                .map(|q| q.config.colors.iter().map(|c| c.ranges()).collect())
                .collect(),
        );
        let use_artifacts = cfg.use_artifacts;
        let factory: RunnerFactory<MultiDnnJob> = Arc::new(move || {
            let detector = if use_artifacts {
                let engine = Engine::from_default_artifacts()?;
                Detector::artifact(&engine)?
            } else {
                Detector::native(12, 25.0)
            };
            let bgs = Arc::clone(&bgs);
            let ranges_by_query = Arc::clone(&ranges_by_query);
            Ok(Box::new(move |job: &MultiDnnJob| {
                let bg = bgs
                    .get(&job.camera)
                    .ok_or_else(|| anyhow!("no background for camera {}", job.camera))?;
                let _ = detector.detect(
                    &job.rgb,
                    bg,
                    job.width,
                    job.height,
                    &ranges_by_query[job.query],
                )?;
                Ok(())
            }))
        });
        let worker = SupervisedWorker::spawn(factory, supervisor_cfg(cfg))?;
        let planners = set
            .queries()
            .iter()
            .enumerate()
            .map(|(qi, q)| {
                BackendQuery::new(
                    q.config.clone(),
                    Detector::native(12, 25.0),
                    CostModel::new(cfg.costs.clone(), multi_backend_seed(cfg.seed, qi)),
                    25.0,
                )
            })
            .collect();
        Ok(MultiThreadedBackend {
            planners,
            worker,
            submit_seq: vec![0; set.len()],
            dnn_job_of: HashMap::new(),
            jobs_submitted: 0,
        })
    }

    /// Times the supervised worker was respawned after a crash.
    pub fn worker_restarts(&self) -> u32 {
        self.worker.restarts()
    }
}

impl MultiBackendExecutor for MultiThreadedBackend {
    fn submit(
        &mut self,
        query: usize,
        payload: &FramePayload,
        background: &[f32],
    ) -> Result<(Stage, f64)> {
        // Filter stages + cost sampling on the driver thread, in this
        // query's dispatch order (the multi cost contract); the DNN runs
        // for real on the worker.
        let seq = self.submit_seq[query];
        self.submit_seq[query] += 1;
        let r = self.planners[query].plan(
            &payload.rgb,
            background,
            payload.width,
            payload.height,
        )?;
        if r.last_stage == Stage::Sink {
            let job = MultiDnnJob {
                query,
                camera: payload.camera,
                rgb: payload.rgb.clone(),
                width: payload.width,
                height: payload.height,
            };
            // Supervised send: a dead channel restarts (with replay), an
            // exhausted budget surfaces the worker's actual failure.
            self.worker.submit(job)?;
            self.dnn_job_of.insert((query, seq), self.jobs_submitted);
            self.jobs_submitted += 1;
        }
        Ok((r.last_stage, r.exec_ms))
    }

    fn on_complete(&mut self, query: usize, seq: u64, dnn: bool) -> Result<()> {
        if !dnn {
            return Ok(());
        }
        let job = self
            .dnn_job_of
            .remove(&(query, seq))
            .ok_or_else(|| anyhow!("completion for unknown dispatch ({query}, {seq})"))?;
        self.worker.wait_for(job)
    }

    fn finish(&mut self) -> Result<()> {
        self.worker.finish()
    }
}

/// Run N concurrent queries over the shared multi-camera stream through
/// the wall-clock pipeline (the multi-query analogue of
/// [`run_realtime`]). Decisions are clock-invariant with
/// [`crate::pipeline::run_multi_sim`] for the same seed and stream.
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.multi_query(set).realtime(opts)`
/// [`.run(videos)`](crate::pipeline::MultiRealtimeBuilder::run); this
/// free function is kept as a thin compatibility wrapper.
pub fn run_multi_realtime(
    videos: &[Video],
    set: &QuerySet,
    cfg: &RealtimeConfig,
) -> Result<MultiPipelineReport> {
    let fps_total = crate::video::streamer::aggregate_fps(videos);
    run_multi_realtime_with(
        videos,
        set,
        cfg,
        IterArrivals::new(crate::video::Streamer::new(videos), fps_total),
    )
}

/// [`run_multi_realtime`] over any [`ArrivalModel`] workload.
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.multi_query(set).realtime(opts)`
/// [`.run_with(videos, arrivals)`](crate::pipeline::MultiRealtimeBuilder::run_with);
/// this free function is kept as a thin compatibility wrapper.
pub fn run_multi_realtime_with<A: ArrivalModel>(
    videos: &[Video],
    set: &QuerySet,
    cfg: &RealtimeConfig,
    arrivals: A,
) -> Result<MultiPipelineReport> {
    let core_cfg =
        MultiSimConfig::from_pipeline(&cfg.pipeline(arrivals.fps_total()), cfg.arbiter);
    let union = set.union_model();
    let extractor = if cfg.use_artifacts {
        if union.colors.len() > 2 {
            bail!(
                "artifact extraction supports at most 2 union colors, got {} — \
                 run with use_artifacts = false",
                union.colors.len()
            );
        }
        let engine = Engine::from_default_artifacts()?;
        Extractor::artifact(&engine, union.clone())?
    } else {
        Extractor::native(union.clone())
    };

    let backgrounds = backgrounds_of(videos);
    let mut executor = MultiThreadedBackend::spawn(videos, set, cfg)?;
    let mut clock =
        WallClock::new(cfg.time_scale).with_completion_pacing(cfg.cost_emulation_scale > 0.0);
    run_multi_pipeline(
        arrivals,
        &backgrounds,
        set,
        &core_cfg,
        &extractor,
        &mut executor,
        &mut clock,
    )
}
