//! Real-time pipeline runtime: actual threads, channels and wall-clock
//! pacing, with the PJRT artifact path on the hot loop (the production
//! configuration). Used by the examples and wall-clock benchmarks.
//!
//! Thread topology (tokio is unavailable offline — std threads + mpsc):
//!
//! ```text
//!   [main: streamer + extractor + Load Shedder]
//!        │ work channel (token-paced)            ▲ completion channel
//!        ▼                                        │
//!   [backend worker: filters + DNN surrogate (+ emulated DNN cost)]
//! ```
//!
//! The PJRT client is not `Send`, so each thread builds its own `Engine`
//! (cheap CPU client + one-time artifact compile).

use crate::backend::{BackendQuery, CostModel, Detector};
use crate::config::{CostConfig, QueryConfig, ShedderConfig};
use crate::features::{Extractor, FrameFeatures, UtilityValues};
use crate::metrics::{LatencyTracker, QorTracker, Stage, StageCounts};
use crate::runtime::Engine;
use crate::shedder::{Decision, LoadShedder, TokenBucket};
use crate::utility::UtilityModel;
use crate::video::Video;
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Real-time run parameters.
pub struct RealtimeConfig {
    pub query: QueryConfig,
    pub shedder: ShedderConfig,
    pub costs: CostConfig,
    /// Emulate the heavy-DNN latency by sleeping `exec_ms × scale` in the
    /// backend worker. 0.0 disables cost emulation (pure compute speed).
    pub cost_emulation_scale: f64,
    /// Wall-clock pacing: stream time × scale (1.0 = real time, 0.1 = 10×
    /// fast-forward). Cost emulation scales identically so the control
    /// loop sees a consistent world.
    pub time_scale: f64,
    pub backend_tokens: u32,
    /// Use the AOT artifact path (false = native oracle; for A/B benches).
    pub use_artifacts: bool,
}

impl Default for RealtimeConfig {
    fn default() -> Self {
        RealtimeConfig {
            query: QueryConfig::single(crate::color::NamedColor::Red),
            shedder: ShedderConfig::default(),
            costs: CostConfig::default(),
            cost_emulation_scale: 1.0,
            time_scale: 1.0,
            backend_tokens: 1,
            use_artifacts: true,
        }
    }
}

/// Results of a real-time run.
pub struct RealtimeReport {
    pub qor: QorTracker,
    pub latency: LatencyTracker,
    pub stages: StageCounts,
    pub ingress: u64,
    pub transmitted: u64,
    pub shed: u64,
    /// Wall-clock duration of the run.
    pub wall: Duration,
    /// Mean extractor latency per frame (ms) — the camera-side overhead.
    pub extract_ms_mean: f64,
}

struct WorkItem {
    capture_stream_ms: f64,
    capture_wall: Instant,
    target_ids: Vec<u64>,
    rgb: Vec<f32>,
    width: usize,
    height: usize,
}

struct DoneItem {
    capture_stream_ms: f64,
    capture_wall: Instant,
    target_ids: Vec<u64>,
    last_stage: Stage,
    exec_ms: f64,
}

/// Run the multi-camera stream through the real-time pipeline.
pub fn run_realtime(
    videos: &[Video],
    model: &UtilityModel,
    cfg: &RealtimeConfig,
) -> Result<RealtimeReport> {
    let start = Instant::now();
    let fps_total = crate::video::streamer::aggregate_fps(videos);

    // --- backend worker -----------------------------------------------------
    let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
    let (done_tx, done_rx) = mpsc::channel::<DoneItem>();
    let bq_query = cfg.query.clone();
    let bq_costs = cfg.costs.clone();
    let emulation = cfg.cost_emulation_scale * cfg.time_scale;
    let use_artifacts = cfg.use_artifacts;
    let worker = std::thread::spawn(move || -> Result<()> {
        let detector = if use_artifacts {
            let engine = Engine::from_default_artifacts()?;
            Detector::artifact(&engine)?
        } else {
            Detector::native(12, 25.0)
        };
        let mut backend = BackendQuery::new(
            bq_query,
            detector,
            CostModel::new(bq_costs, 0xB__E),
            25.0,
        );
        // The worker needs per-camera backgrounds; they ride in on the
        // first frame of each camera via rgb-background pairing below.
        while let Ok(item) = work_rx.recv() {
            let (bg, rgb) = item.rgb.split_at(item.rgb.len() / 2);
            let result = backend.process(rgb, bg, item.width, item.height)?;
            if emulation > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(
                    result.exec_ms * emulation / 1000.0,
                ));
            }
            let _ = done_tx.send(DoneItem {
                capture_stream_ms: item.capture_stream_ms,
                capture_wall: item.capture_wall,
                target_ids: item.target_ids,
                last_stage: result.last_stage,
                exec_ms: result.exec_ms,
            });
        }
        Ok(())
    });

    // --- edge side: streamer + extractor + shedder ---------------------------
    let extractor = if cfg.use_artifacts {
        let engine = Engine::from_default_artifacts()?;
        Extractor::artifact(&engine, model.clone())?
    } else {
        Extractor::native(model.clone())
    };

    let mut shedder: LoadShedder<WorkItem> = LoadShedder::new(
        &cfg.shedder,
        &cfg.costs,
        cfg.query.latency_bound_ms,
        fps_total,
    );
    let mut tokens = TokenBucket::new(cfg.backend_tokens.max(1));
    let mut qor = QorTracker::new();
    let mut latency = LatencyTracker::new(cfg.query.latency_bound_ms);
    let mut stages = StageCounts::new(5_000.0);
    let (mut ingress, mut transmitted, mut shed) = (0u64, 0u64, 0u64);
    let mut extract_ms_sum = 0.0f64;
    // Reused feature/utility buffers: the camera-side hot loop stays
    // allocation-free (zero-allocation API sweep).
    let mut feat_buf = FrameFeatures::empty();
    let mut util_buf = UtilityValues::empty();

    let t0 = Instant::now();
    let handle_done = |d: DoneItem,
                           tokens: &mut TokenBucket,
                           shedder: &mut LoadShedder<WorkItem>,
                           latency: &mut LatencyTracker,
                           stages: &mut StageCounts|
     {
        tokens.release();
        shedder.on_backend_complete(d.exec_ms);
        // E2E in *stream* time: wall elapsed since capture, descaled.
        let e2e_wall_ms = d.capture_wall.elapsed().as_secs_f64() * 1e3;
        let e2e_stream_ms = if cfg.time_scale > 0.0 {
            e2e_wall_ms / cfg.time_scale
        } else {
            e2e_wall_ms
        };
        latency.observe(e2e_stream_ms);
        stages.observe(Stage::BlobFilter, d.capture_stream_ms);
        if d.last_stage >= Stage::ColorFilter {
            stages.observe(Stage::ColorFilter, d.capture_stream_ms);
        }
        if d.last_stage == Stage::Sink {
            stages.observe(Stage::Dnn, d.capture_stream_ms);
            stages.observe(Stage::Sink, d.capture_stream_ms);
        }
        let _ = &d.target_ids;
    };

    for frame in crate::video::Streamer::new(videos) {
        // Pace to stream time.
        let due = Duration::from_secs_f64(frame.ts_ms / 1000.0 * cfg.time_scale);
        let elapsed = t0.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
        // Drain completions.
        while let Ok(d) = done_rx.try_recv() {
            handle_done(d, &mut tokens, &mut shedder, &mut latency, &mut stages);
        }

        ingress += 1;
        stages.observe(Stage::Ingress, frame.ts_ms);
        let bg = videos
            .iter()
            .find(|v| v.camera_id() == frame.camera)
            .unwrap()
            .background();
        let te = Instant::now();
        extractor.extract_camera_into(
            frame.camera,
            frame.width,
            frame.height,
            &frame.rgb,
            bg,
            &mut feat_buf,
            &mut util_buf,
        )?;
        extract_ms_sum += te.elapsed().as_secs_f64() * 1e3;

        let mut target_ids = Vec::new();
        frame.target_ids_into(&cfg.query.colors, cfg.query.min_blob_px, &mut target_ids);
        // Pack background + rgb together so the worker needs no shared map.
        let mut packed = Vec::with_capacity(frame.rgb.len() * 2);
        packed.extend_from_slice(bg);
        packed.extend_from_slice(&frame.rgb);
        let item = WorkItem {
            capture_stream_ms: frame.ts_ms,
            capture_wall: t0 + Duration::from_secs_f64(frame.ts_ms / 1000.0 * cfg.time_scale),
            target_ids: target_ids.clone(),
            rgb: packed,
            width: frame.width,
            height: frame.height,
        };
        let (decision, evicted) =
            shedder.on_ingress(util_buf.combined, frame.ts_ms, item);
        for e in evicted {
            qor.observe(&e.item.target_ids, false);
            stages.observe(Stage::Shed, e.item.capture_stream_ms);
            shed += 1;
        }
        match decision {
            Decision::ShedAdmission | Decision::ShedQueueReject => {
                qor.observe(&target_ids, false);
                stages.observe(Stage::Shed, frame.ts_ms);
                shed += 1;
            }
            Decision::Enqueued => {}
        }

        // Transmit while tokens allow.
        while tokens.available() > 0 {
            let Some(entry) = shedder.next_to_send() else { break };
            assert!(tokens.try_acquire());
            qor.observe(&entry.item.target_ids, true);
            transmitted += 1;
            work_tx.send(entry.item).expect("backend alive");
        }
    }

    // Drain: close the work channel after flushing the queue.
    loop {
        while tokens.available() > 0 {
            let Some(entry) = shedder.next_to_send() else { break };
            assert!(tokens.try_acquire());
            qor.observe(&entry.item.target_ids, true);
            transmitted += 1;
            work_tx.send(entry.item).expect("backend alive");
        }
        if tokens.in_flight() == 0 && shedder.queue.is_empty() {
            break;
        }
        let d = done_rx.recv().expect("completion");
        handle_done(d, &mut tokens, &mut shedder, &mut latency, &mut stages);
    }
    drop(work_tx);
    worker.join().expect("worker panicked")?;
    while let Ok(d) = done_rx.try_recv() {
        handle_done(d, &mut tokens, &mut shedder, &mut latency, &mut stages);
    }

    Ok(RealtimeReport {
        qor,
        latency,
        stages,
        ingress,
        transmitted,
        shed,
        wall: start.elapsed(),
        extract_ms_mean: if ingress > 0 { extract_ms_sum / ingress as f64 } else { 0.0 },
    })
}
