//! Pipeline runtimes connecting cameras → Load Shedder → backend query.
//!
//! * [`sim`] — deterministic discrete-event simulator with calibrated stage
//!   costs; regenerates the paper's long-running experiments in seconds.
//! * [`parallel`] — sharded multi-camera sweep engine: one simulation shard
//!   per camera across scoped threads, deterministic metric merge.
//! * [`realtime`] — thread-per-component runtime over std channels with the
//!   PJRT artifact path on the hot loop; used by the examples and the
//!   wall-clock benchmarks.

pub mod parallel;
pub mod realtime;
pub mod sim;

pub use parallel::{
    default_threads, merge_reports, parallel_map, run_sharded_sim, run_sharded_sim_with,
};
pub use sim::{backgrounds_of, run_sim, BackgroundMap, Policy, SimConfig, SimReport};
