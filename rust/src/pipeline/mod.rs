//! Pipeline runtimes connecting cameras → Load Shedder → backend query.
//!
//! One frame lifecycle, three drivers:
//!
//! * [`core`] — the clock-abstracted streaming core: the single
//!   implementation of capture → extract → utility → admission → queue →
//!   dispatch → backend → completion, parameterized by [`Clock`],
//!   [`ArrivalModel`] and [`BackendExecutor`], feeding one metrics sink.
//! * [`multi`] — the multi-query path: N queries over one shared stream,
//!   one extraction per frame, per-query shedding behind a capacity
//!   arbiter (see [`crate::shedder::multi`]).
//! * [`workloads`] — arrival-model plugins: plain interleaved streams,
//!   bursty Poisson ingress, mid-run camera churn.
//! * [`sim`] — discrete-event driver ([`SimClock`] + in-process backend);
//!   regenerates the paper's long-running experiments in seconds.
//! * [`realtime`] — wall-clock driver ([`WallClock`] + worker-thread
//!   backend with the PJRT artifact path on the hot loop).
//! * [`reactor`] — socket-backed realtime driver: an epoll reactor ships
//!   wire-encoded frames over real loopback TCP/Unix sockets to a
//!   backend worker pool, and the *measured* per-frame transfers feed
//!   the control loop's network budget (Eq. 19/20) in place of modeled
//!   [`LinkModel`](transport::LinkModel) samples.
//! * [`parallel`] — sharded multi-camera sweep engine: one sim-driver
//!   shard per camera across scoped threads, deterministic metric merge.
//! * [`transport`] — the modeled shedder→backend network link: FIFO
//!   serialization at a configured bandwidth over each frame's actual
//!   wire size ([`crate::video::wire`]), propagation, jitter, loss.
//! * [`faults`] — seeded, clock-abstracted fault injection: scheduled
//!   virtual-time windows of camera dropout/freeze, link blackout /
//!   bandwidth collapse, worker crash / straggler slowdown, poisoned
//!   control observations. The empty plan is bit-identical to a
//!   faultless run.
//! * [`supervise`] — the supervised worker-thread harness behind the
//!   realtime backends: restart-on-crash with bounded retries and
//!   exponential backoff, timeout-bounded rendezvous.
//! * [`fleet`] — fleet-scale hierarchical shedding: E edge nodes (each
//!   a multi-query run over its camera slice) feed a regional
//!   aggregator running a second-level shedder in front of M backend
//!   workers, with cross-tier conservation and deterministic replay.
//! * [`builder`] — the unified entry point: [`Pipeline::builder()`]
//!   composes one [`PipelineConfig`] template into any deployment
//!   (sim / multi-query / realtime / sharded / fleet), replacing the
//!   historical free-function matrix (kept as thin wrappers).

// The pipeline is the long-running production surface: a stray panic in
// it takes the whole edge deployment down, so unwrap/expect must either
// be converted to Result paths or carry an explicit invariant
// justification under `#[allow]` (tests are blanket-allowed).
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod builder;
pub mod core;
pub mod faults;
pub mod fleet;
pub mod multi;
pub mod parallel;
pub mod reactor;
pub mod realtime;
pub mod sim;
pub mod supervise;
pub mod transport;
pub mod workloads;

pub use self::core::{
    backgrounds_of, run_pipeline, ArrivalModel, BackendExecutor, BackgroundMap, Clock,
    EventClass, FrameDecision, FramePayload, PipelineConfig, PipelineReport, Policy, SimClock,
    SimConfig, SyncBackend, WallClock,
};
pub use builder::{
    FleetBuilder, MultiQueryBuilder, MultiRealtimeBuilder, Pipeline, PipelineBuilder,
    ReactorBuilder, RealtimeBuilder, ShardedBuilder, SimBuilder,
};
pub use crate::utility::{AdaptEvent, AdaptEventKind, AdaptationConfig, AdaptationStats};
pub use faults::{FaultKind, FaultPlan, FaultStats, FaultWindow, PoisonKind};
pub use fleet::{
    fleet_node_seed, run_fleet, AggregatorPolicy, FleetConfig, FleetDecision, FleetOutcome,
    FleetQueryReport, FleetReport, FleetTopology,
};
pub use multi::{
    multi_backend_seed, multi_backends, run_multi_pipeline, MultiBackendExecutor,
    MultiPipelineReport, MultiSimConfig, MultiSyncBackend, QueryReport,
};
pub use parallel::{
    default_threads, merge_reports, parallel_map, run_sharded_sim, run_sharded_sim_with,
};
pub use reactor::{
    run_reactor, run_reactor_with, ReactorBackend, ReactorOpts, ReactorReport, SocketKind,
    SocketStats,
};
pub use realtime::{RealtimeConfig, RealtimeOpts, RealtimeReport};
pub use sim::{run_multi_sim, run_multi_sim_with, run_sim, run_sim_with, SimReport};
pub use supervise::{Runner, RunnerFactory, SupervisedWorker, SupervisorConfig};
pub use transport::{Link, LinkModel, Transmission, TransportConfig};
pub use workloads::{CameraChurn, ChurnWindow, IterArrivals, PoissonArrivals};
