//! Pipeline runtimes connecting cameras → Load Shedder → backend query.
//!
//! * [`sim`] — deterministic discrete-event simulator with calibrated stage
//!   costs; regenerates the paper's long-running experiments in seconds.
//! * [`realtime`] — thread-per-component runtime over std channels with the
//!   PJRT artifact path on the hot loop; used by the examples and the
//!   wall-clock benchmarks.

pub mod realtime;
pub mod sim;

pub use sim::{run_sim, Policy, SimConfig, SimReport};
