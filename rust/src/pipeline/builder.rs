//! One unified entry point over every deployment driver:
//! [`Pipeline::builder()`].
//!
//! Historically each deployment shape had its own free function —
//! `run_sim` / `run_sim_with` / `run_multi_sim` / `run_multi_sim_with`
//! / `run_realtime(_with)` / `run_multi_realtime(_with)` /
//! `run_sharded_sim(_with)` — an 8-way matrix that forced every caller
//! to re-assemble the same config literals. The builder replaces the
//! matrix with one shared [`PipelineConfig`] template plus a mode
//! selector:
//!
//! ```text
//!   Pipeline::builder()            shared lifecycle knobs
//!       .seed(..).fps_total(..)    (costs, shedder, transport, …)
//!       │
//!       ├─ .sim()                  discrete-event, single query
//!       ├─ .multi_query(&set)      N queries, shared stream
//!       │      └─ .realtime(opts)  …under the wall clock
//!       ├─ .realtime(opts)         wall clock, single query
//!       │      └─ .reactor(ropts)  …over real loopback sockets (epoll)
//!       ├─ .sharded(threads)       one shard per camera
//!       └─ .fleet(topology)        edge nodes → aggregator → cluster
//! ```
//!
//! Every terminal `run*` method drives the exact historical
//! construction (same extractor, same backend seeds, same engine), so
//! builder runs bit-match the free functions — pinned by
//! `rust/tests/builder_defaults.rs`. The free functions remain as thin
//! compatibility wrappers with `Deprecated:` doc pointers here.

use crate::backend::BackendQuery;
use crate::config::{CostConfig, QueryConfig, ShedderConfig};
use crate::features::{Extractor, IncrementalConfig};
use crate::pipeline::core::{backgrounds_of, ArrivalModel, BackgroundMap, PipelineConfig, Policy};
use crate::pipeline::fleet::{run_fleet, FleetConfig, FleetReport, FleetTopology};
use crate::pipeline::multi::{multi_backends, MultiPipelineReport, MultiSimConfig};
use crate::pipeline::reactor::{run_reactor, run_reactor_with, ReactorOpts, ReactorReport};
use crate::pipeline::realtime::{
    run_multi_realtime, run_multi_realtime_with, run_realtime, run_realtime_with, RealtimeConfig,
    RealtimeOpts, RealtimeReport,
};
use crate::pipeline::sim::{
    run_multi_sim, run_multi_sim_with, run_sim, run_sim_with, SimConfig, SimReport,
};
use crate::pipeline::transport::TransportConfig;
use crate::pipeline::{parallel, FaultPlan};
use crate::shedder::{ArbiterPolicy, QuerySet};
use crate::utility::{AdaptationConfig, UtilityModel};
use crate::video::{Frame, Streamer, Video};
use anyhow::Result;

/// Namespace for the unified pipeline API: [`Pipeline::builder()`] is
/// the one front door to every deployment driver.
pub struct Pipeline;

impl Pipeline {
    /// Start from [`PipelineConfig::default()`] (the historical
    /// `SimConfig`/`RealtimeConfig` defaults, pinned by test).
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder { cfg: PipelineConfig::default() }
    }
}

/// Shared-template stage of the builder: set the lifecycle knobs every
/// deployment understands, then pick a mode.
#[derive(Debug, Clone)]
pub struct PipelineBuilder {
    cfg: PipelineConfig,
}

impl PipelineBuilder {
    /// Replace the whole template (e.g. a tier config pulled from an
    /// existing run).
    pub fn config(mut self, cfg: PipelineConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Per-stage execution/transfer cost distributions (paper Table I).
    pub fn costs(mut self, v: CostConfig) -> Self {
        self.cfg.costs = v;
        self
    }

    /// Load-shedder tuning (admission CDF, queue capacity, control gains).
    pub fn shedder(mut self, v: ShedderConfig) -> Self {
        self.cfg.shedder = v;
        self
    }

    /// Single-query deployments' query (multi-query deployments take
    /// theirs from the [`QuerySet`]).
    pub fn query(mut self, v: QueryConfig) -> Self {
        self.cfg.query = v;
        self
    }

    /// Backend concurrency (token capacity).
    pub fn backend_tokens(mut self, v: u32) -> Self {
        self.cfg.backend_tokens = v;
        self
    }

    /// Shedding policy (the paper's control loop or an ablation).
    pub fn policy(mut self, v: Policy) -> Self {
        self.cfg.policy = v;
        self
    }

    /// Seed for the cost model and policy coin.
    pub fn seed(mut self, v: u64) -> Self {
        self.cfg.seed = v;
        self
    }

    /// Nominal aggregate ingress fps (rate-estimator fallback).
    pub fn fps_total(mut self, v: f64) -> Self {
        self.cfg.fps_total = v;
        self
    }

    /// Modeled shedder→backend link + wire encoding.
    pub fn transport(mut self, v: TransportConfig) -> Self {
        self.cfg.transport = v;
        self
    }

    /// Scheduled fault windows (empty = faultless verification mode).
    pub fn faults(mut self, v: FaultPlan) -> Self {
        self.cfg.faults = v;
        self
    }

    /// Online utility-model adaptation (off by default).
    pub fn adaptation(mut self, v: AdaptationConfig) -> Self {
        self.cfg.adaptation = v;
        self
    }

    /// The assembled template (for composing tiers by hand, e.g.
    /// [`FleetConfig`]).
    pub fn build(self) -> PipelineConfig {
        self.cfg
    }

    /// Discrete-event simulation, single query (historically
    /// `run_sim` / `run_sim_with`).
    pub fn sim(self) -> SimBuilder {
        SimBuilder { cfg: self.cfg.into() }
    }

    /// N concurrent queries over one shared stream (historically
    /// `run_multi_sim` / `run_multi_sim_with`). Defaults to the
    /// work-conserving weighted fair-share arbiter.
    pub fn multi_query(self, set: &QuerySet) -> MultiQueryBuilder<'_> {
        MultiQueryBuilder {
            cfg: self.cfg,
            set,
            arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
        }
    }

    /// Wall-clock realtime deployment, single query (historically
    /// `run_realtime` / `run_realtime_with`).
    pub fn realtime(self, opts: RealtimeOpts) -> RealtimeBuilder {
        RealtimeBuilder { cfg: RealtimeConfig::from_pipeline(&self.cfg, opts) }
    }

    /// One shard per camera across `threads` workers (historically
    /// `run_sharded_sim(_with)`).
    pub fn sharded(self, threads: usize) -> ShardedBuilder {
        ShardedBuilder { cfg: self.cfg.into(), threads, incremental: None }
    }

    /// Two-tier fleet: the template becomes both tiers via
    /// [`FleetConfig::uniform`] (override per tier with
    /// [`FleetBuilder::aggregator_config`]).
    pub fn fleet(self, topology: FleetTopology) -> FleetBuilder {
        FleetBuilder { cfg: FleetConfig::uniform(self.cfg, topology) }
    }
}

/// Terminal stage for the single-query discrete-event driver.
pub struct SimBuilder {
    cfg: SimConfig,
}

impl SimBuilder {
    /// Run over a timestamp-ordered frame stream with an explicit
    /// extractor/backend pair (full control, the `run_sim` shape).
    pub fn run_frames<I>(
        &self,
        frames: I,
        backgrounds: &BackgroundMap<'_>,
        extractor: &Extractor,
        backend: &mut BackendQuery,
    ) -> Result<SimReport>
    where
        I: IntoIterator<Item = Frame>,
    {
        run_sim(frames, backgrounds, &self.cfg, extractor, backend)
    }

    /// Run over any [`ArrivalModel`] (the `run_sim_with` shape).
    pub fn run_arrivals<A: ArrivalModel>(
        &self,
        arrivals: A,
        backgrounds: &BackgroundMap<'_>,
        extractor: &Extractor,
        backend: &mut BackendQuery,
    ) -> Result<SimReport> {
        run_sim_with(arrivals, backgrounds, &self.cfg, extractor, backend)
    }

    /// Run over any [`ArrivalModel`] with the default construction:
    /// native extractor over `model`, and the standard backend
    /// (12-blob detector, calibrated cost model seeded with the
    /// template seed) — the figure harnesses' historical scaffold.
    pub fn run_model<A: ArrivalModel>(
        &self,
        arrivals: A,
        backgrounds: &BackgroundMap<'_>,
        model: &UtilityModel,
    ) -> Result<SimReport> {
        let extractor = Extractor::native(model.clone());
        let mut backend = BackendQuery::new(
            self.cfg.query.clone(),
            crate::backend::Detector::native(12, 25.0),
            crate::backend::CostModel::new(self.cfg.costs.clone(), self.cfg.seed),
            25.0,
        );
        run_sim_with(arrivals, backgrounds, &self.cfg, &extractor, &mut backend)
    }

    /// Stream every video at the template's `fps_total` through
    /// [`Self::run_model`].
    pub fn run(&self, videos: &[Video], model: &UtilityModel) -> Result<SimReport> {
        self.run_model(
            crate::pipeline::workloads::IterArrivals::new(
                Streamer::new(videos),
                self.cfg.fps_total,
            ),
            &backgrounds_of(videos),
            model,
        )
    }
}

/// Terminal stage for the shared-stream multi-query drivers.
pub struct MultiQueryBuilder<'a> {
    cfg: PipelineConfig,
    set: &'a QuerySet,
    arbiter: ArbiterPolicy,
}

impl<'a> MultiQueryBuilder<'a> {
    /// How the measured backend budget splits across queries.
    pub fn arbiter(mut self, v: ArbiterPolicy) -> Self {
        self.arbiter = v;
        self
    }

    fn multi_cfg(&self) -> MultiSimConfig {
        MultiSimConfig::from_pipeline(&self.cfg, self.arbiter)
    }

    /// Run over a frame stream with explicit extractor/backends (the
    /// `run_multi_sim` shape; `extractor` must match the set's union).
    pub fn run_frames<I>(
        &self,
        frames: I,
        backgrounds: &BackgroundMap<'_>,
        extractor: &Extractor,
        backends: &mut [BackendQuery],
    ) -> Result<MultiPipelineReport>
    where
        I: IntoIterator<Item = Frame>,
    {
        run_multi_sim(frames, backgrounds, self.set, &self.multi_cfg(), extractor, backends)
    }

    /// Run over any [`ArrivalModel`] (the `run_multi_sim_with` shape).
    pub fn run_arrivals<A: ArrivalModel>(
        &self,
        arrivals: A,
        backgrounds: &BackgroundMap<'_>,
        extractor: &Extractor,
        backends: &mut [BackendQuery],
    ) -> Result<MultiPipelineReport> {
        run_multi_sim_with(
            arrivals,
            backgrounds,
            self.set,
            &self.multi_cfg(),
            extractor,
            backends,
        )
    }

    /// Stream every video with the default construction: a native
    /// union-model extractor and one standard backend per query
    /// ([`multi_backends`], seeds decorrelated per query).
    pub fn run(&self, videos: &[Video]) -> Result<MultiPipelineReport> {
        let extractor = Extractor::native(self.set.union_model().clone());
        let mut backends = multi_backends(self.set, &self.cfg.costs, self.cfg.seed);
        self.run_frames(
            Streamer::new(videos),
            &backgrounds_of(videos),
            &extractor,
            &mut backends,
        )
    }

    /// The same query set under the wall clock (historically
    /// `run_multi_realtime(_with)`); the builder's arbiter rides along.
    pub fn realtime(self, opts: RealtimeOpts) -> MultiRealtimeBuilder<'a> {
        let mut cfg = RealtimeConfig::from_pipeline(&self.cfg, opts);
        cfg.arbiter = self.arbiter;
        MultiRealtimeBuilder { cfg, set: self.set }
    }
}

/// Terminal stage for the single-query wall-clock driver.
pub struct RealtimeBuilder {
    cfg: RealtimeConfig,
}

impl RealtimeBuilder {
    /// Stream every video at its native rate (the `run_realtime`
    /// shape).
    pub fn run(&self, videos: &[Video], model: &UtilityModel) -> Result<RealtimeReport> {
        run_realtime(videos, model, &self.cfg)
    }

    /// Run over any [`ArrivalModel`] (the `run_realtime_with` shape).
    pub fn run_with<A: ArrivalModel>(
        &self,
        videos: &[Video],
        model: &UtilityModel,
        arrivals: A,
    ) -> Result<RealtimeReport> {
        run_realtime_with(videos, model, &self.cfg, arrivals)
    }

    /// Reactor mode: the same realtime config, but frames cross **real
    /// loopback sockets** (TCP or Unix-domain) to a backend worker pool
    /// behind an epoll reactor, and the measured per-frame transfers —
    /// not [`LinkModel`](crate::pipeline::transport::LinkModel) samples —
    /// feed `ControlLoop::observe_network`. Requires the ideal transport
    /// (the default); see [`crate::pipeline::reactor`].
    pub fn reactor(self, opts: ReactorOpts) -> ReactorBuilder {
        ReactorBuilder { cfg: self.cfg, opts }
    }
}

/// Terminal stage for the socket-backed reactor driver.
pub struct ReactorBuilder {
    cfg: RealtimeConfig,
    opts: ReactorOpts,
}

impl ReactorBuilder {
    /// Stream every video at its native rate (the `run_reactor` shape).
    pub fn run(&self, videos: &[Video], model: &UtilityModel) -> Result<ReactorReport> {
        run_reactor(videos, model, &self.cfg, &self.opts)
    }

    /// Run over any [`ArrivalModel`] (the `run_reactor_with` shape).
    pub fn run_with<A: ArrivalModel>(
        &self,
        videos: &[Video],
        model: &UtilityModel,
        arrivals: A,
    ) -> Result<ReactorReport> {
        run_reactor_with(videos, model, &self.cfg, &self.opts, arrivals)
    }
}

/// Terminal stage for the multi-query wall-clock driver.
pub struct MultiRealtimeBuilder<'a> {
    cfg: RealtimeConfig,
    set: &'a QuerySet,
}

impl MultiRealtimeBuilder<'_> {
    /// Stream every video at its native rate (the `run_multi_realtime`
    /// shape).
    pub fn run(&self, videos: &[Video]) -> Result<MultiPipelineReport> {
        run_multi_realtime(videos, self.set, &self.cfg)
    }

    /// Run over any [`ArrivalModel`] (the `run_multi_realtime_with`
    /// shape).
    pub fn run_with<A: ArrivalModel>(
        &self,
        videos: &[Video],
        arrivals: A,
    ) -> Result<MultiPipelineReport> {
        run_multi_realtime_with(videos, self.set, &self.cfg, arrivals)
    }
}

/// Terminal stage for the one-shard-per-camera sweep.
pub struct ShardedBuilder {
    cfg: SimConfig,
    threads: usize,
    incremental: Option<IncrementalConfig>,
}

impl ShardedBuilder {
    /// Per-camera incremental feature extraction (bit-identical
    /// results, less per-frame work).
    pub fn incremental(mut self, v: IncrementalConfig) -> Self {
        self.incremental = Some(v);
        self
    }

    /// One shard per camera across the builder's thread budget (the
    /// `run_sharded_sim(_with)` shape).
    pub fn run(
        &self,
        videos: &[Video],
        model: &UtilityModel,
    ) -> Result<(SimReport, Vec<(u32, SimReport)>)> {
        parallel::run_sharded_sim_with(videos, &self.cfg, model, self.threads, self.incremental)
    }
}

/// Terminal stage for the two-tier fleet driver.
pub struct FleetBuilder {
    cfg: FleetConfig,
}

impl FleetBuilder {
    /// Override the aggregator tier's template (hop-B link, seed, …).
    pub fn aggregator_config(mut self, v: PipelineConfig) -> Self {
        self.cfg.aggregator = v;
        self
    }

    /// Backend-budget split inside each edge node.
    pub fn edge_arbiter(mut self, v: ArbiterPolicy) -> Self {
        self.cfg.edge_arbiter = v;
        self
    }

    /// The assembled two-tier config.
    pub fn build(self) -> FleetConfig {
        self.cfg
    }

    /// Run the fleet over the cameras for a trained query set.
    pub fn run(&self, videos: &[Video], set: &QuerySet) -> Result<FleetReport> {
        run_fleet(videos, set, &self.cfg)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::shedder::QuerySpec;
    use crate::utility::{train, Combine};
    use crate::video::VideoConfig;

    fn cameras(n: usize, frames: usize) -> Vec<Video> {
        (0..n)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 0xB111 + i as u64, i as u32, frames);
                vc.traffic.vehicle_rate = 0.35;
                Video::new(vc)
            })
            .collect()
    }

    #[test]
    fn builder_sim_matches_free_function() {
        let videos = cameras(3, 100);
        let model = train(&videos, &[0, 1, 2], &[NamedColor::Red], Combine::Single);
        let b = Pipeline::builder().seed(0x77).fps_total(30.0);
        let built = b.clone().sim().run(&videos, &model).unwrap();

        let cfg: SimConfig = b.build().into();
        let extractor = Extractor::native(model.clone());
        let mut backend = BackendQuery::new(
            cfg.query.clone(),
            crate::backend::Detector::native(12, 25.0),
            crate::backend::CostModel::new(cfg.costs.clone(), cfg.seed),
            25.0,
        );
        let free = run_sim(
            Streamer::new(&videos),
            &backgrounds_of(&videos),
            &cfg,
            &extractor,
            &mut backend,
        )
        .unwrap();

        assert_eq!(built.ingress, free.ingress);
        assert_eq!(built.decisions, free.decisions);
        assert_eq!(built.qor.overall(), free.qor.overall());
    }

    #[test]
    fn builder_multi_matches_free_function() {
        let videos = cameras(2, 80);
        let specs = vec![
            QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
            QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)),
        ];
        let set = QuerySet::train(&specs, &videos, &[0, 1]).unwrap();
        let fps = crate::video::streamer::aggregate_fps(&videos);
        let builder = Pipeline::builder().seed(0x42).fps_total(fps);
        let built = builder.clone().multi_query(&set).run(&videos).unwrap();

        let cfg = MultiSimConfig::from_pipeline(
            &builder.build(),
            ArbiterPolicy::WeightedFair { work_conserving: true },
        );
        let extractor = Extractor::native(set.union_model().clone());
        let mut backends = multi_backends(&set, &cfg.costs, cfg.seed);
        let free = run_multi_sim(
            Streamer::new(&videos),
            &backgrounds_of(&videos),
            &set,
            &cfg,
            &extractor,
            &mut backends,
        )
        .unwrap();

        assert_eq!(built.frames, free.frames);
        for (a, b) in built.queries.iter().zip(&free.queries) {
            assert_eq!(a.report.decisions, b.report.decisions);
            assert_eq!(a.report.qor.overall(), b.report.qor.overall());
        }
    }
}
