//! Workload plugins for the streaming core: implementations of
//! [`ArrivalModel`] that describe *what* arrives and *when*, independent of
//! the clock that executes it. Every model here runs unchanged under both
//! [`crate::pipeline::SimClock`] and [`crate::pipeline::WallClock`].
//!
//! * [`IterArrivals`] — any timestamp-ordered frame iterator (the plain
//!   interleaved multi-camera stream via [`crate::video::Streamer`], a
//!   single [`crate::video::Video`], a [`crate::video::SegmentedVideo`]).
//! * [`PoissonArrivals`] — bursty ingress: each camera's frames arrive on
//!   a Poisson process (exponential inter-arrival times) at its nominal
//!   rate, so the instantaneous load swings far above and below the mean
//!   while the long-run rate matches the fixed-fps stream.
//! * [`CameraChurn`] — mid-run camera join/leave: each camera streams
//!   only inside its `[join_ms, leave_ms)` window, so aggregate ingress
//!   steps up and down while the run is in flight.

use super::core::ArrivalModel;
use crate::util::rng::Rng;
use crate::video::{Frame, Video};

/// Adapter: any ts-ordered frame iterator + its nominal aggregate fps.
pub struct IterArrivals<I> {
    iter: I,
    fps_total: f64,
}

impl<I: Iterator<Item = Frame>> IterArrivals<I> {
    /// Wrap a ts-ordered frame iterator with its nominal aggregate fps.
    pub fn new(iter: I, fps_total: f64) -> Self {
        IterArrivals { iter, fps_total }
    }
}

impl<I: Iterator<Item = Frame>> ArrivalModel for IterArrivals<I> {
    fn next_frame(&mut self) -> Option<Frame> {
        self.iter.next()
    }

    fn fps_total(&self) -> f64 {
        self.fps_total
    }
}

/// Bursty Poisson ingress over a camera set: camera `i`'s k-th frame is
/// its video's frame `k`, re-stamped onto a Poisson arrival process with
/// mean rate `fps × rate_scale`. Deterministic for a given seed; cameras
/// are merged by arrival time.
pub struct PoissonArrivals<'a> {
    videos: &'a [Video],
    /// Next frame index per camera.
    next_idx: Vec<usize>,
    /// Arrival time (ms) of each camera's next frame.
    next_ts: Vec<f64>,
    rngs: Vec<Rng>,
    mean_gap_ms: Vec<f64>,
    fps_total: f64,
}

impl<'a> PoissonArrivals<'a> {
    /// `rate_scale` multiplies each camera's nominal rate (1.0 = the same
    /// long-run rate as the fixed-fps stream; >1 = overload on average).
    pub fn new(videos: &'a [Video], seed: u64, rate_scale: f64) -> Self {
        assert!(rate_scale > 0.0, "rate_scale must be positive");
        let mut rngs = Vec::with_capacity(videos.len());
        let mut next_ts = Vec::with_capacity(videos.len());
        let mut mean_gap_ms = Vec::with_capacity(videos.len());
        let mut fps_total = 0.0;
        for v in videos {
            let tag = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(v.camera_id() as u64 + 1);
            let mut rng = Rng::new(seed ^ tag);
            let gap = 1000.0 / (v.config.fps * rate_scale);
            // First arrival is itself exponentially distributed.
            next_ts.push(rng.exponential(gap));
            mean_gap_ms.push(gap);
            rngs.push(rng);
            fps_total += v.config.fps * rate_scale;
        }
        PoissonArrivals {
            videos,
            next_idx: vec![0; videos.len()],
            next_ts,
            rngs,
            mean_gap_ms,
            fps_total,
        }
    }
}

impl ArrivalModel for PoissonArrivals<'_> {
    fn next_frame(&mut self) -> Option<Frame> {
        // Pick the camera with the earliest pending arrival.
        let mut best: Option<(usize, f64)> = None;
        for (i, v) in self.videos.iter().enumerate() {
            if self.next_idx[i] >= v.len() {
                continue;
            }
            let ts = self.next_ts[i];
            if best.is_none_or(|(_, bts)| ts < bts) {
                best = Some((i, ts));
            }
        }
        let (i, ts) = best?;
        let mut frame = self.videos[i].render(self.next_idx[i]);
        frame.ts_ms = ts; // re-stamp capture onto the Poisson process
        self.next_idx[i] += 1;
        self.next_ts[i] = ts + self.rngs[i].exponential(self.mean_gap_ms[i]);
        Some(frame)
    }

    fn fps_total(&self) -> f64 {
        self.fps_total
    }
}

/// One camera's lifetime in a churn scenario.
#[derive(Debug, Clone, Copy)]
pub struct ChurnWindow {
    /// Stream time (ms) the camera joins the deployment.
    pub join_ms: f64,
    /// Stream time (ms) the camera leaves (exclusive); `f64::INFINITY`
    /// for cameras that stay until their video ends.
    pub leave_ms: f64,
}

impl ChurnWindow {
    /// A camera present for the whole run (join at 0, never leave).
    pub fn always() -> Self {
        ChurnWindow { join_ms: 0.0, leave_ms: f64::INFINITY }
    }
}

/// Mid-run camera churn: camera `i` emits frame `k` at
/// `join_ms + k / fps`, while that instant is before `leave_ms`. The
/// aggregate ingress rate therefore steps as cameras come and go — the
/// scenario the per-window control loop has to ride out.
pub struct CameraChurn<'a> {
    videos: &'a [Video],
    windows: Vec<ChurnWindow>,
    next_idx: Vec<usize>,
}

impl<'a> CameraChurn<'a> {
    /// `windows[i]` is camera `i`'s lifetime; must match `videos.len()`.
    pub fn new(videos: &'a [Video], windows: Vec<ChurnWindow>) -> Self {
        assert_eq!(videos.len(), windows.len(), "one churn window per camera");
        CameraChurn { videos, windows, next_idx: vec![0; videos.len()] }
    }

    /// Staggered deployment: camera `i` joins at `i × stagger_ms` and
    /// stays `up_ms` (the classic rolling join/leave pattern).
    pub fn staggered(videos: &'a [Video], stagger_ms: f64, up_ms: f64) -> Self {
        let windows = (0..videos.len())
            .map(|i| {
                let join = i as f64 * stagger_ms;
                ChurnWindow { join_ms: join, leave_ms: join + up_ms }
            })
            .collect();
        Self::new(videos, windows)
    }

    fn pending_ts(&self, i: usize) -> Option<f64> {
        let v = &self.videos[i];
        let k = self.next_idx[i];
        if k >= v.len() {
            return None;
        }
        let w = &self.windows[i];
        let ts = w.join_ms + k as f64 / v.config.fps * 1e3;
        (ts < w.leave_ms).then_some(ts)
    }
}

impl ArrivalModel for CameraChurn<'_> {
    fn next_frame(&mut self) -> Option<Frame> {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..self.videos.len() {
            let Some(ts) = self.pending_ts(i) else { continue };
            if best.is_none_or(|(_, bts)| ts < bts) {
                best = Some((i, ts));
            }
        }
        let (i, ts) = best?;
        let mut frame = self.videos[i].render(self.next_idx[i]);
        frame.ts_ms = ts; // shift onto the camera's join offset
        self.next_idx[i] += 1;
        Some(frame)
    }

    fn fps_total(&self) -> f64 {
        // Nominal: the full camera set's aggregate (the estimator measures
        // the actual stepped rate once arrivals flow).
        crate::video::streamer::aggregate_fps(self.videos)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;
    use crate::video::VideoConfig;

    fn cams(n: usize, frames: usize) -> Vec<Video> {
        (0..n)
            .map(|i| Video::new(VideoConfig::new(3, 40 + i as u64, i as u32, frames)))
            .collect()
    }

    fn drain(mut a: impl ArrivalModel) -> Vec<Frame> {
        let mut out = Vec::new();
        while let Some(f) = a.next_frame() {
            out.push(f);
        }
        out
    }

    #[test]
    fn poisson_is_ordered_deterministic_and_rate_matched() {
        let videos = cams(3, 60);
        let frames = drain(PoissonArrivals::new(&videos, 7, 1.0));
        assert_eq!(frames.len(), 180, "every frame is emitted exactly once");
        for w in frames.windows(2) {
            assert!(w[0].ts_ms <= w[1].ts_ms, "ts order violated");
        }
        // Deterministic for a fixed seed…
        let again = drain(PoissonArrivals::new(&videos, 7, 1.0));
        let ts: Vec<f64> = frames.iter().map(|f| f.ts_ms).collect();
        let ts2: Vec<f64> = again.iter().map(|f| f.ts_ms).collect();
        assert_eq!(ts, ts2);
        // …different for another seed.
        let other = drain(PoissonArrivals::new(&videos, 8, 1.0));
        assert_ne!(ts, other.iter().map(|f| f.ts_ms).collect::<Vec<f64>>());
        // Long-run rate ≈ nominal 30 fps: 180 frames should span ~6 s.
        let span_s = ts.last().unwrap() / 1000.0;
        assert!(span_s > 3.0 && span_s < 12.0, "span {span_s}s");
        // Burstiness: inter-arrival CV of an exponential process is ~1,
        // far above the near-zero CV of the fixed-fps stream.
        let gaps: Vec<f64> = ts.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        assert!(var.sqrt() / mean > 0.5, "not bursty: cv {}", var.sqrt() / mean);
    }

    #[test]
    fn poisson_rate_scale_compresses_time() {
        let videos = cams(1, 100);
        let slow = drain(PoissonArrivals::new(&videos, 5, 1.0));
        let fast = drain(PoissonArrivals::new(&videos, 5, 2.0));
        assert_eq!(slow.len(), fast.len());
        assert!(fast.last().unwrap().ts_ms < slow.last().unwrap().ts_ms);
    }

    #[test]
    fn churn_windows_gate_emission() {
        let videos = cams(2, 50); // 10 fps each → 5 s of content
        // Camera 0 always on; camera 1 joins at 1 s and leaves at 3 s.
        let churn = CameraChurn::new(
            &videos,
            vec![
                ChurnWindow::always(),
                ChurnWindow { join_ms: 1_000.0, leave_ms: 3_000.0 },
            ],
        );
        let frames = drain(churn);
        for w in frames.windows(2) {
            assert!(w[0].ts_ms <= w[1].ts_ms);
        }
        let cam0 = frames.iter().filter(|f| f.camera == 0).count();
        let cam1: Vec<&Frame> = frames.iter().filter(|f| f.camera == 1).collect();
        assert_eq!(cam0, 50);
        // 2 s window at 10 fps → 20 frames, all inside [1 s, 3 s).
        assert_eq!(cam1.len(), 20);
        for f in &cam1 {
            assert!(f.ts_ms >= 1_000.0 && f.ts_ms < 3_000.0, "ts {}", f.ts_ms);
        }
    }

    #[test]
    fn staggered_churn_steps_the_aggregate_rate() {
        let videos = cams(3, 40);
        let frames = drain(CameraChurn::staggered(&videos, 1_000.0, 2_000.0));
        // Each camera contributes 2 s × 10 fps = 20 frames.
        for cam in 0..3u32 {
            assert_eq!(frames.iter().filter(|f| f.camera == cam).count(), 20);
        }
        // During [1 s, 2 s) two cameras overlap → higher arrival density
        // than [0 s, 1 s).
        let in_window = |lo: f64, hi: f64| {
            frames.iter().filter(|f| f.ts_ms >= lo && f.ts_ms < hi).count()
        };
        assert!(in_window(1_000.0, 2_000.0) > in_window(0.0, 1_000.0));
    }
}
