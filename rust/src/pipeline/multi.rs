//! Multi-query path through the streaming core: N concurrent queries over
//! one shared camera stream, with **one** feature extraction per frame
//! and per-query shedding behind a
//! [`CapacityArbiter`](crate::shedder::CapacityArbiter).
//!
//! Event loop shape (mirrors [`super::core::run_pipeline`]):
//!
//! ```text
//!   arrival ──► ONE extract (union colors) ──► per-query utility
//!               (cheap reductions)            reductions
//!       │
//!       ▼  shared LS-ingress event
//!   per-query admission (own threshold/CDF) ──► admission bitset on the
//!   per-query utility queue + token bucket      shared FramePayload
//!       │
//!       ▼  per-query dispatch
//!   MultiBackendExecutor::submit(query, frame) — only admitted queries
//!   run; completions feed that query's control loop.
//! ```
//!
//! Every per-query decision rule is copied operation-for-operation from
//! the single-query engine, so under [`ArbiterPolicy::Standalone`] (every
//! query sees the full backend budget) and deterministic stage costs the
//! per-query decision logs **bit-match** N independent single-query runs
//! — pinned by `rust/tests/multiquery.rs`. Under the weighted fair-share
//! arbiter the queries instead split the measured backend budget, with
//! idle share re-offered work-conservingly.
//!
//! The physical sharing is the point: frames are rendered once, extracted
//! once (`Extractor::extractions` counts exactly one per frame regardless
//! of N), and the payload is reference-counted into each admitting
//! query's queue instead of cloned.

use crate::backend::{BackendQuery, CostModel, Detector};
use crate::config::{CostConfig, ShedderConfig};
use crate::features::{Extractor, FrameFeatures, UtilityValues};
use crate::metrics::{LatencyTracker, QorTracker, Stage, StageCounts, WindowSeries};
use crate::pipeline::core::{
    ArrivalModel, BackgroundMap, Clock, EventClass, EventQueue, FrameDecision, FramePayload,
    PipelineConfig, PipelineReport,
};
use crate::pipeline::faults::{FaultPlan, FaultStats, PoisonKind};
use crate::pipeline::transport::{Transmission, TransportConfig, TransportState};
use crate::shedder::{ArbiterPolicy, Entry, MultiShedder, QueryMask, QuerySet};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

/// Multi-query lifecycle parameters (the shared-stream analogue of
/// [`super::SimConfig`]; the per-query `QueryConfig`s live in the
/// [`QuerySet`]).
#[derive(Debug, Clone)]
pub struct MultiSimConfig {
    /// Stage cost model parameters (shared across queries).
    pub costs: CostConfig,
    /// Load Shedder parameters (each query gets its own instance).
    pub shedder: ShedderConfig,
    /// Transmission-window tokens **per query** (each query owns its
    /// bucket; aggregate backend capacity is governed by the arbiter's
    /// budget split, not by a shared bucket).
    pub backend_tokens: u32,
    /// How the measured backend budget splits across queries.
    pub arbiter: ArbiterPolicy,
    /// Master seed for cost/link RNGs and per-query decorrelation.
    pub seed: u64,
    /// Nominal aggregate ingress fps (shared rate-estimator fallback).
    pub fps_total: f64,
    /// The ONE shared shedder→backend link: each frame admitted by ≥ 1
    /// query crosses it **once** (the transmission analogue of the
    /// shared-extraction invariant). Defaults to the ideal link.
    pub transport: TransportConfig,
    /// Scheduled fault windows (see [`crate::pipeline::faults`]). Camera
    /// dropout/freeze hits the shared arrival side once; link faults hit
    /// the one shared crossing; backend faults apply per query. The
    /// default empty plan is bit-identical to a faultless run. Unlike the
    /// single-query engine, a worker-crash window books its losses
    /// immediately (per-query token buckets make the token-recovery dance
    /// redundant) and there is no watchdog/liveness degraded mode here.
    pub faults: FaultPlan,
}

impl MultiSimConfig {
    /// Project the shared lifecycle template
    /// ([`PipelineConfig`](crate::pipeline::PipelineConfig)) onto the
    /// multi-query config, adding the one multi-only knob (the arbiter).
    /// The single-query-only fields don't apply here: per-query
    /// `QueryConfig`s live in the [`QuerySet`], the multi engine always
    /// runs the utility control loop, and multi-query adaptation is
    /// still a roadmap item.
    pub fn from_pipeline(p: &PipelineConfig, arbiter: ArbiterPolicy) -> Self {
        MultiSimConfig {
            costs: p.costs.clone(),
            shedder: p.shedder.clone(),
            backend_tokens: p.backend_tokens,
            arbiter,
            seed: p.seed,
            fps_total: p.fps_total,
            transport: p.transport,
            faults: p.faults.clone(),
        }
    }
}

/// One query's slice of a multi-query run: the full single-query metrics
/// sink under the query's name.
#[derive(Clone)]
pub struct QueryReport {
    /// Query name (from the query config's color spec).
    pub name: String,
    /// The query's full single-query metrics sink.
    pub report: PipelineReport,
}

/// What a multi-query run reports: per-query [`PipelineReport`]s plus the
/// shared-side aggregates.
pub struct MultiPipelineReport {
    /// Per-query reports (query order = [`QuerySet`] order).
    pub queries: Vec<QueryReport>,
    /// Physical frames ingested (each appears once here, N times across
    /// the per-query reports).
    pub frames: u64,
    /// Feature extractions performed — equals `frames` for the shared
    /// pipeline (pinned by test), `frames × N` for N independent runs.
    pub extractions: u64,
    /// Physical frames that crossed the shared link — at most one per
    /// ingress frame regardless of how many queries admitted it (the
    /// shared-transmission invariant; N independent deployments pay N×).
    pub wire_frames: u64,
    /// Bytes serialized onto the shared link (actual wire sizes).
    pub bytes_on_wire: u64,
    /// Physical frames lost on the shared link (every admitting query
    /// loses its copy; per-query reports count those per query).
    pub link_lost_frames: u64,
    /// Latest event timestamp in the run (virtual ms).
    pub end_ms: f64,
    /// Camera-side extraction wall time (ms), shared across queries.
    pub extract_ms_total: f64,
}

impl MultiPipelineReport {
    /// Merge the per-query reports into one aggregate view (per-query
    /// ingress/decision counts sum, so `aggregate().ingress` is
    /// `frames × N`). QoR merges per target object across queries.
    pub fn aggregate(&self) -> PipelineReport {
        // Invariant: `run_multi_pipeline` bails on an empty query set, so
        // every constructed report has ≥ 1 query.
        #[allow(clippy::expect_used)]
        let mut agg = crate::pipeline::parallel::merge_reports(
            self.queries.iter().map(|q| &q.report),
        )
        .expect("query set is non-empty");
        agg.extract_ms_total = self.extract_ms_total;
        agg
    }

    /// Mean per-query QoR (the headline of the multi-tenant scenario).
    pub fn qor_mean(&self) -> f64 {
        if self.queries.is_empty() {
            return 1.0;
        }
        let sum: f64 = self.queries.iter().map(|q| q.report.qor.overall()).sum();
        sum / self.queries.len() as f64
    }
}

// ---------------------------------------------------------------------------
// Backend executor abstraction (multi-query)
// ---------------------------------------------------------------------------

/// How dispatched (frame, query) pairs run through the backend: the
/// multi-query analogue of [`super::core::BackendExecutor`]. `submit` is
/// called only for queries that admitted the frame.
pub trait MultiBackendExecutor {
    /// Run query `query` on a dispatched frame; returns the deepest stage
    /// reached and the execution time (ms) charged to that query's
    /// backend share. Per-query call order is the cost-sampling contract.
    fn submit(
        &mut self,
        query: usize,
        payload: &FramePayload,
        background: &[f32],
    ) -> anyhow::Result<(Stage, f64)>;

    /// The completion event for query `query`'s `seq`-th dispatch fired.
    fn on_complete(&mut self, query: usize, seq: u64, dnn: bool) -> anyhow::Result<()>;

    /// Stream ended and every completion has been applied.
    fn finish(&mut self) -> anyhow::Result<()>;
}

/// Synchronous in-process executor: one [`BackendQuery`] per query, run
/// on the driver thread — the discrete-event drivers' backend.
pub struct MultiSyncBackend<'a> {
    backends: &'a mut [BackendQuery],
}

impl<'a> MultiSyncBackend<'a> {
    /// Wrap one [`BackendQuery`] per query (index order = query order).
    pub fn new(backends: &'a mut [BackendQuery]) -> Self {
        MultiSyncBackend { backends }
    }
}

impl MultiBackendExecutor for MultiSyncBackend<'_> {
    fn submit(
        &mut self,
        query: usize,
        payload: &FramePayload,
        background: &[f32],
    ) -> anyhow::Result<(Stage, f64)> {
        let r = self.backends[query].process(
            &payload.rgb,
            background,
            payload.width,
            payload.height,
        )?;
        Ok((r.last_stage, r.exec_ms))
    }

    fn on_complete(&mut self, _query: usize, _seq: u64, _dnn: bool) -> anyhow::Result<()> {
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Per-query backend cost seed: query 0 keeps the base seed (so a 1-query
/// multi run matches a single-query run built with `seed` directly);
/// later queries decorrelate golden-ratio style. Single-query reference
/// runs must seed their backend with the same derivation to bit-match.
pub fn multi_backend_seed(base: u64, query: usize) -> u64 {
    base.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(query as u64))
}

/// Build the default native backend set for a query set: one
/// [`BackendQuery`] per query, cost models seeded via
/// [`multi_backend_seed`].
pub fn multi_backends(set: &QuerySet, costs: &CostConfig, seed: u64) -> Vec<BackendQuery> {
    set.queries()
        .iter()
        .enumerate()
        .map(|(qi, q)| {
            BackendQuery::new(
                q.config.clone(),
                Detector::native(12, 25.0),
                CostModel::new(costs.clone(), multi_backend_seed(seed, qi)),
                25.0,
            )
        })
        .collect()
}

// ---------------------------------------------------------------------------
// The multi-query lifecycle engine
// ---------------------------------------------------------------------------

/// A query's queue entry: the shared frame plus that query's ground-truth
/// target ids (colors differ per query, so the id sets do too), and the
/// outcome of the frame's one crossing of the shared link (`None` under
/// an ideal link).
struct MultiItem {
    frame: Rc<FramePayload>,
    ids: Vec<u64>,
    transit: Option<Transmission>,
}

/// One ingress event: the shared payload, per-query utilities (reduced
/// from the one extraction) and per-query ground-truth ids.
struct IngressEvent {
    frame: FramePayload,
    utilities: Vec<f32>,
    ids: Vec<Vec<u64>>,
}

enum MEvent {
    Ingress(Box<IngressEvent>),
    Completion { query: usize, seq: u64, capture_ms: f64, exec_ms: f64, dnn: bool },
    /// A shared frame destroyed by a camera-dropout fault at capture
    /// time: every query loses its copy (per-query ground-truth id sets
    /// ride along for QoR accounting).
    FaultDrop { camera: u32, capture_ms: f64, ids: Vec<Vec<u64>> },
}

/// Per-query metrics sink + per-query virtual clock.
struct QueryState {
    qor: QorTracker,
    latency: LatencyTracker,
    latency_windows: WindowSeries,
    stages: StageCounts,
    control_series: Vec<(f64, f32, f64)>,
    decisions: Vec<FrameDecision>,
    ingress: u64,
    transmitted: u64,
    shed: u64,
    link_dropped: u64,
    transmit_ms_total: f64,
    /// Max event time this query has seen — identical to the global
    /// clock of an independent single-query run of this query (its event
    /// set is the shared ingresses plus its own completions).
    now: f64,
    last_control_sample: f64,
    dispatch_seq: u64,
    /// Fault counters for this query's report (only `fault_dropped` and
    /// `poisoned_rejected` are populated by the multi engine).
    fstats: FaultStats,
}

impl QueryState {
    fn new(latency_bound_ms: f64) -> Self {
        QueryState {
            qor: QorTracker::new(),
            latency: LatencyTracker::new(latency_bound_ms),
            latency_windows: WindowSeries::new(5_000.0),
            stages: StageCounts::new(5_000.0),
            control_series: Vec::new(),
            decisions: Vec::new(),
            ingress: 0,
            transmitted: 0,
            shed: 0,
            link_dropped: 0,
            transmit_ms_total: 0.0,
            now: 0.0,
            last_control_sample: f64::NEG_INFINITY,
            dispatch_seq: 0,
            fstats: FaultStats::default(),
        }
    }

    /// Account one shed frame (any shed point: admission, queue
    /// rejection/eviction, retune shrink, transmission-deadline check).
    fn account_shed(&mut self, e: Entry<MultiItem>, id_pool: &mut Vec<Vec<u64>>) {
        self.qor.observe(&e.item.ids, false);
        self.stages.observe(Stage::Shed, e.item.frame.capture_ms);
        self.decisions.push(FrameDecision {
            camera: e.item.frame.camera,
            capture_ms: e.item.frame.capture_ms,
            kept: false,
        });
        self.shed += 1;
        recycle(id_pool, e.item.ids);
    }

    /// Account one frame an injected fault destroyed for this query
    /// (camera dropout, link blackout, crashed worker).
    fn account_fault_drop(
        &mut self,
        camera: u32,
        capture_ms: f64,
        ids: Vec<u64>,
        id_pool: &mut Vec<Vec<u64>>,
    ) {
        self.qor.observe(&ids, false);
        self.stages.observe(Stage::Shed, capture_ms);
        self.decisions.push(FrameDecision { camera, capture_ms, kept: false });
        self.fstats.fault_dropped += 1;
        recycle(id_pool, ids);
    }

    /// Account one frame this query queued but the shared link lost.
    fn account_link_drop(&mut self, e: Entry<MultiItem>, id_pool: &mut Vec<Vec<u64>>) {
        self.qor.observe(&e.item.ids, false);
        self.stages.observe(Stage::Shed, e.item.frame.capture_ms);
        self.decisions.push(FrameDecision {
            camera: e.item.frame.camera,
            capture_ms: e.item.frame.capture_ms,
            kept: false,
        });
        self.link_dropped += 1;
        recycle(id_pool, e.item.ids);
    }
}

fn recycle(pool: &mut Vec<Vec<u64>>, mut ids: Vec<u64>) {
    ids.clear();
    if pool.len() < 256 {
        pool.push(ids);
    }
}

/// Arrival side: one extraction per frame into reused buffers, then the
/// per-query utility reductions and ground-truth id sets.
struct MultiFeeder {
    feat_buf: FrameFeatures,
    util_buf: UtilityValues,
    id_pool: Vec<Vec<u64>>,
    /// Recycled per-event buffers (per-query utilities / id-set holders),
    /// so the feed path stays allocation-free after warmup like the
    /// single-query engine's.
    util_pool: Vec<Vec<f32>>,
    ids_pool: Vec<Vec<Vec<u64>>>,
    extract_ms_total: f64,
    frames: u64,
    /// Last delivered pixels per camera — only populated when the fault
    /// plan contains a camera-freeze window (see the single-query
    /// `ArrivalFeeder`).
    last_rgb: HashMap<u32, Vec<f32>>,
}

impl MultiFeeder {
    fn new() -> Self {
        MultiFeeder {
            feat_buf: FrameFeatures::empty(),
            util_buf: UtilityValues::empty(),
            id_pool: Vec::new(),
            util_pool: Vec::new(),
            ids_pool: Vec::new(),
            extract_ms_total: 0.0,
            frames: 0,
            last_rgb: HashMap::new(),
        }
    }

    /// Retire a consumed ingress event's per-query buffers. The inner id
    /// vectors were moved into queue items (and recycle through
    /// `id_pool`); only the cleared holders return here.
    fn recycle_event(&mut self, mut utilities: Vec<f32>, mut ids: Vec<Vec<u64>>) {
        utilities.clear();
        ids.clear();
        if self.util_pool.len() < 64 {
            self.util_pool.push(utilities);
        }
        if self.ids_pool.len() < 64 {
            self.ids_pool.push(ids);
        }
    }

    fn feed_next(
        &mut self,
        eq: &mut EventQueue<MEvent>,
        arrivals: &mut impl ArrivalModel,
        backgrounds: &BackgroundMap<'_>,
        set: &QuerySet,
        extractor: &Extractor,
        cost: &mut CostModel,
        faults: &FaultPlan,
    ) -> anyhow::Result<bool> {
        let Some(mut f) = arrivals.next_frame() else {
            return Ok(false);
        };
        // Fault: camera dropout — the shared frame never leaves the
        // device; every query loses its copy, accounted at capture time.
        // No extraction and no cost-model draws, so the RNG sequences
        // stay aligned with the healthy stream.
        if faults.camera_dropped(f.camera, f.ts_ms) {
            let mut ids = self.ids_pool.pop().unwrap_or_default();
            for q in set.queries() {
                let mut v = self.id_pool.pop().unwrap_or_default();
                f.target_ids_into(&q.config.colors, q.config.min_blob_px, &mut v);
                ids.push(v);
            }
            self.frames += 1;
            eq.push(
                f.ts_ms,
                MEvent::FaultDrop { camera: f.camera, capture_ms: f.ts_ms, ids },
            );
            return Ok(true);
        }
        // Fault: camera freeze — stale pixels, live ground truth.
        if faults.has_camera_freeze() {
            if faults.camera_frozen(f.camera, f.ts_ms) {
                if let Some(prev) = self.last_rgb.get(&f.camera) {
                    f.rgb.clear();
                    f.rgb.extend_from_slice(prev);
                }
            } else {
                let slot = self.last_rgb.entry(f.camera).or_default();
                slot.clear();
                slot.extend_from_slice(&f.rgb);
            }
        }
        let bg = *backgrounds
            .get(&f.camera)
            .ok_or_else(|| anyhow::anyhow!("no background for camera {}", f.camera))?;
        let te = Instant::now();
        extractor.extract_camera_into(
            f.camera,
            f.width,
            f.height,
            &f.rgb,
            bg,
            &mut self.feat_buf,
            &mut self.util_buf,
        )?;
        self.extract_ms_total += te.elapsed().as_secs_f64() * 1e3;
        let mut utilities = self.util_pool.pop().unwrap_or_default();
        set.utilities_into(&self.util_buf, &mut utilities);
        let mut ids = self.ids_pool.pop().unwrap_or_default();
        for q in set.queries() {
            let mut v = self.id_pool.pop().unwrap_or_default();
            f.target_ids_into(&q.config.colors, q.config.min_blob_px, &mut v);
            ids.push(v);
        }
        // Historical draw order (camera, then cam→LS); the cam→LS sample
        // is this frame's measured camera→shedder transfer.
        let cam_ms = cost.camera_ms();
        let net_cam_ls_ms = cost.net_cam_ls_ms();
        let t_ls = f.ts_ms + cam_ms + net_cam_ls_ms;
        let frame = FramePayload {
            camera: f.camera,
            capture_ms: f.ts_ms,
            target_ids: Vec::new(),
            admitted: QueryMask::empty(),
            net_cam_ls_ms,
            rgb: f.rgb,
            width: f.width,
            height: f.height,
            features: None,
        };
        eq.push(t_ls, MEvent::Ingress(Box::new(IngressEvent { frame, utilities, ids })));
        self.frames += 1;
        Ok(true)
    }
}

/// Per-dispatch observation hook on the multi-query engine: the fleet
/// tier records every edge dispatch (the aggregator's ingress stream)
/// without perturbing the engine. The hook only *reads* — the no-op impl
/// compiles away and [`run_multi_pipeline`] stays bit-identical.
pub(crate) trait DispatchObserver {
    /// One (query, frame) dispatch. `dispatch_ms` is the query's virtual
    /// clock at dispatch, `frame` the shared payload (still alive at the
    /// tap), `ids` the query's ground-truth target ids (the callback
    /// fires before they recycle), `exec_ms` the post-slowdown backend
    /// service demand, `transit` the frame's one shared-link crossing
    /// (`None` under an ideal link), `done_ms` the completion's virtual
    /// due time.
    #[allow(clippy::too_many_arguments)]
    fn on_dispatch(
        &mut self,
        query: usize,
        dispatch_ms: f64,
        frame: &FramePayload,
        ids: &[u64],
        exec_ms: f64,
        dnn: bool,
        transit: Option<&Transmission>,
        done_ms: f64,
    );
}

/// The default observer: observes nothing.
pub(crate) struct NoopObserver;

impl DispatchObserver for NoopObserver {
    #[inline]
    fn on_dispatch(
        &mut self,
        _: usize,
        _: f64,
        _: &FramePayload,
        _: &[u64],
        _: f64,
        _: bool,
        _: Option<&Transmission>,
        _: f64,
    ) {
    }
}

/// Run N queries over one shared stream, under a clock, against a
/// multi-query backend executor. `extractor` must be built from the
/// set's union model ([`QuerySet::union_model`]).
pub fn run_multi_pipeline<A, E, C>(
    arrivals: A,
    backgrounds: &BackgroundMap<'_>,
    set: &QuerySet,
    cfg: &MultiSimConfig,
    extractor: &Extractor,
    executor: &mut E,
    clock: &mut C,
) -> anyhow::Result<MultiPipelineReport>
where
    A: ArrivalModel,
    E: MultiBackendExecutor,
    C: Clock,
{
    run_multi_pipeline_observed(
        arrivals,
        backgrounds,
        set,
        cfg,
        extractor,
        executor,
        clock,
        &mut NoopObserver,
    )
}

/// [`run_multi_pipeline`] with a [`DispatchObserver`] tap on the dispatch
/// path (the fleet edge tier's recording hook). The observer never feeds
/// back into the engine, so the run is bit-identical to the unobserved
/// one.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_multi_pipeline_observed<A, E, C, O>(
    mut arrivals: A,
    backgrounds: &BackgroundMap<'_>,
    set: &QuerySet,
    cfg: &MultiSimConfig,
    extractor: &Extractor,
    executor: &mut E,
    clock: &mut C,
    observer: &mut O,
) -> anyhow::Result<MultiPipelineReport>
where
    A: ArrivalModel,
    E: MultiBackendExecutor,
    C: Clock,
    O: DispatchObserver,
{
    let k = set.len();
    if k == 0 {
        anyhow::bail!("query set is empty");
    }
    {
        let union = set.union_model();
        let model = extractor.model();
        let matches = model.colors.len() == union.colors.len()
            && model
                .colors
                .iter()
                .zip(&union.colors)
                .all(|(a, b)| a.color == b.color);
        if !matches {
            anyhow::bail!("extractor model does not match the query set's union colors");
        }
    }
    let extractions_before = extractor.extractions();
    let mut cost = CostModel::new(cfg.costs.clone(), cfg.seed ^ 0xCA11);
    let mut shedder: MultiShedder<MultiItem> = MultiShedder::new(
        &set.latency_bounds(),
        &set.weights(),
        &cfg.shedder,
        &cfg.costs,
        cfg.backend_tokens,
        cfg.arbiter,
        cfg.fps_total,
    );
    let mut states: Vec<QueryState> = set
        .queries()
        .iter()
        .map(|q| QueryState::new(q.config.latency_bound_ms))
        .collect();

    let mut eq: EventQueue<MEvent> = EventQueue::new();
    let mut feeder = MultiFeeder::new();
    let mut transport = TransportState::new(&cfg.transport, cfg.seed);
    // Reused drop buffers: retune evictions land per query; the offer
    // buffer collects each offer's sheds (incl. the offered frame).
    let mut retune_dropped: Vec<Vec<Entry<MultiItem>>> = (0..k).map(|_| Vec::new()).collect();
    let mut offer_dropped: Vec<Entry<MultiItem>> = Vec::new();

    let faults = &cfg.faults;
    feeder.feed_next(&mut eq, &mut arrivals, backgrounds, set, extractor, &mut cost, faults)?;

    while let Some((t, ev)) = eq.pop() {
        let class = match ev {
            MEvent::Ingress(..) | MEvent::FaultDrop { .. } => EventClass::Ingress,
            MEvent::Completion { .. } => EventClass::Completion,
        };
        clock.advance_to(t, class);
        match ev {
            MEvent::Ingress(ie) => {
                let IngressEvent { mut frame, utilities, mut ids } = *ie;
                let capture = frame.capture_ms;
                for st in states.iter_mut() {
                    st.now = st.now.max(t);
                    st.ingress += 1;
                    st.stages.observe(Stage::Ingress, capture);
                }
                // Refill the arrival pipeline (before dispatch, like the
                // single engine, so event-sequence ties order the same).
                feeder.feed_next(
                    &mut eq,
                    &mut arrivals,
                    backgrounds,
                    set,
                    extractor,
                    &mut cost,
                    faults,
                )?;

                // Shared pre-step: one rate observation, per-query CDF
                // updates, periodic retune (evictions per query).
                for d in retune_dropped.iter_mut() {
                    d.clear();
                }
                shedder.observe_arrival(t, &utilities, &mut retune_dropped);
                for (q, dr) in retune_dropped.iter_mut().enumerate() {
                    for e in dr.drain(..) {
                        states[q].account_shed(e, &mut feeder.id_pool);
                    }
                }

                // Admission bitset on the shared payload, then one Rc
                // clone per admitting query instead of a frame copy.
                let mut mask = QueryMask::empty();
                for (q, &u) in utilities.iter().enumerate() {
                    if shedder.admits(q, u) {
                        mask.set(q);
                    }
                }
                frame.admitted = mask;
                // Fault: shared-link blackout — the one crossing every
                // query's copy depends on is down, so the whole event is
                // fault-dropped for every query (the non-admitting
                // queries would have shed theirs anyway; skipping the
                // offer path on a dead link keeps per-query conservation
                // exact without queueing undeliverable frames).
                if faults.link_blackout(t) {
                    for (q, st) in states.iter_mut().enumerate() {
                        st.account_fault_drop(
                            frame.camera,
                            capture,
                            std::mem::take(&mut ids[q]),
                            &mut feeder.id_pool,
                        );
                    }
                    for (q, st) in states.iter_mut().enumerate() {
                        if t - st.last_control_sample >= 1_000.0 {
                            st.control_series.push((
                                t,
                                shedder.threshold(q),
                                shedder.target_rate(q),
                            ));
                            st.last_control_sample = t;
                        }
                    }
                    feeder.recycle_event(utilities, ids);
                    continue;
                }
                // Shared transmission: a frame admitted by ≥ 1 query
                // crosses the link exactly ONCE; every admitting query's
                // queue entry carries the same transmission outcome. The
                // ideal link stays byte-accounted but delay-free (a
                // bandwidth-collapse fault forces the modeled-link path).
                let bw_override = faults.bandwidth_override(t);
                let transit = if mask.is_empty() {
                    None
                } else if transport.is_ideal() && bw_override.is_none() {
                    transport.account_ideal(&frame);
                    None
                } else {
                    Some(transport.ship(t, &frame, bw_override))
                };
                let rc = Rc::new(frame);
                for (q, &u) in utilities.iter().enumerate() {
                    let item = MultiItem {
                        frame: rc.clone(),
                        ids: std::mem::take(&mut ids[q]),
                        transit,
                    };
                    offer_dropped.clear();
                    let _ = shedder.offer(q, u, t, item, &mut offer_dropped);
                    for e in offer_dropped.drain(..) {
                        states[q].account_shed(e, &mut feeder.id_pool);
                    }
                    if t - states[q].last_control_sample >= 1_000.0 {
                        states[q].control_series.push((
                            t,
                            shedder.threshold(q),
                            shedder.target_rate(q),
                        ));
                        states[q].last_control_sample = t;
                    }
                }
                feeder.recycle_event(utilities, ids);
            }
            MEvent::Completion { query: q, seq, capture_ms, exec_ms, dnn } => {
                states[q].now = states[q].now.max(t);
                shedder.tokens(q).release();
                // Fault: poisoned control observation — validation in the
                // query's control loop must reject it (see the
                // single-query engine for the semantics).
                let observed_ms = match faults.poison(t) {
                    Some(PoisonKind::Nan) => f64::NAN,
                    Some(PoisonKind::Stale) => -exec_ms.max(1.0),
                    None => exec_ms,
                };
                shedder.on_backend_complete(q, observed_ms);
                executor.on_complete(q, seq, dnn)?;
                let e2e = clock.measure_e2e(capture_ms, t);
                states[q].latency.observe(e2e);
                states[q].latency_windows.observe(capture_ms, e2e);
            }
            MEvent::FaultDrop { camera, capture_ms, ids } => {
                for (st, ids_q) in states.iter_mut().zip(ids) {
                    st.now = st.now.max(t);
                    st.ingress += 1;
                    st.stages.observe(Stage::Ingress, capture_ms);
                    st.account_fault_drop(camera, capture_ms, ids_q, &mut feeder.id_pool);
                }
                feeder.feed_next(
                    &mut eq,
                    &mut arrivals,
                    backgrounds,
                    set,
                    extractor,
                    &mut cost,
                    faults,
                )?;
            }
        }

        // Per-query dispatch: start services while that query has tokens
        // and frames (other queries' events never change this query's
        // state, so attempts after foreign events are no-ops).
        for q in 0..k {
            while shedder.tokens(q).available() > 0 {
                let Some(entry) = shedder.next_to_send(q) else { break };
                let now_q = states[q].now;
                let bound = set.queries()[q].config.latency_bound_ms;
                // Eq. 20 network term from the query's EWMA: exactly the
                // configured constant under an ideal link, the measured
                // shared-link latency under a constrained one.
                let expected_done = now_q + shedder.net_ls_q_ms(q) + shedder.proc_q_ms(q);
                if expected_done - entry.item.frame.capture_ms > bound {
                    states[q].account_shed(entry, &mut feeder.id_pool);
                    continue;
                }
                states[q]
                    .stages
                    .observe(Stage::Transmit, entry.item.frame.capture_ms);
                // The frame crossed the shared link once, at admission;
                // a lost crossing costs every admitting query its copy.
                if entry.item.transit.is_some_and(|tx| !tx.delivered) {
                    states[q].account_link_drop(entry, &mut feeder.id_pool);
                    continue;
                }
                // Fault: backend worker down — the multi engine books the
                // loss immediately (per-query token buckets make the
                // single-engine token-recovery dance redundant here).
                if faults.worker_down_until(now_q).is_some() {
                    let MultiItem { frame: rc, ids, .. } = entry.item;
                    states[q].account_fault_drop(
                        rc.camera,
                        rc.capture_ms,
                        ids,
                        &mut feeder.id_pool,
                    );
                    continue;
                }
                assert!(shedder.tokens(q).try_acquire());
                let MultiItem { frame: rc, ids, transit } = entry.item;
                let st = &mut states[q];
                st.transmitted += 1;
                st.qor.observe(&ids, true);
                st.decisions.push(FrameDecision {
                    camera: rc.camera,
                    capture_ms: rc.capture_ms,
                    kept: true,
                });
                let capture_ms = rc.capture_ms;
                if let Some(tx) = transit {
                    st.transmit_ms_total += tx.transfer_ms;
                    shedder.observe_network(q, rc.net_cam_ls_ms, tx.transfer_ms);
                }
                let bg = *backgrounds
                    .get(&rc.camera)
                    .ok_or_else(|| anyhow::anyhow!("no background for camera {}", rc.camera))?;
                let (last_stage, exec_ms) = executor.submit(q, &rc, bg)?;
                // Fault: straggler slowdown (see the single-query engine).
                let slow = faults.slowdown(now_q);
                let exec_ms = if slow != 1.0 { exec_ms * slow } else { exec_ms };
                let st = &mut states[q];
                st.stages.observe(Stage::BlobFilter, capture_ms);
                if last_stage >= Stage::ColorFilter {
                    st.stages.observe(Stage::ColorFilter, capture_ms);
                }
                let dnn = last_stage == Stage::Sink;
                if dnn {
                    st.stages.observe(Stage::Dnn, capture_ms);
                    st.stages.observe(Stage::Sink, capture_ms);
                }
                let seq = st.dispatch_seq;
                st.dispatch_seq += 1;
                let done_at = match transit {
                    // Ideal link: the historical constant-latency hop
                    // (same cost-RNG draw, same position).
                    None => st.now + cost.net_ls_q_ms() + exec_ms,
                    // Shared link: the backend can start no earlier than
                    // the frame's one delivery.
                    Some(tx) => st.now.max(tx.arrival_ms) + exec_ms,
                };
                observer.on_dispatch(
                    q,
                    now_q,
                    &rc,
                    &ids,
                    exec_ms,
                    dnn,
                    transit.as_ref(),
                    done_at,
                );
                // Recycled after the observer tap (behavior-neutral: the
                // pool is only consumed at the next ingress event).
                recycle(&mut feeder.id_pool, ids);
                drop(rc);
                eq.push(
                    done_at,
                    MEvent::Completion { query: q, seq, capture_ms, exec_ms, dnn },
                );
            }
        }
    }
    executor.finish()?;

    for (q, st) in states.iter_mut().enumerate() {
        st.fstats.poisoned_rejected = shedder.rejected_samples(q);
    }
    let end_ms = states.iter().fold(0.0f64, |m, s| m.max(s.now));
    let queries = set
        .queries()
        .iter()
        .zip(states)
        .map(|(cq, st)| QueryReport {
            name: cq.name.clone(),
            report: PipelineReport {
                qor: st.qor,
                latency: st.latency,
                latency_windows: st.latency_windows,
                stages: st.stages,
                control_series: st.control_series,
                decisions: st.decisions,
                ingress: st.ingress,
                transmitted: st.transmitted,
                shed: st.shed,
                link_dropped: st.link_dropped,
                // Physical bytes live on the shared report: the frame
                // crossed the link once, not once per query.
                bytes_on_wire: 0,
                transmit_ms_total: st.transmit_ms_total,
                end_ms: st.now,
                extract_ms_total: 0.0,
                faults: st.fstats,
                adaptation: crate::utility::AdaptationStats::default(),
            },
        })
        .collect();

    Ok(MultiPipelineReport {
        queries,
        frames: feeder.frames,
        extractions: extractor.extractions() - extractions_before,
        wire_frames: transport.frames_on_wire,
        bytes_on_wire: transport.bytes_on_wire,
        link_lost_frames: transport.frames_lost,
        end_ms,
        extract_ms_total: feeder.extract_ms_total,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::config::QueryConfig;
    use crate::pipeline::core::SimClock;
    use crate::pipeline::workloads::IterArrivals;
    use crate::shedder::QuerySpec;
    use crate::utility::Combine;
    use crate::video::{Video, VideoConfig};

    fn cameras(n: usize, frames: usize) -> Vec<Video> {
        (0..n)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 0xA10 + i as u64, i as u32, frames);
                vc.traffic.vehicle_rate = 0.35;
                Video::new(vc)
            })
            .collect()
    }

    fn specs() -> Vec<QuerySpec> {
        vec![
            QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
            QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)),
            QuerySpec::new(
                "either",
                QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Or),
            ),
        ]
    }

    #[test]
    fn multi_run_conserves_frames_per_query_and_extracts_once() {
        let videos = cameras(2, 120);
        let idx: Vec<usize> = (0..videos.len()).collect();
        let set = QuerySet::train(&specs(), &videos, &idx).unwrap();
        let fps = crate::video::streamer::aggregate_fps(&videos);
        let cfg = MultiSimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            backend_tokens: 1,
            arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
            seed: 0xA1,
            fps_total: fps,
            transport: TransportConfig::default(),
            faults: FaultPlan::default(),
        };
        let extractor = Extractor::native(set.union_model().clone());
        let mut backends = multi_backends(&set, &cfg.costs, cfg.seed);
        let mut executor = MultiSyncBackend::new(&mut backends);
        let bgs = crate::pipeline::backgrounds_of(&videos);
        let r = run_multi_pipeline(
            IterArrivals::new(crate::video::Streamer::new(&videos), fps),
            &bgs,
            &set,
            &cfg,
            &extractor,
            &mut executor,
            &mut SimClock,
        )
        .unwrap();
        assert_eq!(r.frames, 240);
        assert_eq!(r.extractions, r.frames, "one extraction per frame");
        assert_eq!(r.queries.len(), 3);
        for q in &r.queries {
            assert_eq!(q.report.ingress, r.frames);
            assert_eq!(q.report.ingress, q.report.transmitted + q.report.shed);
            assert_eq!(q.report.decisions.len() as u64, q.report.ingress);
        }
        let agg = r.aggregate();
        assert_eq!(agg.ingress, r.frames * 3);
        let qm = r.qor_mean();
        assert!((0.0..=1.0).contains(&qm));
    }

    #[test]
    fn extractor_union_mismatch_is_rejected() {
        let videos = cameras(1, 30);
        let set = QuerySet::train(&specs(), &videos, &[0]).unwrap();
        let wrong = Extractor::native(set.query_model(0)); // red-only model
        let cfg = MultiSimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            backend_tokens: 1,
            arbiter: ArbiterPolicy::Standalone,
            seed: 1,
            fps_total: 10.0,
            transport: TransportConfig::default(),
            faults: FaultPlan::default(),
        };
        let mut backends = multi_backends(&set, &cfg.costs, cfg.seed);
        let mut executor = MultiSyncBackend::new(&mut backends);
        let bgs = crate::pipeline::backgrounds_of(&videos);
        let err = run_multi_pipeline(
            IterArrivals::new(crate::video::Streamer::new(&videos), 10.0),
            &bgs,
            &set,
            &cfg,
            &wrong,
            &mut executor,
            &mut SimClock,
        );
        assert!(err.is_err());
    }
}
