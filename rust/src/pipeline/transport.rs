//! The modeled edge→backend network link: a deterministic,
//! clock-abstracted transmission stage between admission and the backend
//! queue.
//!
//! The paper folds camera→shedder and shedder→backend transfer times into
//! the latency budget (Eq. 20) and motivates shedding with "fewer compute
//! **and network** resources" — yet historically this pipeline modeled
//! transmission as a free constant. [`LinkModel`] makes the link a real
//! resource: finite bandwidth (serialization time derived from each
//! frame's **actual wire size**, see [`crate::video::wire`]), propagation
//! latency, seeded jitter, and optional loss with bounded retransmit.
//! [`Link`] is the FIFO transmit queue over that model.
//!
//! The default [`TransportConfig`] is [`LinkModel::ideal`] + raw
//! encoding: **zero behavioral overhead**. Under an ideal link every
//! driver's decision log is bit-identical to the pre-transport pipeline
//! (no extra RNG draws, no network-EWMA updates) — pinned by
//! `rust/tests/transport.rs`. Under a constrained link, measured
//! per-frame transfer times feed
//! [`ControlLoop::observe_network`](crate::shedder::ControlLoop::observe_network),
//! so the control loop's queue sizing (Eq. 20) and threshold derivation
//! (Eq. 19, via the effective service time) react to link congestion,
//! not just backend load.

use crate::pipeline::core::FramePayload;
use crate::util::rng::Rng;
use crate::video::wire::{raw_wire_size, WireEncoder, WireEncoding};
use std::collections::HashMap;

/// Parameters of the shedder→backend link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Link capacity in Mbit/s. Non-finite or non-positive values mean
    /// "no serialization delay" (infinitely fast).
    pub bandwidth_mbps: f64,
    /// One-way propagation latency added after serialization (ms).
    pub propagation_ms: f64,
    /// Multiplicative jitter amplitude on each attempt's serialization
    /// time (0.1 = ±10%), drawn from the link's seeded RNG.
    pub jitter: f64,
    /// Per-attempt loss probability in [0, 1).
    pub loss: f64,
    /// Retransmissions after a lost attempt; a frame that loses
    /// `1 + max_retransmits` attempts is dropped at the link.
    pub max_retransmits: u32,
}

impl LinkModel {
    /// The verification-mode link: infinitely fast, lossless, latency
    /// free. Pipelines treat it as "no transport stage at all".
    pub fn ideal() -> LinkModel {
        LinkModel {
            bandwidth_mbps: f64::INFINITY,
            propagation_ms: 0.0,
            jitter: 0.0,
            loss: 0.0,
            max_retransmits: 0,
        }
    }

    /// A clean constrained link: finite bandwidth, no propagation
    /// latency, jitter or loss.
    pub fn mbps(bandwidth_mbps: f64) -> LinkModel {
        LinkModel { bandwidth_mbps, ..LinkModel::ideal() }
    }

    /// True when the link adds no delay and loses nothing — the mode the
    /// pipelines bypass entirely (bit-identity with the pre-transport
    /// engine).
    pub fn is_ideal(&self) -> bool {
        !(self.bandwidth_mbps.is_finite() && self.bandwidth_mbps > 0.0)
            && self.propagation_ms <= 0.0
            && self.loss <= 0.0
    }
}

/// Outcome of offering one frame to the link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transmission {
    /// When the frame entered service (≥ offer time; the FIFO wait is
    /// `depart_ms - offer`).
    pub depart_ms: f64,
    /// Delivery time at the backend (end of the last serialization
    /// attempt, plus propagation) — or, for a lost frame, when the link
    /// gave up.
    pub arrival_ms: f64,
    /// Measured shedder→backend transfer (ms): queue wait +
    /// serialization (all attempts) + propagation. This is the sample fed
    /// to `ControlLoop::observe_network`.
    pub transfer_ms: f64,
    /// Bytes actually serialized per attempt (the wire size).
    pub bytes: u64,
    /// Serialization attempts made (1 = no retransmit).
    pub attempts: u32,
    /// False when the frame exhausted its retransmit budget.
    pub delivered: bool,
}

/// The FIFO transmit queue over a [`LinkModel`]: frames serialize one at
/// a time in offer order; a frame offered while the link is busy waits
/// for `busy_until`. Deterministic for a given seed and offer sequence.
#[derive(Debug, Clone)]
pub struct Link {
    model: LinkModel,
    rng: Rng,
    busy_until_ms: f64,
}

impl Link {
    /// A fresh idle link with its own seeded jitter/loss RNG.
    pub fn new(model: LinkModel, seed: u64) -> Link {
        Link { model, rng: Rng::new(seed ^ 0x71A5), busy_until_ms: 0.0 }
    }

    /// The static model this link instance samples from.
    pub fn model(&self) -> &LinkModel {
        &self.model
    }

    /// Serialization time of one attempt at `mbps` (ms), jittered.
    fn ser_ms(&mut self, bytes: u64, mbps: f64) -> f64 {
        if !(mbps.is_finite() && mbps > 0.0) {
            return 0.0;
        }
        // bytes·8 bit / (mbps·10⁶ bit/s) seconds → ms.
        let base = bytes as f64 * 8.0 / (mbps * 1_000.0);
        if self.model.jitter <= 0.0 {
            return base;
        }
        let f = 1.0 + (self.rng.f64() * 2.0 - 1.0) * self.model.jitter;
        (base * f).max(0.0)
    }

    /// Capacity in effect for one transmission: the model's bandwidth,
    /// further clamped down by an injected bandwidth-collapse fault.
    fn effective_mbps(&self, bw_override: Option<f64>) -> f64 {
        let m = self.model.bandwidth_mbps;
        match bw_override {
            Some(bw) if bw.is_finite() && bw > 0.0 => {
                if m.is_finite() && m > 0.0 {
                    m.min(bw)
                } else {
                    bw
                }
            }
            _ => m,
        }
    }

    /// Offer `bytes` to the link at `now_ms`. Attempts serialize
    /// back-to-back (each re-jittered, each a fresh loss coin) until one
    /// is delivered or the retransmit budget runs out.
    pub fn transmit(&mut self, now_ms: f64, bytes: u64) -> Transmission {
        self.transmit_at(now_ms, bytes, None)
    }

    /// [`Self::transmit`] under an optional bandwidth-collapse override
    /// (Mbit/s) that caps this transmission's capacity.
    pub fn transmit_at(
        &mut self,
        now_ms: f64,
        bytes: u64,
        bw_override: Option<f64>,
    ) -> Transmission {
        let mbps = self.effective_mbps(bw_override);
        let depart_ms = now_ms.max(self.busy_until_ms);
        let mut end = depart_ms;
        let max_attempts = 1 + self.model.max_retransmits;
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            end += self.ser_ms(bytes, mbps);
            let lost = self.model.loss > 0.0 && self.rng.chance(self.model.loss);
            if !lost {
                self.busy_until_ms = end;
                let arrival_ms = end + self.model.propagation_ms.max(0.0);
                return Transmission {
                    depart_ms,
                    arrival_ms,
                    transfer_ms: arrival_ms - now_ms,
                    bytes,
                    attempts,
                    delivered: true,
                };
            }
            if attempts >= max_attempts {
                self.busy_until_ms = end;
                return Transmission {
                    depart_ms,
                    arrival_ms: end,
                    transfer_ms: end - now_ms,
                    bytes,
                    attempts,
                    delivered: false,
                };
            }
        }
    }
}

/// Transport configuration of a pipeline: the link plus the wire
/// encoding that determines each frame's serialized size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransportConfig {
    /// The modeled shedder→backend link.
    pub link: LinkModel,
    /// Wire encoding that sets each frame's serialized size.
    pub encoding: WireEncoding,
}

impl Default for TransportConfig {
    /// Ideal link + raw encoding: the historical "transmission is free"
    /// pipeline, byte-accounted but behaviorally untouched.
    fn default() -> TransportConfig {
        TransportConfig { link: LinkModel::ideal(), encoding: WireEncoding::Raw }
    }
}

impl TransportConfig {
    /// A bandwidth-constrained link with the given encoding.
    pub fn constrained(bandwidth_mbps: f64, encoding: WireEncoding) -> TransportConfig {
        TransportConfig { link: LinkModel::mbps(bandwidth_mbps), encoding }
    }

    /// True when the link is ideal (infinite bandwidth, no delay/loss).
    pub fn is_ideal(&self) -> bool {
        self.link.is_ideal()
    }
}

/// Per-run transport state: the link, one wire encoder per camera, and
/// the bytes/frames accounting that lands in the pipeline report.
pub(crate) struct TransportState {
    encoding: WireEncoding,
    link: Link,
    encoders: HashMap<u32, WireEncoder>,
    buf: Vec<u8>,
    ideal: bool,
    pub bytes_on_wire: u64,
    pub frames_on_wire: u64,
    pub frames_lost: u64,
    pub transmit_ms_total: f64,
}

impl TransportState {
    pub fn new(cfg: &TransportConfig, seed: u64) -> TransportState {
        TransportState {
            encoding: cfg.encoding,
            link: Link::new(cfg.link, seed),
            encoders: HashMap::new(),
            buf: Vec::new(),
            ideal: cfg.link.is_ideal(),
            bytes_on_wire: 0,
            frames_on_wire: 0,
            frames_lost: 0,
            transmit_ms_total: 0.0,
        }
    }

    pub fn is_ideal(&self) -> bool {
        self.ideal
    }

    /// Ideal-link byte accounting: no encoding, no RNG, no delay — the
    /// frame is counted at its raw-u8 wire size and delivered instantly.
    pub fn account_ideal(&mut self, payload: &FramePayload) {
        self.frames_on_wire += 1;
        self.bytes_on_wire += raw_wire_size(payload.width, payload.height) as u64;
    }

    /// Encode the frame (per-camera delta state) and push it through the
    /// link at `now_ms`. `bw_override` is an injected bandwidth-collapse
    /// fault capping this transmission's capacity (None = the model's).
    pub fn ship(
        &mut self,
        now_ms: f64,
        payload: &FramePayload,
        bw_override: Option<f64>,
    ) -> Transmission {
        let enc = self
            .encoders
            .entry(payload.camera)
            .or_insert_with(|| WireEncoder::new(self.encoding));
        enc.encode_into(
            payload.camera,
            payload.width,
            payload.height,
            &payload.rgb,
            &mut self.buf,
        );
        let bytes = self.buf.len() as u64;
        let tx = self.link.transmit_at(now_ms, bytes, bw_override);
        self.frames_on_wire += 1;
        self.bytes_on_wire += bytes;
        if tx.delivered {
            self.transmit_ms_total += tx.transfer_ms;
        } else {
            self.frames_lost += 1;
            // The decoder never saw this message: drop the camera's delta
            // reference so the next frame ships as a keyframe and the two
            // ends stay bit-coherent.
            if let Some(enc) = self.encoders.get_mut(&payload.camera) {
                enc.invalidate();
            }
        }
        tx
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;

    #[test]
    fn ideal_link_detection() {
        assert!(LinkModel::ideal().is_ideal());
        assert!(TransportConfig::default().is_ideal());
        assert!(!LinkModel::mbps(10.0).is_ideal());
        assert!(!LinkModel { propagation_ms: 5.0, ..LinkModel::ideal() }.is_ideal());
        assert!(!LinkModel { loss: 0.1, ..LinkModel::ideal() }.is_ideal());
        // Non-positive bandwidth means "infinitely fast", not "stalled".
        assert!(LinkModel { bandwidth_mbps: 0.0, ..LinkModel::ideal() }.is_ideal());
    }

    #[test]
    fn serialization_and_fifo_math() {
        // 1 Mbit/s, no jitter: 125 000 bytes = 1 Mbit = 1000 ms.
        let mut link = Link::new(
            LinkModel { propagation_ms: 2.0, ..LinkModel::mbps(1.0) },
            7,
        );
        let a = link.transmit(0.0, 125_000);
        assert!(a.delivered);
        assert_eq!(a.depart_ms, 0.0);
        assert!((a.arrival_ms - 1002.0).abs() < 1e-9, "arrival {}", a.arrival_ms);
        assert!((a.transfer_ms - 1002.0).abs() < 1e-9);
        // Offered while busy: waits for the link, FIFO.
        let b = link.transmit(10.0, 12_500);
        assert!((b.depart_ms - 1000.0).abs() < 1e-9);
        assert!((b.arrival_ms - 1102.0).abs() < 1e-9);
        assert!((b.transfer_ms - 1092.0).abs() < 1e-9);
    }

    #[test]
    fn loss_exhausts_bounded_retransmits() {
        let mut link = Link::new(
            LinkModel { loss: 1.0, max_retransmits: 2, ..LinkModel::mbps(1.0) },
            1,
        );
        let t = link.transmit(0.0, 125_000);
        assert!(!t.delivered);
        assert_eq!(t.attempts, 3);
        // All three attempts occupied the link back-to-back.
        assert!((t.arrival_ms - 3000.0).abs() < 1e-9, "gave up at {}", t.arrival_ms);
    }

    #[test]
    fn jitter_is_seeded_and_bounded() {
        let mk = |seed| {
            Link::new(LinkModel { jitter: 0.1, ..LinkModel::mbps(1.0) }, seed)
                .transmit(0.0, 125_000)
        };
        let a = mk(5);
        let b = mk(5);
        assert_eq!(a, b, "same seed, same transmission");
        assert!(a.transfer_ms >= 900.0 - 1e-9 && a.transfer_ms <= 1100.0 + 1e-9);
    }

    #[test]
    fn ideal_link_transmits_for_free() {
        let mut link = Link::new(LinkModel::ideal(), 9);
        let t = link.transmit(42.0, 1 << 30);
        assert!(t.delivered);
        assert_eq!(t.transfer_ms, 0.0);
        assert_eq!(t.arrival_ms, 42.0);
    }

    #[test]
    fn bandwidth_override_caps_capacity() {
        // Override on an ideal link: 1 Mbit/s effective → 1000 ms.
        let mut ideal = Link::new(LinkModel::ideal(), 3);
        let t = ideal.transmit_at(0.0, 125_000, Some(1.0));
        assert!((t.transfer_ms - 1000.0).abs() < 1e-9, "ser {}", t.transfer_ms);
        // Override only ever *lowers* a finite link's capacity.
        let mut slow = Link::new(LinkModel::mbps(1.0), 3);
        let u = slow.transmit_at(0.0, 125_000, Some(10.0));
        assert!((u.transfer_ms - 1000.0).abs() < 1e-9, "ser {}", u.transfer_ms);
        // Degenerate overrides are ignored.
        let mut l = Link::new(LinkModel::mbps(1.0), 3);
        let v = l.transmit_at(0.0, 125_000, Some(f64::NAN));
        assert!((v.transfer_ms - 1000.0).abs() < 1e-9);
    }
}
