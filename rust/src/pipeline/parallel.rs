//! Sharded multi-camera sweep engine: run independent simulations across
//! `std::thread::scope` workers with a deterministic merge of metrics.
//!
//! Two layers:
//!
//! * [`parallel_map`] — a minimal deterministic parallel map (rayon is
//!   unavailable offline): items are claimed from an atomic cursor, each
//!   result lands in its own slot, and the output order is the input
//!   order regardless of scheduling. A panic in any worker propagates
//!   when the scope joins.
//! * [`run_sharded_sim`] — the multi-camera scaling scenario from the
//!   ROADMAP north-star: one **shard per camera**, each a thin
//!   `pipeline::core` driver with its own Load Shedder + backend executor
//!   (the per-camera edge-box deployment, complementing `run_sim`'s
//!   shared-shedder deployment), merged into a single [`SimReport`].
//!   Per-shard seeds are derived from the base seed and camera id, so
//!   results are reproducible and independent of the worker count.
//!
//! The extractor/backend types are deliberately constructed *inside* each
//! worker (they are `!Send`: the artifact backend holds `Rc` handles), so
//! shards share only `Sync` inputs: the videos, the model, the config.

use crate::backend::{BackendQuery, CostModel, Detector};
use crate::features::{Extractor, IncrementalConfig};
use crate::pipeline::sim::{run_sim, SimConfig, SimReport};
use crate::utility::UtilityModel;
use crate::video::Video;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count for sweep parallelism (defaults to the machine).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Deterministic parallel map: applies `f` to every item on up to
/// `threads` scoped workers; `out[i]` is always `f(i, &items[i])`.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                // Invariant: slot mutexes are never poisoned — a worker
                // panic propagates at scope join before the unwrap runs.
                #[allow(clippy::unwrap_used)]
                {
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    // Invariant: the cursor hands every index to exactly one worker, and
    // the scope joins only after all workers finish — every slot is full.
    #[allow(clippy::unwrap_used, clippy::expect_used)]
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

/// Merge shard reports by reference (index order → deterministic
/// output); only the first report is copied, the rest are absorbed. The
/// control-loop series is re-sorted by timestamp across shards; the
/// decision logs are concatenated in shard order (each shard's log stays
/// event-ordered internally, the merged log is grouped per camera).
pub fn merge_reports<'a, I>(reports: I) -> Option<SimReport>
where
    I: IntoIterator<Item = &'a SimReport>,
{
    let mut it = reports.into_iter();
    let mut acc = it.next()?.clone();
    for r in it {
        acc.qor.merge(&r.qor);
        acc.latency.merge(&r.latency);
        acc.latency_windows.merge(&r.latency_windows);
        acc.stages.merge(&r.stages);
        acc.control_series.extend_from_slice(&r.control_series);
        acc.decisions.extend_from_slice(&r.decisions);
        acc.ingress += r.ingress;
        acc.transmitted += r.transmitted;
        acc.shed += r.shed;
        acc.link_dropped += r.link_dropped;
        acc.bytes_on_wire += r.bytes_on_wire;
        acc.transmit_ms_total += r.transmit_ms_total;
        acc.end_ms = acc.end_ms.max(r.end_ms);
        acc.extract_ms_total += r.extract_ms_total;
        acc.faults.merge(&r.faults);
        acc.adaptation.merge(&r.adaptation);
    }
    acc.control_series.sort_by(|a, b| a.0.total_cmp(&b.0));
    Some(acc)
}

/// Run the N-camera simulation as one shard per camera — each camera gets
/// its own Load Shedder and (token-paced) backend — across `threads`
/// workers, then merge metrics deterministically.
///
/// `cfg` is the per-shard template: `fps_total` is overridden with each
/// camera's rate and the seed is decorrelated per camera. Returns the
/// merged report plus per-camera reports (camera-id order).
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.sharded(threads)`
/// [`.run(videos, model)`](crate::pipeline::ShardedBuilder::run); this
/// free function is kept as a thin compatibility wrapper.
pub fn run_sharded_sim(
    videos: &[Video],
    cfg: &SimConfig,
    model: &UtilityModel,
    threads: usize,
) -> Result<(SimReport, Vec<(u32, SimReport)>)> {
    run_sharded_sim_with(videos, cfg, model, threads, None)
}

/// [`run_sharded_sim`] with optional per-camera **incremental feature
/// extraction**: each shard's extractor owns one tile engine for its
/// camera, so per-frame classification work shrinks to the dirty tiles.
/// Extraction stays bit-identical, so every metric matches the
/// non-incremental run exactly (pinned by `rust/tests/incremental.rs`).
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.sharded(threads)`
/// [`.incremental(cfg)`](crate::pipeline::ShardedBuilder::incremental)
/// [`.run(videos, model)`](crate::pipeline::ShardedBuilder::run); this
/// free function is kept as a thin compatibility wrapper.
pub fn run_sharded_sim_with(
    videos: &[Video],
    cfg: &SimConfig,
    model: &UtilityModel,
    threads: usize,
    incremental: Option<IncrementalConfig>,
) -> Result<(SimReport, Vec<(u32, SimReport)>)> {
    if videos.is_empty() {
        return Err(anyhow!("run_sharded_sim needs at least one camera"));
    }
    let shard_results = parallel_map(videos, threads, |_, video| -> Result<SimReport> {
        let mut shard_cfg = cfg.clone();
        shard_cfg.fps_total = video.config.fps;
        shard_cfg.seed = cfg
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(video.camera_id() as u64 + 1));
        let mut extractor = Extractor::native(model.clone());
        if let Some(inc) = incremental {
            extractor = extractor.with_incremental(inc);
        }
        let mut backend = BackendQuery::new(
            shard_cfg.query.clone(),
            Detector::native(12, model.fg_threshold),
            CostModel::new(shard_cfg.costs.clone(), shard_cfg.seed),
            model.fg_threshold,
        );
        let mut bgs: HashMap<u32, &[f32]> = HashMap::new();
        bgs.insert(video.camera_id(), video.background());
        run_sim(video.iter(), &bgs, &shard_cfg, &extractor, &mut backend)
    });

    let mut per_camera = Vec::with_capacity(videos.len());
    for (video, result) in videos.iter().zip(shard_results) {
        per_camera.push((video.camera_id(), result?));
    }
    let merged = merge_reports(per_camera.iter().map(|(_, r)| r))
        .ok_or_else(|| anyhow!("non-empty shard set"))?;
    Ok((merged, per_camera))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::config::{CostConfig, QueryConfig, ShedderConfig};
    use crate::pipeline::Policy;
    use crate::utility::{train, Combine};
    use crate::video::VideoConfig;

    fn cameras(n: usize, frames: usize) -> Vec<Video> {
        (0..n)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 0x5AD + i as u64, i as u32, frames);
                vc.traffic.vehicle_rate = 0.35;
                Video::new(vc)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        SimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
            backend_tokens: 1,
            policy: Policy::UtilityControlLoop,
            seed: 0x5A,
            fps_total: 10.0,
            transport: crate::pipeline::TransportConfig::default(),
            faults: crate::pipeline::FaultPlan::default(),
            adaptation: crate::utility::AdaptationConfig::default(),
        }
    }

    #[test]
    fn parallel_map_is_deterministic_and_ordered() {
        let items: Vec<u64> = (0..64).collect();
        let serial = parallel_map(&items, 1, |i, &x| x * 3 + i as u64);
        let parallel = parallel_map(&items, 8, |i, &x| x * 3 + i as u64);
        assert_eq!(serial, parallel);
        assert_eq!(serial[5], 20);
        let empty: Vec<u64> = Vec::new();
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
    }

    #[test]
    fn sharded_sim_conserves_frames_and_is_thread_count_invariant() {
        let videos = cameras(4, 120);
        let model = train(&videos, &[0, 1], &[NamedColor::Red], Combine::Single);
        let cfg = cfg();
        let (serial, per_cam_serial) = run_sharded_sim(&videos, &cfg, &model, 1).unwrap();
        let (parallel, per_cam_par) = run_sharded_sim(&videos, &cfg, &model, 4).unwrap();

        assert_eq!(serial.ingress, 480);
        assert_eq!(serial.ingress, serial.transmitted + serial.shed);
        // Bit-for-bit the same decisions regardless of worker count.
        assert_eq!(serial.ingress, parallel.ingress);
        assert_eq!(serial.transmitted, parallel.transmitted);
        assert_eq!(serial.shed, parallel.shed);
        assert_eq!(serial.qor.overall(), parallel.qor.overall());
        assert_eq!(serial.latency.count(), parallel.latency.count());
        assert_eq!(serial.control_series, parallel.control_series);
        for ((c1, r1), (c2, r2)) in per_cam_serial.iter().zip(&per_cam_par) {
            assert_eq!(c1, c2);
            assert_eq!(r1.ingress, r2.ingress);
            assert_eq!(r1.shed, r2.shed);
        }
    }

    #[test]
    fn merged_metrics_match_shard_sums() {
        let videos = cameras(3, 100);
        let model = train(&videos, &[0], &[NamedColor::Red], Combine::Single);
        let (merged, per_camera) = run_sharded_sim(&videos, &cfg(), &model, 2).unwrap();
        let sum_ingress: u64 = per_camera.iter().map(|(_, r)| r.ingress).sum();
        let sum_shed: u64 = per_camera.iter().map(|(_, r)| r.shed).sum();
        assert_eq!(merged.ingress, sum_ingress);
        assert_eq!(merged.shed, sum_shed);
        let sum_latency: u64 = per_camera.iter().map(|(_, r)| r.latency.count()).sum();
        assert_eq!(merged.latency.count(), sum_latency);
    }
}
