//! Supervised worker threads for the realtime backends: restart-on-crash
//! with a bounded retry budget and exponential backoff, plus a
//! `recv_timeout` rendezvous so a hung worker yields a diagnosable error
//! instead of a frozen run.
//!
//! [`SupervisedWorker`] owns one worker thread built from a
//! [`RunnerFactory`]: the factory crosses into the thread and builds the
//! actual job runner *there* (the PJRT client is not `Send`, so detector
//! construction must happen on the worker). Jobs are `Clone` and queued
//! in a replay buffer until their completion is acked, so a restart can
//! resend everything the dead worker never finished — completions that
//! were already buffered in the channel when the worker died are drained
//! first and never re-run.
//!
//! Failure taxonomy surfaced to callers (the realtime satellite of the
//! fault-injection work):
//! * factory/runner `Err` → the worker's *actual* error, with context;
//! * panic → the panic payload's message;
//! * hang → "unresponsive" timeout error naming the configured window
//!   and the jobs outstanding.

use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// A job runner living on the worker thread (built there by the factory;
/// it never crosses threads, so it may hold `!Send` handles).
pub type Runner<J> = Box<dyn FnMut(&J) -> Result<()>>;

/// Builds a fresh runner inside each (re)spawned worker thread.
pub type RunnerFactory<J> = Arc<dyn Fn() -> Result<Runner<J>> + Send + Sync>;

/// Restart / rendezvous policy for a [`SupervisedWorker`].
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Rendezvous timeout: how long a completion wait may block before
    /// the worker is declared hung.
    pub recv_timeout: Duration,
    /// Restart budget: how many times a crashed worker is respawned
    /// before the supervisor gives up and surfaces the cause.
    pub max_restarts: u32,
    /// Base backoff before the first respawn; doubles per restart.
    pub backoff: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            recv_timeout: Duration::from_secs(30),
            max_restarts: 2,
            backoff: Duration::from_millis(50),
        }
    }
}

/// A worker thread under supervision: FIFO job channel in, unit acks
/// out, crash → bounded restart with outstanding-job replay, hang →
/// timeout error.
pub struct SupervisedWorker<J: Send + Clone + 'static> {
    factory: RunnerFactory<J>,
    cfg: SupervisorConfig,
    work_tx: Option<mpsc::Sender<J>>,
    done_rx: mpsc::Receiver<()>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    /// Jobs sent but not yet acked, FIFO — the restart replay buffer.
    outstanding: VecDeque<J>,
    jobs_done: u64,
    jobs_submitted: u64,
    restarts: u32,
    dead: Option<String>,
}

impl<J: Send + Clone + 'static> SupervisedWorker<J> {
    /// Spawn the first worker. A factory that fails immediately (e.g. an
    /// artifact load error) is only discovered at the first rendezvous —
    /// the error it returned is what surfaces there.
    pub fn spawn(factory: RunnerFactory<J>, cfg: SupervisorConfig) -> Result<Self> {
        let (work_tx, done_rx, handle) = Self::spawn_thread(&factory)?;
        Ok(SupervisedWorker {
            factory,
            cfg,
            work_tx: Some(work_tx),
            done_rx,
            handle: Some(handle),
            outstanding: VecDeque::new(),
            jobs_done: 0,
            jobs_submitted: 0,
            restarts: 0,
            dead: None,
        })
    }

    fn spawn_thread(
        factory: &RunnerFactory<J>,
    ) -> Result<(mpsc::Sender<J>, mpsc::Receiver<()>, std::thread::JoinHandle<Result<()>>)> {
        let (work_tx, work_rx) = mpsc::channel::<J>();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let factory = Arc::clone(factory);
        let handle = std::thread::Builder::new()
            .name("backend-worker".into())
            .spawn(move || -> Result<()> {
                let mut runner = factory()?;
                while let Ok(job) = work_rx.recv() {
                    runner(&job)?;
                    if done_tx.send(()).is_err() {
                        break; // supervisor gone: orderly exit
                    }
                }
                Ok(())
            })
            .map_err(|e| anyhow!("failed to spawn backend worker: {e}"))?;
        Ok((work_tx, done_rx, handle))
    }

    /// Times the worker has been respawned after a crash.
    pub fn restarts(&self) -> u32 {
        self.restarts
    }

    /// Jobs acked so far.
    pub fn jobs_done(&self) -> u64 {
        self.jobs_done
    }

    /// Send one job. On a dead channel the supervisor restarts (the job
    /// is already in the replay buffer, so it is resent); once the
    /// restart budget is exhausted every further submit fails fast with
    /// the recorded cause.
    pub fn submit(&mut self, job: J) -> Result<()> {
        if let Some(cause) = &self.dead {
            return Err(anyhow!("backend worker is dead: {cause}"));
        }
        self.outstanding.push_back(job.clone());
        self.jobs_submitted += 1;
        let tx = self
            .work_tx
            .as_ref()
            .ok_or_else(|| anyhow!("backend worker already shut down"))?;
        if tx.send(job).is_err() {
            self.restart("worker channel closed on submit")?;
        }
        Ok(())
    }

    /// Block until the 0-based job `job` has been acked. A crash mid-wait
    /// triggers a restart (with replay); a silent worker past
    /// `recv_timeout` yields an "unresponsive" error naming the window
    /// and the outstanding count.
    pub fn wait_for(&mut self, job: u64) -> Result<()> {
        while self.jobs_done <= job {
            if let Some(cause) = &self.dead {
                return Err(anyhow!("backend worker is dead: {cause}"));
            }
            match self.done_rx.recv_timeout(self.cfg.recv_timeout) {
                Ok(()) => {
                    self.jobs_done += 1;
                    self.outstanding.pop_front();
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.restart("worker disconnected mid-run")?;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let finished =
                        self.handle.as_ref().map(|h| h.is_finished()).unwrap_or(true);
                    if finished {
                        // Exited without dropping its channel yet — treat
                        // as a crash, not a hang.
                        self.restart("worker exited mid-run")?;
                    } else {
                        return Err(anyhow!(
                            "backend worker unresponsive: no completion within {:?} \
                             ({} of {} jobs done)",
                            self.cfg.recv_timeout,
                            self.jobs_done,
                            self.jobs_submitted
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Join the dead worker for its real cause, respawn within budget,
    /// and replay every unacked job on the fresh worker.
    fn restart(&mut self, context: &str) -> Result<()> {
        // Acks buffered in the channel before the crash survive the
        // sender's death: harvest them first so finished jobs are never
        // re-run on the replacement worker.
        while self.done_rx.try_recv().is_ok() {
            self.jobs_done += 1;
            self.outstanding.pop_front();
        }
        let cause = match self.handle.take() {
            Some(h) => match h.join() {
                Ok(Ok(())) => format!("{context}: worker exited cleanly"),
                Ok(Err(e)) => format!("{context}: {e:#}"),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".to_string());
                    format!("{context}: worker panicked: {msg}")
                }
            },
            None => context.to_string(),
        };
        if self.restarts >= self.cfg.max_restarts {
            self.dead = Some(cause.clone());
            self.work_tx = None;
            return Err(anyhow!(
                "backend worker failed permanently after {} restart(s): {cause}",
                self.restarts
            ));
        }
        let wait = self.cfg.backoff.saturating_mul(1u32 << self.restarts.min(16));
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        self.restarts += 1;
        let (work_tx, done_rx, handle) = Self::spawn_thread(&self.factory)?;
        self.work_tx = Some(work_tx);
        self.done_rx = done_rx;
        self.handle = Some(handle);
        // Replay in-flight work the dead worker never acked.
        let replay: Vec<J> = self.outstanding.iter().cloned().collect();
        for job in replay {
            let Some(tx) = self.work_tx.as_ref() else { break };
            if tx.send(job).is_err() {
                // The fresh worker died during replay (e.g. the factory
                // succeeds but the runner fails instantly): burn another
                // slot of the restart budget.
                return self.restart("worker died replaying outstanding jobs");
            }
        }
        Ok(())
    }

    /// Orderly shutdown: close the channel, join, surface the worker's
    /// terminal result.
    pub fn finish(&mut self) -> Result<()> {
        drop(self.work_tx.take());
        if let Some(h) = self.handle.take() {
            match h.join() {
                Ok(r) => r?,
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".to_string());
                    return Err(anyhow!("backend worker panicked: {msg}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn cfg(timeout_ms: u64, max_restarts: u32) -> SupervisorConfig {
        SupervisorConfig {
            recv_timeout: Duration::from_millis(timeout_ms),
            max_restarts,
            backoff: Duration::from_millis(1),
        }
    }

    #[test]
    fn healthy_worker_runs_jobs_in_order() {
        let factory: RunnerFactory<u32> = Arc::new(|| Ok(Box::new(|_: &u32| Ok(()))));
        let mut w = SupervisedWorker::spawn(factory, cfg(5_000, 2)).unwrap();
        for i in 0..10u32 {
            w.submit(i).unwrap();
        }
        w.wait_for(9).unwrap();
        assert_eq!(w.jobs_done(), 10);
        assert_eq!(w.restarts(), 0);
        w.finish().unwrap();
    }

    #[test]
    fn panicking_worker_exhausts_budget_and_surfaces_the_message() {
        let factory: RunnerFactory<u32> = Arc::new(|| {
            Ok(Box::new(|_: &u32| -> Result<()> {
                panic!("detector exploded on frame");
            }))
        });
        let mut w = SupervisedWorker::spawn(factory, cfg(5_000, 1)).unwrap();
        w.submit(1).unwrap();
        let err = w.wait_for(0).unwrap_err().to_string();
        assert!(err.contains("detector exploded on frame"), "got: {err}");
        assert!(err.contains("failed permanently"), "got: {err}");
        // Every further submit fails fast with the recorded cause.
        let err2 = w.submit(2).unwrap_err().to_string();
        assert!(err2.contains("dead"), "got: {err2}");
    }

    #[test]
    fn factory_error_surfaces_as_the_real_cause() {
        let factory: RunnerFactory<u32> =
            Arc::new(|| Err(anyhow!("artifact load failed: missing kernel.bin")));
        let mut w = SupervisedWorker::spawn(factory, cfg(5_000, 0)).unwrap();
        // The worker exits before touching any job; depending on timing
        // the dead channel is noticed at submit or at the rendezvous.
        let err = match w.submit(7) {
            Err(e) => e.to_string(),
            Ok(()) => w.wait_for(0).unwrap_err().to_string(),
        };
        assert!(err.contains("artifact load failed"), "got: {err}");
    }

    #[test]
    fn erroring_worker_surfaces_its_error() {
        let factory: RunnerFactory<u32> = Arc::new(|| {
            Ok(Box::new(|j: &u32| -> Result<()> {
                if *j >= 2 {
                    Err(anyhow!("background missing for camera {j}"))
                } else {
                    Ok(())
                }
            }))
        });
        let mut w = SupervisedWorker::spawn(factory, cfg(5_000, 0)).unwrap();
        for j in 0..3u32 {
            w.submit(j).unwrap();
        }
        w.wait_for(1).unwrap();
        let err = w.wait_for(2).unwrap_err().to_string();
        assert!(err.contains("background missing for camera 2"), "got: {err}");
    }

    #[test]
    fn hung_worker_times_out_with_a_diagnosable_error() {
        let factory: RunnerFactory<u32> = Arc::new(|| {
            Ok(Box::new(|_: &u32| -> Result<()> {
                std::thread::sleep(Duration::from_secs(30));
                Ok(())
            }))
        });
        let mut w = SupervisedWorker::spawn(factory, cfg(100, 2)).unwrap();
        w.submit(1).unwrap();
        let err = w.wait_for(0).unwrap_err().to_string();
        assert!(err.contains("unresponsive"), "got: {err}");
        assert!(err.contains("0 of 1 jobs done"), "got: {err}");
    }

    #[test]
    fn transient_crash_restarts_and_replays_outstanding_jobs() {
        // The worker panics on job 3, first incarnation only. The restart
        // must replay jobs 2..5 (job 0 and 1 were acked) and finish.
        let generation = Arc::new(AtomicU32::new(0));
        let seen = Arc::new(AtomicU32::new(0));
        let factory: RunnerFactory<u32> = {
            let generation = Arc::clone(&generation);
            let seen = Arc::clone(&seen);
            Arc::new(move || {
                let gen = generation.fetch_add(1, Ordering::SeqCst);
                let seen = Arc::clone(&seen);
                Ok(Box::new(move |j: &u32| -> Result<()> {
                    seen.fetch_add(1, Ordering::SeqCst);
                    if gen == 0 && *j == 3 {
                        panic!("transient fault on job 3");
                    }
                    Ok(())
                }))
            })
        };
        let mut w = SupervisedWorker::spawn(factory, cfg(5_000, 2)).unwrap();
        for j in 0..6u32 {
            w.submit(j).unwrap();
        }
        w.wait_for(5).unwrap();
        assert_eq!(w.jobs_done(), 6);
        assert_eq!(w.restarts(), 1, "exactly one respawn");
        // Acked jobs are never re-run: the first incarnation ran jobs
        // 0..=3 (4 calls), the replacement replays only the unacked tail.
        assert!(seen.load(Ordering::SeqCst) <= 10);
        w.finish().unwrap();
    }
}
