//! Fleet-scale hierarchical shedding: E edge nodes → one regional
//! aggregator → a load-balanced cluster of M detector workers.
//!
//! Two-tier topology (the paper's edge deployment scaled out):
//!
//! ```text
//!   cameras ──► edge node 0 ─┐  (multi-query shedder, hop-A uplink)
//!   cameras ──► edge node 1 ─┼─► regional aggregator ──► M workers
//!   cameras ──► edge node E ─┘  (2nd-level shedder,     (min-busy
//!                                hop-B link)             dispatch)
//! ```
//!
//! Tier 1 reuses the multi-query engine verbatim: each node is an
//! independent [`run_multi_pipeline`](super::multi::run_multi_pipeline)
//! run over its camera slice, with its own [`MultiShedder`] and its own
//! hop-A uplink (the node's `transport` — the shared shedder→backend
//! link reinterpreted as the edge→aggregator uplink). A
//! [`DispatchObserver`] tap records every edge dispatch — the
//! aggregator's ingress stream — without perturbing the engine, so each
//! node's run stays bit-identical to a standalone deployment.
//!
//! Tier 2 replays the recorded dispatches in one deterministic merge
//! order — `(egress time, node, record index)` — through the
//! aggregator policy:
//!
//! * [`AggregatorPolicy::PassThrough`] forwards everything: no extra
//!   sheds, no extra delay. A 1-node pass-through fleet over an ideal
//!   hop-B link **bit-matches** `run_multi_sim` (pinned by
//!   `rust/tests/fleet.rs`).
//! * [`AggregatorPolicy::DeadlineCapacity`] re-arbitrates: each
//!   physical frame crosses the hop-B link **once** (query copies of
//!   the same frame share the crossing, like the edge tier's shared
//!   transmission), then the least-busy worker (lowest index on ties)
//!   is picked and the frame is shed if its projected completion would
//!   bust the query's latency bound. The edge's `exec_ms` draw is the
//!   cluster's service demand — the edge runs the same calibrated cost
//!   model the cluster charges, so its local control loop prices
//!   downstream work correctly.
//!
//! Per-query fleet metrics merge through the existing
//! [`merge_reports`] path; aggregator-tier sheds and hop-B losses are
//! applied as exact [`QorTracker::demote`](crate::metrics::QorTracker)
//! corrections, and under `DeadlineCapacity` the per-query latency is
//! rebuilt from cluster completions. Conservation holds per query
//! across tiers (pinned by `conserves()` and the property tests):
//!
//! ```text
//!   ingress == completed + shed(edge) + shed(aggregator)
//!            + link_dropped(hop A) + link_dropped(hop B)
//!            + fault_dropped
//! ```
//!
//! Seeds: node 0 keeps the edge seed (the 1-node equivalence above);
//! node k decorrelates golden-ratio style like shard and per-query
//! backend seeds. The hop-B link draws from the aggregator tier's own
//! seed, so both hops' loss processes are independent.

use crate::features::Extractor;
use crate::metrics::{LatencyTracker, WindowSeries};
use crate::pipeline::core::{
    backgrounds_of, FramePayload, PipelineConfig, PipelineReport, SimClock,
};
use crate::pipeline::multi::{
    multi_backends, run_multi_pipeline_observed, DispatchObserver, MultiPipelineReport,
    MultiSimConfig, MultiSyncBackend,
};
use crate::pipeline::parallel::{default_threads, merge_reports, parallel_map};
use crate::pipeline::transport::{Link, Transmission};
use crate::pipeline::workloads::IterArrivals;
use crate::shedder::{ArbiterPolicy, QuerySet};
use crate::video::streamer::aggregate_fps;
use crate::video::{raw_wire_size, Streamer, Video};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::ops::Range;

/// How the regional aggregator treats the filtered union stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregatorPolicy {
    /// Forward every edge dispatch untouched: no second-level sheds, no
    /// hop-B delay. The bit-identity mode (a 1-node pass-through fleet
    /// is exactly `run_multi_sim`).
    PassThrough,
    /// Second-level shedder: ship each physical frame once over the
    /// hop-B link, dispatch to the least-busy of M workers, and shed
    /// any frame whose projected completion busts its query's latency
    /// bound (the edge deadline check, re-run against cluster state).
    DeadlineCapacity,
}

/// Fleet shape: how many edge nodes the cameras split across, the
/// backend cluster size, and the driver parallelism.
#[derive(Debug, Clone)]
pub struct FleetTopology {
    /// Edge nodes; cameras partition contiguously across them (the
    /// first `cameras % edge_nodes` nodes take one extra).
    pub edge_nodes: usize,
    /// Detector workers in the backend cluster (used by
    /// [`AggregatorPolicy::DeadlineCapacity`]).
    pub workers: usize,
    /// Worker threads for the tier-1 node sweep (results are
    /// thread-count invariant, like `run_sharded_sim`).
    pub threads: usize,
    /// Second-level shedding policy at the regional aggregator.
    pub aggregator: AggregatorPolicy,
}

impl Default for FleetTopology {
    fn default() -> Self {
        FleetTopology {
            edge_nodes: 1,
            workers: 1,
            threads: default_threads(),
            aggregator: AggregatorPolicy::PassThrough,
        }
    }
}

/// Fleet lifecycle parameters: one shared [`PipelineConfig`] template
/// per tier, composed rather than flattened — the edge tier's
/// `transport` is the hop-A uplink, the aggregator tier's `transport`
/// is the hop-B link and its `seed` drives hop-B loss/jitter.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Edge-tier template; its `transport` is the hop-A uplink.
    pub edge: PipelineConfig,
    /// Aggregator-tier template; its `transport` is the hop-B link.
    pub aggregator: PipelineConfig,
    /// Backend-budget split across queries inside each edge node.
    pub edge_arbiter: ArbiterPolicy,
    /// Node/worker/thread counts and the aggregator policy.
    pub topology: FleetTopology,
}

impl FleetConfig {
    /// Both tiers from one template: the aggregator inherits the edge
    /// tier's knobs with a decorrelated seed (so the two hops' link
    /// RNGs never share a stream).
    pub fn uniform(tier: PipelineConfig, topology: FleetTopology) -> FleetConfig {
        let mut aggregator = tier.clone();
        aggregator.seed = tier.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xA66);
        FleetConfig {
            edge: tier,
            aggregator,
            edge_arbiter: ArbiterPolicy::WeightedFair { work_conserving: true },
            topology,
        }
    }
}

/// Edge node seed derivation: node 0 keeps the base seed (so a 1-node
/// fleet bit-matches `run_multi_sim` under the same seed); later nodes
/// decorrelate golden-ratio style like
/// [`multi_backend_seed`](super::multi::multi_backend_seed) and the
/// sharded-sim per-camera seeds.
pub fn fleet_node_seed(base: u64, node: usize) -> u64 {
    base.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(node as u64))
}

/// What happened to one edge dispatch at the aggregator tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetOutcome {
    /// Pass-through aggregator: forwarded without re-arbitration.
    Forwarded,
    /// Completed on cluster worker `worker`.
    Completed { worker: usize },
    /// Shed by the aggregator's deadline-capacity check.
    AggregatorShed,
    /// Lost on the hop-B (aggregator→cluster) link.
    ClusterLinkDrop,
}

/// One row of the fleet decision log: the tier-2 outcome of an edge
/// dispatch, in the aggregator's deterministic replay order. Same seed
/// ⇒ same log, byte for byte, regardless of `threads`.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetDecision {
    /// Edge node that dispatched the frame.
    pub node: usize,
    /// Query index inside the node's multi-query run.
    pub query: usize,
    /// Source camera id.
    pub camera: u32,
    /// Capture timestamp (virtual ms).
    pub capture_ms: f64,
    /// Tier-2 outcome at the aggregator.
    pub outcome: FleetOutcome,
}

/// One query's fleet-wide slice: the merged per-node report with
/// aggregator-tier corrections applied, plus the tier-2 counters.
pub struct FleetQueryReport {
    /// Query name (from the query config's color spec).
    pub name: String,
    /// Merged edge-tier report. QoR carries the aggregator demotions;
    /// under [`AggregatorPolicy::DeadlineCapacity`] the latency
    /// trackers are rebuilt from cluster completions (the edge-tier
    /// counters `ingress`/`transmitted`/`shed`/`link_dropped` keep
    /// their tier-1 meaning: `transmitted` is edge egress).
    pub report: PipelineReport,
    /// Frames this query completed on the backend cluster.
    pub completed: u64,
    /// Frames shed by the aggregator's second-level deadline check.
    pub agg_shed: u64,
    /// Frames lost on the hop-B link.
    pub agg_link_dropped: u64,
}

impl FleetQueryReport {
    /// Cross-tier conservation for this query (see the module docs).
    pub fn conserves(&self) -> bool {
        let r = &self.report;
        r.ingress
            == self.completed
                + r.shed
                + self.agg_shed
                + r.link_dropped
                + self.agg_link_dropped
                + r.faults.fault_dropped
    }
}

/// What a fleet run reports: per-query fleet-wide views, per-node
/// edge-tier reports, the fleet decision log, and both hops' physical
/// wire accounting.
pub struct FleetReport {
    /// Per-query fleet-wide views (query order = config order).
    pub queries: Vec<FleetQueryReport>,
    /// Tier-1 outputs, untouched (node order = camera order).
    pub nodes: Vec<MultiPipelineReport>,
    /// Tier-2 outcome log in deterministic replay order.
    pub decisions: Vec<FleetDecision>,
    /// Physical frames ingested across all edge nodes.
    pub frames: u64,
    /// Feature extractions across all edge nodes (one per frame).
    pub extractions: u64,
    /// Hop-A (edge→aggregator) physical frames, summed over nodes.
    pub uplink_frames: u64,
    /// Hop-A bytes on the wire, summed over nodes.
    pub uplink_bytes: u64,
    /// Hop-A frames lost to link faults/loss, summed over nodes.
    pub uplink_lost_frames: u64,
    /// Hop-B (aggregator→cluster) physical frames (zero under
    /// [`AggregatorPolicy::PassThrough`]).
    pub cluster_frames: u64,
    /// Hop-B bytes on the wire.
    pub cluster_bytes: u64,
    /// Hop-B frames lost to link faults/loss.
    pub cluster_lost_frames: u64,
    /// Frames completed per cluster worker (load-balance visibility;
    /// empty under [`AggregatorPolicy::PassThrough`]).
    pub worker_frames: Vec<u64>,
    /// Latest event timestamp across the fleet (virtual ms).
    pub end_ms: f64,
}

impl FleetReport {
    /// Merge the per-query fleet reports into one aggregate view
    /// through the existing metrics merge (per-query counts sum).
    pub fn aggregate(&self) -> Option<PipelineReport> {
        merge_reports(self.queries.iter().map(|q| &q.report))
    }

    /// Mean per-query fleet QoR (the sweep headline).
    pub fn qor_mean(&self) -> f64 {
        if self.queries.is_empty() {
            return 1.0;
        }
        let sum: f64 = self.queries.iter().map(|q| q.report.qor.overall()).sum();
        sum / self.queries.len() as f64
    }

    /// Cross-tier conservation across every query.
    pub fn conserves(&self) -> bool {
        self.queries.iter().all(FleetQueryReport::conserves)
    }
}

/// One recorded edge dispatch: the aggregator's view of a (query,
/// frame) pair leaving an edge node.
struct EdgeDispatch {
    query: usize,
    camera: u32,
    capture_ms: f64,
    ids: Vec<u64>,
    /// Cluster service demand: the edge's calibrated cost draw.
    exec_ms: f64,
    /// When the frame is available at the aggregator: the edge
    /// dispatch time, or the hop-A delivery time under a modeled
    /// uplink (whichever is later).
    egress_ms: f64,
    /// Physical wire size for the hop-B crossing: the hop-A encoded
    /// size when the uplink is modeled, the raw wire size otherwise.
    bytes: u64,
}

/// The tier-1 tap: records every dispatch, observes nothing back.
struct RecordingObserver {
    records: Vec<EdgeDispatch>,
}

impl DispatchObserver for RecordingObserver {
    fn on_dispatch(
        &mut self,
        query: usize,
        dispatch_ms: f64,
        frame: &FramePayload,
        ids: &[u64],
        exec_ms: f64,
        _dnn: bool,
        transit: Option<&Transmission>,
        _done_ms: f64,
    ) {
        let (egress_ms, bytes) = match transit {
            Some(tx) => (dispatch_ms.max(tx.arrival_ms), tx.bytes),
            None => (dispatch_ms, raw_wire_size(frame.width, frame.height) as u64),
        };
        self.records.push(EdgeDispatch {
            query,
            camera: frame.camera,
            capture_ms: frame.capture_ms,
            ids: ids.to_vec(),
            exec_ms,
            egress_ms,
            bytes,
        });
    }
}

/// A node-tagged record in the aggregator's replay order.
struct TaggedDispatch {
    node: usize,
    /// Record index within the node (engine event order): the
    /// deterministic tiebreak inside one node.
    idx: usize,
    rec: EdgeDispatch,
}

/// Contiguous camera partition: `parts` ranges over `0..n`, first
/// `n % parts` ranges one longer.
fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run the two-tier fleet over `videos` (camera order = partition
/// order) for the query set. `set` is shared by every node —
/// fleet-wide training, node-local shedding.
pub fn run_fleet(videos: &[Video], set: &QuerySet, cfg: &FleetConfig) -> Result<FleetReport> {
    let e = cfg.topology.edge_nodes;
    if videos.is_empty() {
        bail!("run_fleet needs at least one camera");
    }
    if e == 0 || e > videos.len() {
        bail!("edge_nodes must be in 1..={} (got {e})", videos.len());
    }
    if set.is_empty() {
        bail!("query set is empty");
    }
    let m = cfg.topology.workers;
    if cfg.topology.aggregator == AggregatorPolicy::DeadlineCapacity && m == 0 {
        bail!("DeadlineCapacity aggregator needs at least one worker");
    }

    // --- Tier 1: every edge node is an independent multi-query run
    // over its camera slice, recorded through the dispatch tap.
    let parts = partition(videos.len(), e);
    let node_results = parallel_map(
        &parts,
        cfg.topology.threads.max(1),
        |node, range| -> Result<(MultiPipelineReport, Vec<EdgeDispatch>)> {
            let node_videos = &videos[range.clone()];
            let mut tier = cfg.edge.clone();
            tier.seed = fleet_node_seed(cfg.edge.seed, node);
            tier.fps_total = aggregate_fps(node_videos);
            let node_cfg = MultiSimConfig::from_pipeline(&tier, cfg.edge_arbiter);
            let extractor = Extractor::native(set.union_model().clone());
            let mut backends = multi_backends(set, &node_cfg.costs, node_cfg.seed);
            let mut executor = MultiSyncBackend::new(&mut backends);
            let mut observer = RecordingObserver { records: Vec::new() };
            let report = run_multi_pipeline_observed(
                IterArrivals::new(Streamer::new(node_videos), node_cfg.fps_total),
                &backgrounds_of(node_videos),
                set,
                &node_cfg,
                &extractor,
                &mut executor,
                &mut SimClock,
                &mut observer,
            )?;
            Ok((report, observer.records))
        },
    );

    let mut nodes = Vec::with_capacity(e);
    let mut records: Vec<TaggedDispatch> = Vec::new();
    for (node, res) in node_results.into_iter().enumerate() {
        let (report, recs) = res?;
        records.extend(
            recs.into_iter()
                .enumerate()
                .map(|(idx, rec)| TaggedDispatch { node, idx, rec }),
        );
        nodes.push(report);
    }
    // The aggregator's replay order: arrival time, then node, then the
    // node's own event order — a deterministic total order independent
    // of `threads`.
    records.sort_by(|a, b| {
        a.rec
            .egress_ms
            .total_cmp(&b.rec.egress_ms)
            .then(a.node.cmp(&b.node))
            .then(a.idx.cmp(&b.idx))
    });

    // --- Per-query fleet base: the existing metrics merge over nodes.
    let k = set.len();
    let mut merged: Vec<PipelineReport> = Vec::with_capacity(k);
    for q in 0..k {
        merged.push(
            merge_reports(nodes.iter().map(|n| &n.queries[q].report))
                .ok_or_else(|| anyhow!("fleet has at least one node"))?,
        );
    }

    // --- Tier 2: replay the merged dispatch stream through the
    // aggregator policy.
    let mut decisions = Vec::with_capacity(records.len());
    let mut completed = vec![0u64; k];
    let mut agg_shed = vec![0u64; k];
    let mut agg_lost = vec![0u64; k];
    let mut cluster_frames = 0u64;
    let mut cluster_bytes = 0u64;
    let mut cluster_lost = 0u64;
    let mut worker_frames = Vec::new();
    let mut end_ms = nodes.iter().fold(0.0f64, |acc, n| acc.max(n.end_ms));

    match cfg.topology.aggregator {
        AggregatorPolicy::PassThrough => {
            for t in &records {
                completed[t.rec.query] += 1;
                decisions.push(FleetDecision {
                    node: t.node,
                    query: t.rec.query,
                    camera: t.rec.camera,
                    capture_ms: t.rec.capture_ms,
                    outcome: FleetOutcome::Forwarded,
                });
            }
        }
        AggregatorPolicy::DeadlineCapacity => {
            let mut link = Link::new(cfg.aggregator.transport.link, cfg.aggregator.seed);
            // One hop-B crossing per physical frame: query copies of
            // the same (node, camera, capture) share the transmission,
            // exactly like the edge tier's shared link.
            let mut phys: HashMap<(usize, u32, u64), Transmission> = HashMap::new();
            let mut busy = vec![0.0f64; m];
            worker_frames = vec![0u64; m];
            let mut latency: Vec<LatencyTracker> = set
                .queries()
                .iter()
                .map(|q| LatencyTracker::new(q.config.latency_bound_ms))
                .collect();
            let mut latency_windows: Vec<WindowSeries> =
                (0..k).map(|_| WindowSeries::new(5_000.0)).collect();

            for t in &records {
                let rec = &t.rec;
                let q = rec.query;
                let key = (t.node, rec.camera, rec.capture_ms.to_bits());
                let tx = match phys.get(&key) {
                    Some(tx) => *tx,
                    None => {
                        let tx = link.transmit_at(rec.egress_ms, rec.bytes, None);
                        cluster_frames += 1;
                        cluster_bytes += rec.bytes;
                        if !tx.delivered {
                            cluster_lost += 1;
                        }
                        phys.insert(key, tx);
                        tx
                    }
                };
                if !tx.delivered {
                    agg_lost[q] += 1;
                    merged[q].qor.demote(&rec.ids);
                    decisions.push(FleetDecision {
                        node: t.node,
                        query: q,
                        camera: rec.camera,
                        capture_ms: rec.capture_ms,
                        outcome: FleetOutcome::ClusterLinkDrop,
                    });
                    continue;
                }
                // Least-busy worker, lowest index on ties.
                let (w, w_busy) = busy.iter().enumerate().fold(
                    (0usize, f64::INFINITY),
                    |(bi, bv), (i, &v)| if v < bv { (i, v) } else { (bi, bv) },
                );
                let done = tx.arrival_ms.max(w_busy) + rec.exec_ms;
                let bound = set.queries()[q].config.latency_bound_ms;
                if done - rec.capture_ms > bound {
                    agg_shed[q] += 1;
                    merged[q].qor.demote(&rec.ids);
                    decisions.push(FleetDecision {
                        node: t.node,
                        query: q,
                        camera: rec.camera,
                        capture_ms: rec.capture_ms,
                        outcome: FleetOutcome::AggregatorShed,
                    });
                    continue;
                }
                busy[w] = done;
                worker_frames[w] += 1;
                completed[q] += 1;
                let e2e = done - rec.capture_ms;
                latency[q].observe(e2e);
                latency_windows[q].observe(rec.capture_ms, e2e);
                end_ms = end_ms.max(done);
                decisions.push(FleetDecision {
                    node: t.node,
                    query: q,
                    camera: rec.camera,
                    capture_ms: rec.capture_ms,
                    outcome: FleetOutcome::Completed { worker: w },
                });
            }
            // The fleet latency is the cluster's, not the edge
            // estimate: swap the rebuilt trackers in.
            for (r, (lat, win)) in merged
                .iter_mut()
                .zip(latency.into_iter().zip(latency_windows))
            {
                r.latency = lat;
                r.latency_windows = win;
            }
        }
    }

    let queries = set
        .queries()
        .iter()
        .zip(merged)
        .enumerate()
        .map(|(q, (cq, report))| FleetQueryReport {
            name: cq.name.clone(),
            report,
            completed: completed[q],
            agg_shed: agg_shed[q],
            agg_link_dropped: agg_lost[q],
        })
        .collect();

    Ok(FleetReport {
        queries,
        frames: nodes.iter().map(|n| n.frames).sum(),
        extractions: nodes.iter().map(|n| n.extractions).sum(),
        uplink_frames: nodes.iter().map(|n| n.wire_frames).sum(),
        uplink_bytes: nodes.iter().map(|n| n.bytes_on_wire).sum(),
        uplink_lost_frames: nodes.iter().map(|n| n.link_lost_frames).sum(),
        cluster_frames,
        cluster_bytes,
        cluster_lost_frames: cluster_lost,
        worker_frames,
        nodes,
        decisions,
        end_ms,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::config::QueryConfig;
    use crate::pipeline::transport::{LinkModel, TransportConfig};
    use crate::shedder::QuerySpec;
    use crate::video::wire::WireEncoding;
    use crate::video::VideoConfig;

    fn cameras(n: usize, frames: usize) -> Vec<Video> {
        (0..n)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 0xF1EE7 + i as u64, i as u32, frames);
                vc.traffic.vehicle_rate = 0.35;
                Video::new(vc)
            })
            .collect()
    }

    fn trained_set(videos: &[Video]) -> QuerySet {
        let specs = vec![
            QuerySpec::new("red", QueryConfig::single(NamedColor::Red)),
            QuerySpec::new("yellow", QueryConfig::single(NamedColor::Yellow)),
        ];
        let idx: Vec<usize> = (0..videos.len()).collect();
        QuerySet::train(&specs, videos, &idx).unwrap()
    }

    fn base_cfg(topology: FleetTopology) -> FleetConfig {
        let tier = PipelineConfig { seed: 0xF1EE7, ..PipelineConfig::default() };
        FleetConfig::uniform(tier, topology)
    }

    #[test]
    fn pass_through_fleet_conserves_and_is_thread_invariant() {
        let videos = cameras(4, 80);
        let set = trained_set(&videos);
        let mk = |threads| {
            base_cfg(FleetTopology {
                edge_nodes: 2,
                workers: 1,
                threads,
                aggregator: AggregatorPolicy::PassThrough,
            })
        };
        let serial = run_fleet(&videos, &set, &mk(1)).unwrap();
        let parallel = run_fleet(&videos, &set, &mk(4)).unwrap();
        assert_eq!(serial.frames, 4 * 80);
        assert!(serial.conserves());
        assert_eq!(serial.decisions, parallel.decisions);
        assert_eq!(serial.uplink_bytes, parallel.uplink_bytes);
        for (a, b) in serial.queries.iter().zip(&parallel.queries) {
            assert_eq!(a.report.decisions, b.report.decisions);
            assert_eq!(a.report.qor.overall(), b.report.qor.overall());
            assert_eq!(a.completed, a.report.transmitted);
        }
        // Pass-through adds no second hop.
        assert_eq!(serial.cluster_frames, 0);
        assert!(serial.worker_frames.is_empty());
    }

    #[test]
    fn deadline_capacity_sheds_when_the_cluster_is_small() {
        let videos = cameras(6, 80);
        let set = trained_set(&videos);
        let mut cfg = base_cfg(FleetTopology {
            edge_nodes: 3,
            workers: 1,
            threads: 2,
            aggregator: AggregatorPolicy::DeadlineCapacity,
        });
        // A thin, lossy hop-B link: some frames miss their deadline or
        // die on the wire, and conservation must still be exact.
        cfg.aggregator.transport = TransportConfig {
            link: LinkModel { loss: 0.05, max_retransmits: 0, ..LinkModel::mbps(4.0) },
            encoding: WireEncoding::Raw,
        };
        let r = run_fleet(&videos, &set, &cfg).unwrap();
        assert!(r.conserves(), "cross-tier conservation");
        assert_eq!(r.worker_frames.len(), 1);
        let total_agg: u64 = r.queries.iter().map(|q| q.agg_shed + q.agg_link_dropped).sum();
        assert!(total_agg > 0, "one worker behind a thin link must shed");
        let completed: u64 = r.queries.iter().map(|q| q.completed).sum();
        assert_eq!(completed, r.worker_frames.iter().sum::<u64>());
        assert!(r.cluster_frames > 0 && r.cluster_bytes > 0);
        // Deterministic replay: same seed, same log.
        let again = run_fleet(&videos, &set, &cfg).unwrap();
        assert_eq!(r.decisions, again.decisions);
    }

    #[test]
    fn worker_scaling_reduces_aggregator_sheds() {
        let videos = cameras(6, 80);
        let set = trained_set(&videos);
        let mk = |workers| {
            base_cfg(FleetTopology {
                edge_nodes: 3,
                workers,
                threads: 2,
                aggregator: AggregatorPolicy::DeadlineCapacity,
            })
        };
        let one = run_fleet(&videos, &set, &mk(1)).unwrap();
        let many = run_fleet(&videos, &set, &mk(8)).unwrap();
        let sheds = |r: &FleetReport| -> u64 { r.queries.iter().map(|q| q.agg_shed).sum() };
        assert!(
            sheds(&many) <= sheds(&one),
            "more workers cannot shed more ({} vs {})",
            sheds(&many),
            sheds(&one)
        );
        assert!(many.conserves() && one.conserves());
    }

    #[test]
    fn bad_topologies_are_rejected() {
        let videos = cameras(2, 10);
        let set = trained_set(&videos);
        let zero_nodes = base_cfg(FleetTopology { edge_nodes: 0, ..FleetTopology::default() });
        assert!(run_fleet(&videos, &set, &zero_nodes).is_err());
        let too_many = base_cfg(FleetTopology { edge_nodes: 3, ..FleetTopology::default() });
        assert!(run_fleet(&videos, &set, &too_many).is_err());
        let no_workers = base_cfg(FleetTopology {
            edge_nodes: 1,
            workers: 0,
            threads: 1,
            aggregator: AggregatorPolicy::DeadlineCapacity,
        });
        assert!(run_fleet(&videos, &set, &no_workers).is_err());
    }

    #[test]
    fn partition_is_contiguous_and_covers() {
        assert_eq!(partition(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(partition(4, 4), vec![0..1, 1..2, 2..3, 3..4]);
        assert_eq!(partition(5, 1), vec![0..5]);
    }
}
