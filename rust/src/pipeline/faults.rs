//! Deterministic fault injection for the streaming core.
//!
//! A [`FaultPlan`] schedules **virtual-time fault windows** — camera
//! dropout/freeze, link blackout and bandwidth collapse (layered on
//! [`crate::pipeline::transport`]), backend-worker crash and straggler
//! slowdown, and poisoned control observations — that the lifecycle
//! engines ([`crate::pipeline::core`], [`crate::pipeline::multi`])
//! consult at event times. Because every query is keyed on virtual time
//! and the engines process events strictly in virtual-time order under
//! every [`crate::pipeline::Clock`], an injected fault fires identically
//! under `SimClock` and `WallClock`.
//!
//! The **empty plan is the verification mode**: every query
//! short-circuits on `windows.is_empty()`, so a pipeline run with
//! `FaultPlan::default()` performs zero extra RNG draws, zero extra EWMA
//! updates and no code-path changes — bit-identical to a faultless
//! build, pinned by `rust/tests/faults.rs` (the same standard
//! `LinkModel::ideal()` sets for the transport layer).
//!
//! Frame accounting: frames destroyed *by a fault* (camera dropout,
//! link blackout, in-flight loss to a crashed worker) count as
//! `fault_dropped`, extending the conservation invariant to
//! `ingress == transmitted + shed + link_dropped + fault_dropped`.

use crate::util::rng::Rng;

/// What a poisoned control observation looks like on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonKind {
    /// The observation arrives as NaN (a corrupted measurement).
    Nan,
    /// The observation arrives as a negative duration (a stale /
    /// clock-skewed timestamp pair).
    Stale,
}

/// One fault mode, active over a window's `[start_ms, end_ms)` span.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Camera `camera` emits nothing: captured frames never leave the
    /// device (counted as `fault_dropped` at their capture time).
    CameraDrop { camera: u32 },
    /// Camera `camera` keeps streaming its last pre-window frame: stale
    /// pixels, live ground truth (the scene moves on).
    CameraFreeze { camera: u32 },
    /// The shedder→backend link delivers nothing: frames dispatched
    /// during the window are lost (counted as `fault_dropped`).
    LinkBlackout,
    /// The shedder→backend link's bandwidth collapses to `mbps` —
    /// frames still flow, slowly, through the modeled link.
    BandwidthCollapse { mbps: f64 },
    /// The backend worker is down: frames dispatched during the window
    /// occupy a backend token until the window ends (the supervised
    /// restart discovering the lost in-flight work), then count as
    /// `fault_dropped`.
    WorkerCrash,
    /// Backend execution takes `factor`× as long (a straggler).
    BackendSlowdown { factor: f64 },
    /// Backend-time observations fed to the control loop are poisoned;
    /// the loop's input validation must reject them.
    PoisonControl { kind: PoisonKind },
}

/// A half-open virtual-time window `[start_ms, end_ms)` of one fault.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultWindow {
    /// Window start (virtual ms, inclusive).
    pub start_ms: f64,
    /// Window end (virtual ms, exclusive).
    pub end_ms: f64,
    /// The fault active inside the window.
    pub kind: FaultKind,
}

impl FaultWindow {
    /// Is virtual time `t` inside this window?
    pub fn covers(&self, t: f64) -> bool {
        t >= self.start_ms && t < self.end_ms
    }
}

/// A schedule of fault windows. `FaultPlan::default()` is the empty
/// plan — the verification mode, bit-identical to a faultless pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    windows: Vec<FaultWindow>,
    has_freeze: bool,
}

impl FaultPlan {
    /// The empty plan (same as `FaultPlan::default()`).
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Builder: add a fault window. Windows may overlap freely.
    pub fn with(mut self, start_ms: f64, end_ms: f64, kind: FaultKind) -> Self {
        self.push(start_ms, end_ms, kind);
        self
    }

    /// Add a fault window in place.
    pub fn push(&mut self, start_ms: f64, end_ms: f64, kind: FaultKind) {
        debug_assert!(
            start_ms.is_finite() && end_ms.is_finite() && start_ms <= end_ms,
            "fault window must be finite and ordered: [{start_ms}, {end_ms})"
        );
        if matches!(kind, FaultKind::CameraFreeze { .. }) {
            self.has_freeze = true;
        }
        self.windows.push(FaultWindow { start_ms, end_ms, kind });
    }

    /// True when no fault windows are scheduled.
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// All scheduled windows, in insertion order.
    pub fn windows(&self) -> &[FaultWindow] {
        &self.windows
    }

    /// Is camera `camera` in a dropout window at `t`?
    pub fn camera_dropped(&self, camera: u32, t: f64) -> bool {
        if self.windows.is_empty() {
            return false;
        }
        self.windows.iter().any(|w| {
            matches!(w.kind, FaultKind::CameraDrop { camera: c } if c == camera) && w.covers(t)
        })
    }

    /// Is camera `camera` in a freeze window at `t`?
    pub fn camera_frozen(&self, camera: u32, t: f64) -> bool {
        if !self.has_freeze {
            return false;
        }
        self.windows.iter().any(|w| {
            matches!(w.kind, FaultKind::CameraFreeze { camera: c } if c == camera) && w.covers(t)
        })
    }

    /// Does the plan contain any freeze window at all? Gates the
    /// last-frame retention buffer so the empty plan clones nothing.
    pub fn has_camera_freeze(&self) -> bool {
        self.has_freeze
    }

    /// Is the link blacked out at `t`?
    pub fn link_blackout(&self, t: f64) -> bool {
        if self.windows.is_empty() {
            return false;
        }
        self.windows
            .iter()
            .any(|w| matches!(w.kind, FaultKind::LinkBlackout) && w.covers(t))
    }

    /// Collapsed link bandwidth at `t` (the tightest covering window),
    /// or `None` outside every collapse window.
    pub fn bandwidth_override(&self, t: f64) -> Option<f64> {
        if self.windows.is_empty() {
            return None;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                FaultKind::BandwidthCollapse { mbps } if w.covers(t) => Some(mbps),
                _ => None,
            })
            .min_by(|a, b| a.total_cmp(b))
    }

    /// If the backend worker is crashed at `t`, when does it recover
    /// (the latest covering crash window's end)?
    pub fn worker_down_until(&self, t: f64) -> Option<f64> {
        if self.windows.is_empty() {
            return None;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                FaultKind::WorkerCrash if w.covers(t) => Some(w.end_ms),
                _ => None,
            })
            .max_by(|a, b| a.total_cmp(b))
    }

    /// Backend-execution slowdown factor at `t` (1.0 outside every
    /// slowdown window; the worst covering window wins).
    pub fn slowdown(&self, t: f64) -> f64 {
        if self.windows.is_empty() {
            return 1.0;
        }
        self.windows
            .iter()
            .filter_map(|w| match w.kind {
                FaultKind::BackendSlowdown { factor } if w.covers(t) => Some(factor),
                _ => None,
            })
            .max_by(|a, b| a.total_cmp(b))
            .unwrap_or(1.0)
    }

    /// Poison mode for control observations recorded at `t`, if any.
    pub fn poison(&self, t: f64) -> Option<PoisonKind> {
        if self.windows.is_empty() {
            return None;
        }
        self.windows.iter().find_map(|w| match w.kind {
            FaultKind::PoisonControl { kind } if w.covers(t) => Some(kind),
            _ => None,
        })
    }

    /// A seeded random fault storm over `[0, horizon_ms)` across
    /// `cameras` cameras: 3–6 windows of uniformly-drawn kinds, each
    /// starting in `[0.1, 0.7]·horizon` and lasting
    /// `[0.05, 0.2]·horizon`. The chaos property test runs many of
    /// these; same seed → same plan.
    pub fn randomized(seed: u64, horizon_ms: f64, cameras: u32) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17);
        let mut plan = FaultPlan::new();
        let n = 3 + rng.below(4);
        for _ in 0..n {
            let start = rng.range_f64(0.1, 0.7) * horizon_ms;
            let dur = rng.range_f64(0.05, 0.2) * horizon_ms;
            let cam = rng.below(cameras.max(1) as u64) as u32;
            let kind = match rng.below(7) {
                0 => FaultKind::CameraDrop { camera: cam },
                1 => FaultKind::CameraFreeze { camera: cam },
                2 => FaultKind::LinkBlackout,
                3 => FaultKind::BandwidthCollapse { mbps: rng.range_f64(0.3, 3.0) },
                4 => FaultKind::WorkerCrash,
                5 => FaultKind::BackendSlowdown { factor: rng.range_f64(2.0, 6.0) },
                _ => FaultKind::PoisonControl {
                    kind: if rng.chance(0.5) { PoisonKind::Nan } else { PoisonKind::Stale },
                },
            };
            plan.push(start, start + dur, kind);
        }
        plan
    }

    /// A seeded chaos storm **composed with** a seeded content-drift
    /// schedule over the same horizon: infrastructure faults (this plan)
    /// and data drift ([`crate::video::DriftPlan`]) overlapping freely.
    /// Same seed → same pair; the chaos composition test in
    /// `rust/tests/drift.rs` drives both through the pipeline and
    /// asserts frame conservation plus termination.
    pub fn randomized_with_drift(
        seed: u64,
        horizon_ms: f64,
        cameras: u32,
    ) -> (FaultPlan, crate::video::DriftPlan) {
        (
            FaultPlan::randomized(seed, horizon_ms, cameras),
            crate::video::DriftPlan::randomized(seed, horizon_ms, cameras),
        )
    }
}

/// Fault / graceful-degradation counters carried on every pipeline
/// report. All zeros (and no windows) on a faultless run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// Frames destroyed by an injected fault: camera dropout, link
    /// blackout, or in-flight loss to a crashed worker. Extends frame
    /// conservation: `ingress == transmitted + shed + link_dropped +
    /// fault_dropped`.
    pub fault_dropped: u64,
    /// Control observations rejected by input validation (NaN /
    /// negative — see [`crate::shedder::ControlLoop`]).
    pub poisoned_rejected: u64,
    /// Declared degraded-mode spans `(enter_ms, exit_ms)`: the watchdog
    /// froze the threshold and shed everything until progress resumed.
    pub degraded_windows: Vec<(f64, f64)>,
    /// Frames shed *because* the pipeline was in degraded mode (a
    /// subset of the report's `shed` count).
    pub degraded_shed: u64,
    /// Times the per-camera liveness watchdog re-normalized the nominal
    /// fps after an unplanned camera dropout (or recovery).
    pub liveness_renorms: u64,
}

impl FaultStats {
    /// Merge another shard's counters into this one (sharded sweeps).
    pub fn merge(&mut self, other: &FaultStats) {
        self.fault_dropped += other.fault_dropped;
        self.poisoned_rejected += other.poisoned_rejected;
        self.degraded_shed += other.degraded_shed;
        self.liveness_renorms += other.liveness_renorms;
        self.degraded_windows.extend_from_slice(&other.degraded_windows);
        self.degraded_windows.sort_by(|a, b| a.0.total_cmp(&b.0));
    }

    /// Total declared degraded time (ms).
    pub fn degraded_ms(&self) -> f64 {
        self.degraded_windows.iter().map(|(s, e)| e - s).sum()
    }

    /// Was time `t` inside a declared degraded window?
    pub fn degraded_at(&self, t: f64) -> bool {
        self.degraded_windows.iter().any(|&(s, e)| t >= s && t < e)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_answers_no_everywhere() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(!p.camera_dropped(0, 1e5));
        assert!(!p.camera_frozen(3, 0.0));
        assert!(!p.link_blackout(500.0));
        assert_eq!(p.bandwidth_override(500.0), None);
        assert_eq!(p.worker_down_until(500.0), None);
        assert_eq!(p.slowdown(500.0), 1.0);
        assert_eq!(p.poison(500.0), None);
    }

    #[test]
    fn window_queries_are_half_open_and_kind_scoped() {
        let p = FaultPlan::new()
            .with(100.0, 200.0, FaultKind::CameraDrop { camera: 1 })
            .with(150.0, 300.0, FaultKind::LinkBlackout)
            .with(150.0, 300.0, FaultKind::BandwidthCollapse { mbps: 1.5 })
            .with(150.0, 300.0, FaultKind::BandwidthCollapse { mbps: 0.5 })
            .with(400.0, 500.0, FaultKind::WorkerCrash)
            .with(400.0, 500.0, FaultKind::BackendSlowdown { factor: 4.0 })
            .with(600.0, 700.0, FaultKind::PoisonControl { kind: PoisonKind::Nan });
        assert!(p.camera_dropped(1, 100.0));
        assert!(p.camera_dropped(1, 199.9));
        assert!(!p.camera_dropped(1, 200.0), "end is exclusive");
        assert!(!p.camera_dropped(2, 150.0), "per-camera scope");
        assert!(p.link_blackout(150.0));
        assert!(!p.link_blackout(149.9));
        // The tightest covering collapse wins.
        assert_eq!(p.bandwidth_override(200.0), Some(0.5));
        assert_eq!(p.worker_down_until(450.0), Some(500.0));
        assert_eq!(p.worker_down_until(399.0), None);
        assert_eq!(p.slowdown(450.0), 4.0);
        assert_eq!(p.slowdown(399.0), 1.0);
        assert_eq!(p.poison(650.0), Some(PoisonKind::Nan));
        assert!(!p.has_camera_freeze());
        let p = p.with(0.0, 10.0, FaultKind::CameraFreeze { camera: 0 });
        assert!(p.has_camera_freeze());
        assert!(p.camera_frozen(0, 5.0));
        assert!(!p.camera_frozen(1, 5.0));
    }

    #[test]
    fn randomized_with_drift_pairs_are_seeded_and_composable() {
        let (fa, da) = FaultPlan::randomized_with_drift(7, 10_000.0, 4);
        let (fb, db) = FaultPlan::randomized_with_drift(7, 10_000.0, 4);
        assert_eq!(fa, fb, "same seed, same fault storm");
        assert_eq!(da, db, "same seed, same drift schedule");
        assert!(!fa.is_empty() && !da.is_empty());
        let (fc, dc) = FaultPlan::randomized_with_drift(8, 10_000.0, 4);
        assert!(fa != fc || da != dc, "different seeds diverge");
        // The pair shares a horizon, so overlap between a fault window
        // and a drift window is possible (and with these seeds, actual);
        // the pipeline-level composition is exercised in tests/drift.rs.
        for w in da.windows() {
            assert!(w.start_ms >= 0.0 && w.end_ms <= 0.9 * 10_000.0 + 1e-9);
        }
    }

    #[test]
    fn randomized_plans_are_seeded_and_bounded() {
        let a = FaultPlan::randomized(7, 10_000.0, 4);
        let b = FaultPlan::randomized(7, 10_000.0, 4);
        assert_eq!(a, b, "same seed, same plan");
        let c = FaultPlan::randomized(8, 10_000.0, 4);
        assert_ne!(a, c, "different seeds diverge");
        assert!((3..=6).contains(&a.windows().len()));
        for w in a.windows() {
            assert!(w.start_ms >= 0.0 && w.end_ms <= 0.9 * 10_000.0 + 1e-9);
            assert!(w.end_ms > w.start_ms);
            if let FaultKind::CameraDrop { camera } | FaultKind::CameraFreeze { camera } = w.kind
            {
                assert!(camera < 4);
            }
        }
    }

    #[test]
    fn fault_stats_merge_sums_and_sorts_windows() {
        let mut a = FaultStats {
            fault_dropped: 3,
            poisoned_rejected: 1,
            degraded_windows: vec![(500.0, 700.0)],
            degraded_shed: 2,
            liveness_renorms: 1,
        };
        let b = FaultStats {
            fault_dropped: 4,
            poisoned_rejected: 0,
            degraded_windows: vec![(100.0, 200.0)],
            degraded_shed: 5,
            liveness_renorms: 0,
        };
        a.merge(&b);
        assert_eq!(a.fault_dropped, 7);
        assert_eq!(a.degraded_shed, 7);
        assert_eq!(a.degraded_windows, vec![(100.0, 200.0), (500.0, 700.0)]);
        assert_eq!(a.degraded_ms(), 300.0);
        assert!(a.degraded_at(150.0));
        assert!(!a.degraded_at(300.0));
    }
}
