//! Event-driven realtime engine over **real loopback sockets**: frames
//! leave the shedder as [`crate::video::wire`] messages on actual TCP or
//! Unix-domain connections, and the **measured** per-frame transfer time
//! — not a [`LinkModel`](crate::pipeline::transport::LinkModel) sample —
//! feeds [`ControlLoop::observe_network`](crate::shedder::ControlLoop),
//! so Eq. 19/20's queue sizing and dispatch deadline budget react to real
//! kernel/socket backpressure.
//!
//! Architecture: the module reuses [`run_pipeline`] — the one lifecycle
//! engine every driver shares — and confines all socket I/O to a new
//! [`BackendExecutor`]:
//!
//! ```text
//!   [driver: arrivals + extractor + Load Shedder + filter planner
//!            + reactor (epoll over W non-blocking connections)]
//!        │ wire-encoded frames (camera % W picks the connection) ▲ acks
//!        ▼                                                       │
//!   [worker 0..W: blocking read → WireDecoder → real detector
//!                 (DNN-bound frames) → (seq, recv_us) ack]
//! ```
//!
//! * **Reactor.** A small epoll loop (raw `epoll_create1`/`epoll_ctl`/
//!   `epoll_wait` FFI on Linux — no external crates; a degraded
//!   poll-all-and-sleep fallback elsewhere) multiplexes the W driver-side
//!   connections: it flushes pending envelope bytes when sockets are
//!   writable and drains 16-byte acks when they are readable. The driver
//!   blocks in the reactor only at completion rendezvous, bounded by
//!   `backend_recv_timeout_ms`.
//! * **Wire format.** Each frame ships as an envelope
//!   `[len u32][seq u64][dnn u8][camera u32]` followed by the
//!   [`WireEncoder`] message (raw or delta mode). Cameras are routed to
//!   connection `camera % W`, so every per-camera delta stream stays on
//!   one connection and the worker-side [`WireDecoder`] state matches.
//!   Decode is exact, so the detector sees bit-identical pixels.
//! * **Measurement.** Both ends timestamp against one shared
//!   monotonic epoch ([`std::time::Instant`] is `Copy` and crosses into
//!   the worker threads). `transfer = recv_us − send_us` spans enqueue,
//!   kernel socket buffering, transit and the worker's read — the honest
//!   backpressure signal. With `feed_network` on (the default) each
//!   sample enters the control loop at that frame's completion event via
//!   [`BackendExecutor::take_network_sample`].
//! * **Determinism.** With `feed_network` **off**, frames still cross
//!   the sockets and transfers are still measured/reported, but the
//!   control loop never sees them — exactly the ideal-link contract the
//!   modeled transport keeps. Decisions then bit-match the threaded
//!   [`WallClock`] driver (`run_realtime`) for the same seed and stream,
//!   pinned by `rust/tests/reactor_equivalence.rs`. With feed **on**,
//!   decisions may legitimately diverge: that is the point — the budget
//!   reacts to measured transfers, which are nondeterministic.
//!
//! Reactor mode **supersedes the modeled link**: it requires the ideal
//! [`TransportConfig`](crate::pipeline::transport::TransportConfig)
//! (configuring a bandwidth-modeled link alongside real sockets is an
//! error). Fault windows compose for free — dropout, blackout, crash and
//! slowdown act on the driver's virtual-time schedule before frames
//! reach a socket — except `BandwidthCollapse`, which falls back to the
//! modeled-link path for covered dispatches (the collapse *is* a model).
//!
//! Entry points: [`run_reactor`] / [`run_reactor_with`], or
//! `Pipeline::builder().realtime(opts).reactor(ropts).run(..)`.

use crate::backend::{BackendQuery, CostModel, Detector};
use crate::color::HueRanges;
use crate::features::Extractor;
use crate::metrics::Stage;
use crate::pipeline::core::{
    backgrounds_of, run_pipeline, ArrivalModel, BackendExecutor, FramePayload, PipelineReport,
    SimConfig, WallClock,
};
use crate::pipeline::realtime::RealtimeConfig;
use crate::pipeline::workloads::IterArrivals;
use crate::runtime::Engine;
use crate::util::stats::Summary;
use crate::utility::UtilityModel;
use crate::video::{Video, WireDecoder, WireEncoder, WireEncoding};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Envelope header: `[len u32][seq u64][dnn u8][camera u32]`.
const ENVELOPE_LEN: usize = 4 + 8 + 1 + 4;
/// Ack: `[seq u64][recv_us u64]`.
const ACK_LEN: usize = 8 + 8;

// ---------------------------------------------------------------------------
// Options / stats
// ---------------------------------------------------------------------------

/// Which kernel socket family carries the shedder→backend frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SocketKind {
    /// Loopback TCP (`127.0.0.1`, ephemeral port, `TCP_NODELAY`).
    Tcp,
    /// Unix-domain stream sockets under the system temp directory.
    Unix,
}

impl SocketKind {
    /// Human-readable name for reports and scenario tables.
    pub fn name(self) -> &'static str {
        match self {
            SocketKind::Tcp => "tcp",
            SocketKind::Unix => "uds",
        }
    }
}

/// Reactor-mode knobs — the argument of
/// `Pipeline::builder().realtime(opts).reactor(..)`.
#[derive(Debug, Clone)]
pub struct ReactorOpts {
    /// Socket family for the real shedder→backend hop.
    pub transport: SocketKind,
    /// Backend worker threads (one socket pair each; cameras are routed
    /// to connection `camera % workers`).
    pub workers: usize,
    /// Wire encoding for the frames on the socket ([`WireEncoding::Raw`]
    /// or delta mode — decode is exact either way).
    pub encoding: WireEncoding,
    /// Feed each frame's measured socket transfer to
    /// `ControlLoop::observe_network` at its completion event. Default
    /// `true`; turn off for the calibration/verification mode whose
    /// decisions bit-match the threaded driver (frames still cross the
    /// sockets and transfers are still measured and reported).
    pub feed_network: bool,
}

impl Default for ReactorOpts {
    /// Loopback TCP, two workers, raw encoding, measured-transfer
    /// feeding on.
    fn default() -> Self {
        ReactorOpts {
            transport: SocketKind::Tcp,
            workers: 2,
            encoding: WireEncoding::Raw,
            feed_network: true,
        }
    }
}

impl ReactorOpts {
    /// Builder-style: socket family.
    pub fn transport(mut self, v: SocketKind) -> Self {
        self.transport = v;
        self
    }

    /// Builder-style: backend worker / connection count (min 1).
    pub fn workers(mut self, v: usize) -> Self {
        self.workers = v.max(1);
        self
    }

    /// Builder-style: wire encoding on the socket.
    pub fn encoding(mut self, v: WireEncoding) -> Self {
        self.encoding = v;
        self
    }

    /// Builder-style: feed measured transfers to the control loop.
    pub fn feed_network(mut self, v: bool) -> Self {
        self.feed_network = v;
        self
    }
}

/// What actually crossed the kernel sockets during a reactor run.
/// Reported beside (never inside) the modeled-transport byte accounting
/// in [`PipelineReport`], which stays driver-invariant.
#[derive(Debug, Clone, Default)]
pub struct SocketStats {
    /// Socket family used ("tcp" / "uds").
    pub transport: &'static str,
    /// Backend worker threads (= connections).
    pub workers: usize,
    /// Frames serialized onto a socket (every transmitted frame).
    pub frames_sent: u64,
    /// Envelope + wire-message bytes handed to the kernel.
    pub bytes_sent: u64,
    /// Acks drained from the workers (one per frame at stream end).
    pub acks_received: u64,
    /// Measured transfers actually fed to `observe_network` (0 when
    /// `feed_network` is off).
    pub net_samples_fed: u64,
    /// Mean measured shedder→backend transfer (ms) across acked frames.
    pub transfer_ms_mean: f64,
    /// Worst measured transfer (ms).
    pub transfer_ms_max: f64,
    /// Wire messages per mode, summed over the per-camera encoders
    /// (indexed like `WireEncoder::mode_counts`).
    pub wire_modes: [u64; 4],
}

/// Results of a reactor-mode run: the shared lifecycle report plus what
/// the sockets measured.
pub struct ReactorReport {
    /// The full core-engine report (decisions, QoR, latency, stages,
    /// conservation counters) — same sink as every other driver.
    pub pipeline: PipelineReport,
    /// Wall-clock duration of the run.
    pub wall: Duration,
    /// Socket-side counters and measured-transfer summary.
    pub socket: SocketStats,
}

// ---------------------------------------------------------------------------
// Readiness poller: epoll on Linux, degraded poll-all fallback elsewhere
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    //! Minimal hand-written epoll FFI. The workspace builds offline with
    //! vendored stubs only, so the `libc` crate is unavailable — these
    //! four symbols resolve against the libc `std` already links.

    pub const EPOLL_CLOEXEC: i32 = 0x8_0000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;

    /// Kernel `struct epoll_event`. Packed on x86_64 only — the one
    /// architecture whose kernel ABI declares it `__attribute__((packed))`.
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32)
            -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

/// One readiness report: `(token, readable, writable)`.
type Readiness = (u64, bool, bool);

/// Readiness poller over the driver-side connections. On Linux this is a
/// real epoll instance; elsewhere a degraded fallback that reports every
/// registered fd ready after a short sleep (callers use non-blocking I/O
/// and tolerate spurious readiness).
struct Poller {
    #[cfg(target_os = "linux")]
    epfd: RawFd,
    #[cfg(not(target_os = "linux"))]
    tokens: Vec<u64>,
}

#[cfg(target_os = "linux")]
impl Poller {
    fn new() -> io::Result<Poller> {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd })
    }

    fn ctl(&mut self, op: i32, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        if unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` for read readiness under `token`.
    fn register(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, sys::EPOLLIN)
    }

    /// Add or drop write-readiness interest (read stays on).
    fn set_writable_interest(&mut self, fd: RawFd, token: u64, on: bool) -> io::Result<()> {
        let events = sys::EPOLLIN | if on { sys::EPOLLOUT } else { 0 };
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, events)
    }

    /// Wait up to `timeout` and append readiness reports to `out`.
    fn wait(&mut self, timeout: Duration, out: &mut Vec<Readiness>) -> io::Result<()> {
        let mut evs = [sys::EpollEvent { events: 0, data: 0 }; 32];
        let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        let n = loop {
            let n = unsafe {
                sys::epoll_wait(self.epfd, evs.as_mut_ptr(), evs.len() as i32, timeout_ms)
            };
            if n >= 0 {
                break n as usize;
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        };
        for ev in evs.iter().take(n) {
            // Copy out of the (possibly packed) struct by value.
            let events = ev.events;
            let data = ev.data;
            let err = events & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
            out.push((data, events & sys::EPOLLIN != 0 || err, events & sys::EPOLLOUT != 0 || err));
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    fn new() -> io::Result<Poller> {
        Ok(Poller { tokens: Vec::new() })
    }

    fn register(&mut self, _fd: RawFd, token: u64) -> io::Result<()> {
        if !self.tokens.contains(&token) {
            self.tokens.push(token);
        }
        Ok(())
    }

    fn set_writable_interest(&mut self, _fd: RawFd, _token: u64, _on: bool) -> io::Result<()> {
        Ok(())
    }

    /// Degraded poll: sleep briefly, then report every registered fd
    /// ready for both directions (non-blocking callers skip the
    /// spurious ones with `WouldBlock`).
    fn wait(&mut self, timeout: Duration, out: &mut Vec<Readiness>) -> io::Result<()> {
        if !timeout.is_zero() {
            std::thread::sleep(timeout.min(Duration::from_micros(500)));
        }
        out.extend(self.tokens.iter().map(|&t| (t, true, true)));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Socket plumbing
// ---------------------------------------------------------------------------

/// A connected stream of either family (both ends use the same type).
enum Sock {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Sock {
    fn raw_fd(&self) -> RawFd {
        match self {
            Sock::Tcp(s) => s.as_raw_fd(),
            Sock::Unix(s) => s.as_raw_fd(),
        }
    }

    fn set_nonblocking(&self, on: bool) -> io::Result<()> {
        match self {
            Sock::Tcp(s) => s.set_nonblocking(on),
            Sock::Unix(s) => s.set_nonblocking(on),
        }
    }

    fn shutdown_write(&self) {
        let _ = match self {
            Sock::Tcp(s) => s.shutdown(Shutdown::Write),
            Sock::Unix(s) => s.shutdown(Shutdown::Write),
        };
    }
}

impl Read for Sock {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Sock::Tcp(s) => s.read(buf),
            Sock::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Sock {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Sock::Tcp(s) => s.write(buf),
            Sock::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Sock::Tcp(s) => s.flush(),
            Sock::Unix(s) => s.flush(),
        }
    }
}

/// Monotonic counter making Unix socket paths unique within a process
/// (concurrent reactor runs in one test binary must not collide).
static UDS_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Open `workers` connected socket pairs of the requested family.
/// Loopback connect-then-accept is sequential-safe (the listener backlog
/// absorbs the connect); workers are interchangeable, so pairing order
/// is irrelevant.
fn socket_pairs(kind: SocketKind, workers: usize) -> Result<(Vec<Sock>, Vec<Sock>)> {
    let mut driver = Vec::with_capacity(workers);
    let mut worker = Vec::with_capacity(workers);
    match kind {
        SocketKind::Tcp => {
            let listener = TcpListener::bind(("127.0.0.1", 0))?;
            let addr = listener.local_addr()?;
            for _ in 0..workers {
                let c = TcpStream::connect(addr)?;
                let (s, _) = listener.accept()?;
                // Frames are latency-sensitive and self-contained; never
                // wait for a fuller segment.
                c.set_nodelay(true)?;
                s.set_nodelay(true)?;
                driver.push(Sock::Tcp(c));
                worker.push(Sock::Tcp(s));
            }
        }
        SocketKind::Unix => {
            let path = std::env::temp_dir().join(format!(
                "uals-reactor-{}-{}.sock",
                std::process::id(),
                UDS_COUNTER.fetch_add(1, Ordering::Relaxed),
            ));
            // A stale path from a crashed prior run would fail the bind.
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)?;
            for _ in 0..workers {
                let c = UnixStream::connect(&path)?;
                let (s, _) = listener.accept()?;
                driver.push(Sock::Unix(c));
                worker.push(Sock::Unix(s));
            }
            // All pairs are connected; the filesystem name is no longer
            // needed (the sockets live on).
            let _ = std::fs::remove_file(&path);
        }
    }
    for c in &driver {
        c.set_nonblocking(true)?;
    }
    Ok((driver, worker))
}

/// Read exactly `buf.len()` bytes from a blocking socket. `Ok(false)` on
/// a clean EOF at a message boundary (the driver hung up).
fn read_exact_or_eof(sock: &mut Sock, buf: &mut [u8]) -> io::Result<bool> {
    let mut n = 0;
    while n < buf.len() {
        match sock.read(&mut buf[n..]) {
            Ok(0) if n == 0 => return Ok(false),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-envelope",
                ))
            }
            Ok(k) => n += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Backend worker body: blocking envelope reads, exact wire decode, the
/// real detector for DNN-bound frames, then a `(seq, recv_us)` ack.
/// Returns when the driver shuts the connection down.
fn worker_loop(
    mut sock: Sock,
    bgs: Arc<HashMap<u32, Vec<f32>>>,
    ranges: Arc<Vec<HueRanges>>,
    use_artifacts: bool,
    delta_tile: Option<usize>,
    epoch: Instant,
) -> Result<()> {
    // The PJRT client is not `Send`: the detector must be built here, on
    // the worker thread (same rule as the threaded driver's factory).
    let detector = if use_artifacts {
        let engine = Engine::from_default_artifacts()?;
        Detector::artifact(&engine)?
    } else {
        Detector::native(12, 25.0)
    };
    let mut decoders: HashMap<u32, WireDecoder> = HashMap::new();
    let mut header = [0u8; ENVELOPE_LEN];
    let mut wire: Vec<u8> = Vec::new();
    let mut rgb: Vec<f32> = Vec::new();
    loop {
        if !read_exact_or_eof(&mut sock, &mut header)? {
            return Ok(()); // orderly shutdown
        }
        let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
        let seq = u64::from_le_bytes([
            header[4], header[5], header[6], header[7], header[8], header[9], header[10],
            header[11],
        ]);
        let dnn = header[12] != 0;
        let camera = u32::from_le_bytes([header[13], header[14], header[15], header[16]]);
        wire.resize(len, 0);
        if !read_exact_or_eof(&mut sock, &mut wire)? {
            bail!("connection closed between envelope header and body");
        }
        // The frame has fully crossed the socket: timestamp now, against
        // the epoch shared with the driver (one process, one monotonic
        // clock).
        let recv_us = epoch.elapsed().as_micros() as u64;
        let dec = decoders.entry(camera).or_insert_with(|| {
            let d = WireDecoder::new();
            match delta_tile {
                Some(t) => d.with_tile(t),
                None => d,
            }
        });
        let h = dec.decode_into(&wire, &mut rgb)?;
        if dnn {
            let bg = bgs
                .get(&h.camera)
                .ok_or_else(|| anyhow!("no background for camera {}", h.camera))?;
            let _ = detector.detect(&rgb, bg, h.width, h.height, &ranges)?;
        }
        let mut ack = [0u8; ACK_LEN];
        ack[..8].copy_from_slice(&seq.to_le_bytes());
        ack[8..].copy_from_slice(&recv_us.to_le_bytes());
        sock.write_all(&ack)?;
    }
}

// ---------------------------------------------------------------------------
// The reactor-side executor
// ---------------------------------------------------------------------------

/// Per-frame state between enqueue and ack.
struct Pending {
    net_cam_ls_ms: f64,
    send_us: u64,
}

/// One driver-side connection: a non-blocking socket plus its output
/// backlog and partially-parsed ack bytes.
struct Conn {
    sock: Sock,
    /// Unflushed envelope bytes (`pos..` is still to write).
    out: Vec<u8>,
    pos: usize,
    /// Whether EPOLLOUT interest is currently registered.
    want_write: bool,
    /// Ack bytes read but not yet complete (`< ACK_LEN`).
    ackbuf: Vec<u8>,
}

/// Reactor [`BackendExecutor`]: filter stages + cost sampling on the
/// driver thread (the exact sequence the simulator and the threaded
/// driver sample), every transmitted frame wire-encoded onto a real
/// socket, completion rendezvous via the epoll loop, and the measured
/// transfer surfaced to the core through
/// [`BackendExecutor::take_network_sample`].
pub struct ReactorBackend {
    planner: BackendQuery,
    encoding: WireEncoding,
    encoders: HashMap<u32, WireEncoder>,
    conns: Vec<Conn>,
    poller: Poller,
    workers: Vec<JoinHandle<Result<()>>>,
    epoch: Instant,
    submit_seq: u64,
    pending: HashMap<u64, Pending>,
    acks: HashMap<u64, u64>,
    /// Samples measured at `on_complete`, awaiting the core's
    /// `take_network_sample` pull (empty when `feed_network` is off).
    ready: HashMap<u64, (f64, f64)>,
    feed_network: bool,
    recv_timeout: Duration,
    transport: SocketKind,
    workers_n: usize,
    frames_sent: u64,
    bytes_sent: u64,
    acks_received: u64,
    net_samples_fed: u64,
    transfer: Summary,
    scratch: Vec<u8>,
    events: Vec<Readiness>,
}

impl ReactorBackend {
    /// Open the socket pairs, spawn the worker pool and register every
    /// driver-side connection with the poller.
    pub fn spawn(videos: &[Video], cfg: &RealtimeConfig, opts: &ReactorOpts) -> Result<Self> {
        let workers_n = opts.workers.max(1);
        let (driver_socks, worker_socks) = socket_pairs(opts.transport, workers_n)?;
        let bgs: Arc<HashMap<u32, Vec<f32>>> = Arc::new(
            videos
                .iter()
                .map(|v| (v.camera_id(), v.background().to_vec()))
                .collect(),
        );
        let ranges: Arc<Vec<HueRanges>> =
            Arc::new(cfg.query.colors.iter().map(|c| c.ranges()).collect());
        let epoch = Instant::now();
        let delta_tile = match opts.encoding {
            WireEncoding::Delta { tile, .. } => Some(tile),
            WireEncoding::Raw => None,
        };
        let use_artifacts = cfg.use_artifacts;
        let mut workers = Vec::with_capacity(workers_n);
        for (i, sock) in worker_socks.into_iter().enumerate() {
            let bgs = Arc::clone(&bgs);
            let ranges = Arc::clone(&ranges);
            let handle = std::thread::Builder::new()
                .name(format!("reactor-worker-{i}"))
                .spawn(move || worker_loop(sock, bgs, ranges, use_artifacts, delta_tile, epoch))
                .map_err(|e| anyhow!("failed to spawn reactor worker {i}: {e}"))?;
            workers.push(handle);
        }
        let mut poller = Poller::new().map_err(|e| anyhow!("poller setup failed: {e}"))?;
        let mut conns = Vec::with_capacity(workers_n);
        for (i, sock) in driver_socks.into_iter().enumerate() {
            poller
                .register(sock.raw_fd(), i as u64)
                .map_err(|e| anyhow!("poller register failed: {e}"))?;
            conns.push(Conn {
                sock,
                out: Vec::new(),
                pos: 0,
                want_write: false,
                ackbuf: Vec::new(),
            });
        }
        let planner = BackendQuery::new(
            cfg.query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), cfg.seed),
            25.0,
        );
        Ok(ReactorBackend {
            planner,
            encoding: opts.encoding,
            encoders: HashMap::new(),
            conns,
            poller,
            workers,
            epoch,
            submit_seq: 0,
            pending: HashMap::new(),
            acks: HashMap::new(),
            ready: HashMap::new(),
            feed_network: opts.feed_network,
            recv_timeout: Duration::from_secs_f64(
                (cfg.backend_recv_timeout_ms / 1e3).max(1e-3),
            ),
            transport: opts.transport,
            workers_n,
            frames_sent: 0,
            bytes_sent: 0,
            acks_received: 0,
            net_samples_fed: 0,
            transfer: Summary::new(),
            scratch: Vec::new(),
            events: Vec::new(),
        })
    }

    /// Socket-side counters for the run report.
    pub fn socket_stats(&self) -> SocketStats {
        let mut wire_modes = [0u64; 4];
        for enc in self.encoders.values() {
            for (acc, n) in wire_modes.iter_mut().zip(enc.mode_counts()) {
                *acc += n;
            }
        }
        SocketStats {
            transport: self.transport.name(),
            workers: self.workers_n,
            frames_sent: self.frames_sent,
            bytes_sent: self.bytes_sent,
            acks_received: self.acks_received,
            net_samples_fed: self.net_samples_fed,
            transfer_ms_mean: self.transfer.mean(),
            transfer_ms_max: if self.transfer.count() == 0 { 0.0 } else { self.transfer.max() },
            wire_modes,
        }
    }

    /// Try to flush connection `ci`'s output backlog; registers (or
    /// clears) write interest as the kernel buffer fills and drains.
    fn flush_conn(&mut self, ci: usize) -> Result<()> {
        let conn = &mut self.conns[ci];
        while conn.pos < conn.out.len() {
            match conn.sock.write(&conn.out[conn.pos..]) {
                Ok(0) => bail!("reactor connection {ci}: kernel accepted zero bytes"),
                Ok(n) => conn.pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow!("reactor connection {ci}: write failed: {e}")),
            }
        }
        let drained = conn.pos >= conn.out.len();
        if drained {
            conn.out.clear();
            conn.pos = 0;
        }
        if conn.want_write == drained {
            // Interest flips: blocked ⇒ wake on writable; drained ⇒ stop.
            conn.want_write = !drained;
            let fd = conn.sock.raw_fd();
            let on = conn.want_write;
            self.poller
                .set_writable_interest(fd, ci as u64, on)
                .map_err(|e| anyhow!("poller interest update failed: {e}"))?;
        }
        Ok(())
    }

    /// Drain every complete ack buffered on connection `ci` into the
    /// ledger. An EOF here means a worker died mid-run.
    fn drain_acks(&mut self, ci: usize) -> Result<()> {
        let mut buf = [0u8; 4096];
        loop {
            let conn = &mut self.conns[ci];
            match conn.sock.read(&mut buf) {
                Ok(0) => bail!(
                    "reactor worker {ci} closed its connection mid-run \
                     (it may have failed during startup — see the join error)"
                ),
                Ok(n) => conn.ackbuf.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow!("reactor connection {ci}: read failed: {e}")),
            }
        }
        let conn = &mut self.conns[ci];
        let whole = conn.ackbuf.len() / ACK_LEN * ACK_LEN;
        for ack in conn.ackbuf[..whole].chunks_exact(ACK_LEN) {
            let seq = u64::from_le_bytes([
                ack[0], ack[1], ack[2], ack[3], ack[4], ack[5], ack[6], ack[7],
            ]);
            let recv_us = u64::from_le_bytes([
                ack[8], ack[9], ack[10], ack[11], ack[12], ack[13], ack[14], ack[15],
            ]);
            self.acks.insert(seq, recv_us);
            self.acks_received += 1;
        }
        conn.ackbuf.drain(..whole);
        Ok(())
    }

    /// One reactor turn: flush pending output, wait up to `timeout` for
    /// readiness, service readable/writable connections.
    fn turn(&mut self, timeout: Duration) -> Result<()> {
        for ci in 0..self.conns.len() {
            if self.conns[ci].pos < self.conns[ci].out.len() {
                self.flush_conn(ci)?;
            }
        }
        self.events.clear();
        let mut events = std::mem::take(&mut self.events);
        let r = self.poller.wait(timeout, &mut events);
        // Reinstall the scratch buffer before error handling so a failed
        // wait doesn't leak its capacity.
        self.events = events;
        r.map_err(|e| anyhow!("poller wait failed: {e}"))?;
        let events = std::mem::take(&mut self.events);
        for &(token, readable, writable) in &events {
            let ci = token as usize;
            if ci >= self.conns.len() {
                continue;
            }
            if writable && self.conns[ci].pos < self.conns[ci].out.len() {
                self.flush_conn(ci)?;
            }
            if readable {
                self.drain_acks(ci)?;
            }
        }
        self.events = events;
        Ok(())
    }
}

impl BackendExecutor for ReactorBackend {
    fn submit(&mut self, payload: FramePayload, background: &[f32]) -> Result<(Stage, f64)> {
        let seq = self.submit_seq;
        self.submit_seq += 1;
        // Filter stages + cost sampling in dispatch order — the exact
        // RNG sequence the sim and threaded drivers draw, so decisions
        // stay bit-comparable.
        let r = self
            .planner
            .plan(&payload.rgb, background, payload.width, payload.height)?;
        let dnn = r.last_stage == Stage::Sink;
        let encoding = self.encoding;
        let enc = self
            .encoders
            .entry(payload.camera)
            .or_insert_with(|| WireEncoder::new(encoding));
        enc.encode_into(
            payload.camera,
            payload.width,
            payload.height,
            &payload.rgb,
            &mut self.scratch,
        );
        let ci = payload.camera as usize % self.conns.len();
        let conn = &mut self.conns[ci];
        conn.out.extend_from_slice(&(self.scratch.len() as u32).to_le_bytes());
        conn.out.extend_from_slice(&seq.to_le_bytes());
        conn.out.push(u8::from(dnn));
        conn.out.extend_from_slice(&payload.camera.to_le_bytes());
        conn.out.extend_from_slice(&self.scratch);
        // The transfer clock starts at enqueue: backlog the reactor has
        // not flushed yet is backpressure too.
        let send_us = self.epoch.elapsed().as_micros() as u64;
        self.pending
            .insert(seq, Pending { net_cam_ls_ms: payload.net_cam_ls_ms, send_us });
        self.frames_sent += 1;
        self.bytes_sent += (ENVELOPE_LEN + self.scratch.len()) as u64;
        // Opportunistic turn: start the bytes moving and harvest any
        // acks already buffered, without blocking.
        self.turn(Duration::ZERO)?;
        Ok((r.last_stage, r.exec_ms))
    }

    fn on_complete(&mut self, seq: u64, _dnn: bool) -> Result<()> {
        // Every transmitted frame crossed a socket, so every completion
        // rendezvouses with its ack (not just DNN-bound frames).
        let deadline = Instant::now() + self.recv_timeout;
        loop {
            if let Some(recv_us) = self.acks.remove(&seq) {
                let p = self
                    .pending
                    .remove(&seq)
                    .ok_or_else(|| anyhow!("ack for unknown dispatch seq {seq}"))?;
                let transfer_ms = recv_us.saturating_sub(p.send_us) as f64 / 1e3;
                self.transfer.add(transfer_ms);
                if self.feed_network {
                    self.ready.insert(seq, (p.net_cam_ls_ms, transfer_ms));
                }
                return Ok(());
            }
            if Instant::now() >= deadline {
                bail!(
                    "reactor backend unresponsive: no ack for frame {seq} within {:?} \
                     ({} of {} frames acked)",
                    self.recv_timeout,
                    self.acks_received,
                    self.frames_sent
                );
            }
            self.turn(Duration::from_millis(5))?;
        }
    }

    fn take_network_sample(&mut self, seq: u64) -> Option<(f64, f64)> {
        let s = self.ready.remove(&seq);
        if s.is_some() {
            self.net_samples_fed += 1;
        }
        s
    }

    fn finish(&mut self) -> Result<()> {
        // Every submit was acked (the core applies all completions before
        // finishing), so the only work left is an orderly hang-up.
        for conn in self.conns.drain(..) {
            conn.sock.shutdown_write();
        }
        let mut first_err = None;
        for (i, h) in self.workers.drain(..).enumerate() {
            let r = match h.join() {
                Ok(r) => r,
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".to_string());
                    Err(anyhow!("reactor worker {i} panicked: {msg}"))
                }
            };
            if let Err(e) = r {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for ReactorBackend {
    /// Error-path cleanup: hang up so blocked workers see EOF, then join
    /// them (results discarded — the run already failed). The success
    /// path drains both vectors in `finish`, making this a no-op.
    fn drop(&mut self) {
        for conn in self.conns.drain(..) {
            conn.sock.shutdown_write();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Run the multi-camera stream through the reactor-mode realtime
/// pipeline (frames over real loopback sockets; see the module docs).
pub fn run_reactor(
    videos: &[Video],
    model: &UtilityModel,
    cfg: &RealtimeConfig,
    opts: &ReactorOpts,
) -> Result<ReactorReport> {
    let fps_total = crate::video::streamer::aggregate_fps(videos);
    run_reactor_with(
        videos,
        model,
        cfg,
        opts,
        IterArrivals::new(crate::video::Streamer::new(videos), fps_total),
    )
}

/// [`run_reactor`] over any [`ArrivalModel`] workload (bursty Poisson
/// ingress, camera churn, …).
pub fn run_reactor_with<A: ArrivalModel>(
    videos: &[Video],
    model: &UtilityModel,
    cfg: &RealtimeConfig,
    opts: &ReactorOpts,
    arrivals: A,
) -> Result<ReactorReport> {
    if !cfg.transport.link.is_ideal() {
        bail!(
            "reactor mode replaces the modeled link with real sockets: \
             configure TransportConfig::default() (ideal link), not a \
             bandwidth-modeled one"
        );
    }
    let start = Instant::now();
    let core_cfg: SimConfig = cfg.pipeline(arrivals.fps_total()).into();

    let extractor = if cfg.use_artifacts {
        let engine = Engine::from_default_artifacts()?;
        Extractor::artifact(&engine, model.clone())?
    } else {
        Extractor::native(model.clone())
    };

    let backgrounds = backgrounds_of(videos);
    let mut executor = ReactorBackend::spawn(videos, cfg, opts)?;
    let mut clock =
        WallClock::new(cfg.time_scale).with_completion_pacing(cfg.cost_emulation_scale > 0.0);
    let report = run_pipeline(
        arrivals,
        &backgrounds,
        &core_cfg,
        &extractor,
        &mut executor,
        &mut clock,
    )?;
    Ok(ReactorReport {
        pipeline: report,
        wall: start.elapsed(),
        socket: executor.socket_stats(),
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;

    #[test]
    fn socket_pairs_connect_and_carry_bytes_both_families() {
        for kind in [SocketKind::Tcp, SocketKind::Unix] {
            let (mut driver, mut worker) = socket_pairs(kind, 2).unwrap();
            // Driver sockets are non-blocking: flip one back for a
            // simple blocking echo check.
            driver[0].set_nonblocking(false).unwrap();
            driver[0].write_all(b"ping").unwrap();
            let mut buf = [0u8; 4];
            worker[0].read_exact(&mut buf).unwrap();
            assert_eq!(&buf, b"ping", "{} pair 0 carries bytes", kind.name());
            worker[0].write_all(b"pong").unwrap();
            driver[0].read_exact(&mut buf).unwrap();
            assert_eq!(&buf, b"pong");
            // Hanging up the second pair produces a clean EOF.
            driver[1].shutdown_write();
            let mut h = [0u8; ENVELOPE_LEN];
            assert!(!read_exact_or_eof(&mut worker[1], &mut h).unwrap());
        }
    }

    #[test]
    fn poller_reports_readable_connection() {
        let (mut driver, mut worker) = socket_pairs(SocketKind::Tcp, 1).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(driver[0].raw_fd(), 7).unwrap();
        worker[0].write_all(&[1u8; ACK_LEN]).unwrap();
        let mut events = Vec::new();
        // The loopback delivery is asynchronous; poll until it lands.
        let deadline = Instant::now() + Duration::from_secs(5);
        while events.iter().all(|&(t, r, _)| t != 7 || !r) {
            assert!(Instant::now() < deadline, "readable event never arrived");
            events.clear();
            poller.wait(Duration::from_millis(50), &mut events).unwrap();
        }
        let mut buf = [0u8; ACK_LEN];
        driver[0].set_nonblocking(false).unwrap();
        driver[0].read_exact(&mut buf).unwrap();
        assert_eq!(buf, [1u8; ACK_LEN]);
    }

    #[test]
    fn reactor_opts_builder_clamps_workers() {
        let o = ReactorOpts::default()
            .workers(0)
            .transport(SocketKind::Unix)
            .feed_network(false);
        assert_eq!(o.workers, 1);
        assert_eq!(o.transport, SocketKind::Unix);
        assert!(!o.feed_network);
    }
}
