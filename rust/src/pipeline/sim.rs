//! Discrete-event driver over the shared streaming core
//! ([`crate::pipeline::core`]): the full deployment (paper Fig. 3/8) —
//! cameras → Load Shedder → (token-paced) Backend Query Executor — with
//! calibrated stage costs, regenerating the paper's long-running
//! experiments (Fig. 13/14) in seconds, deterministically.
//!
//! This module is now a thin wrapper: the frame lifecycle, admission /
//! control-loop wiring and metrics sink live in `pipeline::core`; the sim
//! driver supplies [`SimClock`] (virtual time, no pacing) and
//! [`SyncBackend`] (in-process query execution).
//!
//! Time model per frame:
//!   capture ts → [camera proc] → [net cam→LS] → LS ingress (admission /
//!   queue) → token available → [net LS→Q] → backend stages → completion.
//! E2E latency (Eq. 4) = completion − capture, which includes every queue
//! and exec segment on the path.

use crate::backend::BackendQuery;
use crate::features::Extractor;
use crate::pipeline::core::{run_pipeline, ArrivalModel, SimClock, SyncBackend};
use crate::pipeline::multi::{
    run_multi_pipeline, MultiPipelineReport, MultiSimConfig, MultiSyncBackend,
};
use crate::pipeline::workloads::IterArrivals;
use crate::shedder::QuerySet;
use crate::video::Frame;

pub use crate::pipeline::core::{backgrounds_of, BackgroundMap, Policy, SimConfig};

/// What the simulator reports (feeds the figure harnesses) — the shared
/// core report under its historical name.
pub type SimReport = crate::pipeline::core::PipelineReport;

/// Run the simulation over a timestamp-ordered frame stream.
///
/// `backgrounds` maps camera id → borrowed background model (H*W*3);
/// build it with [`backgrounds_of`].
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.sim()`
/// [`.run_frames(frames, backgrounds, extractor, backend)`](crate::pipeline::SimBuilder::run_frames);
/// this free function is kept as a thin compatibility wrapper.
pub fn run_sim<I>(
    frames: I,
    backgrounds: &BackgroundMap<'_>,
    cfg: &SimConfig,
    extractor: &Extractor,
    backend: &mut BackendQuery,
) -> anyhow::Result<SimReport>
where
    I: IntoIterator<Item = Frame>,
{
    run_sim_with(
        IterArrivals::new(frames.into_iter(), cfg.fps_total),
        backgrounds,
        cfg,
        extractor,
        backend,
    )
}

/// [`run_sim`] over any [`ArrivalModel`] (bursty Poisson ingress, camera
/// churn, …): the discrete-event clock against a pluggable workload.
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.sim()`
/// [`.run_arrivals(arrivals, backgrounds, extractor, backend)`](crate::pipeline::SimBuilder::run_arrivals);
/// this free function is kept as a thin compatibility wrapper.
pub fn run_sim_with<A: ArrivalModel>(
    arrivals: A,
    backgrounds: &BackgroundMap<'_>,
    cfg: &SimConfig,
    extractor: &Extractor,
    backend: &mut BackendQuery,
) -> anyhow::Result<SimReport> {
    let mut executor = SyncBackend::new(backend);
    run_pipeline(arrivals, backgrounds, cfg, extractor, &mut executor, &mut SimClock)
}

/// Run N concurrent queries over one shared timestamp-ordered stream
/// under the discrete-event clock: one feature extraction per frame, one
/// in-process [`BackendQuery`] per query (see
/// [`crate::pipeline::multi_backends`] for the default construction).
/// `extractor` must be built from `set`'s union model.
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.multi_query(set)`
/// [`.run_frames(...)`](crate::pipeline::MultiQueryBuilder::run_frames);
/// this free function is kept as a thin compatibility wrapper.
pub fn run_multi_sim<I>(
    frames: I,
    backgrounds: &BackgroundMap<'_>,
    set: &QuerySet,
    cfg: &MultiSimConfig,
    extractor: &Extractor,
    backends: &mut [BackendQuery],
) -> anyhow::Result<MultiPipelineReport>
where
    I: IntoIterator<Item = Frame>,
{
    run_multi_sim_with(
        IterArrivals::new(frames.into_iter(), cfg.fps_total),
        backgrounds,
        set,
        cfg,
        extractor,
        backends,
    )
}

/// [`run_multi_sim`] over any [`ArrivalModel`] workload.
///
/// Deprecated: use
/// [`Pipeline::builder()`](crate::pipeline::Pipeline::builder)`.multi_query(set)`
/// [`.run_arrivals(...)`](crate::pipeline::MultiQueryBuilder::run_arrivals);
/// this free function is kept as a thin compatibility wrapper.
pub fn run_multi_sim_with<A: ArrivalModel>(
    arrivals: A,
    backgrounds: &BackgroundMap<'_>,
    set: &QuerySet,
    cfg: &MultiSimConfig,
    extractor: &Extractor,
    backends: &mut [BackendQuery],
) -> anyhow::Result<MultiPipelineReport> {
    let mut executor = MultiSyncBackend::new(backends);
    run_multi_pipeline(
        arrivals,
        backgrounds,
        set,
        cfg,
        extractor,
        &mut executor,
        &mut SimClock,
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;
    use crate::backend::{CostModel, Detector};
    use crate::color::NamedColor;
    use crate::config::{CostConfig, QueryConfig, ShedderConfig};
    use crate::utility::train;
    use crate::video::{Video, VideoConfig};

    fn sim_setup(vehicle_rate: f64) -> (Vec<Video>, SimConfig) {
        // Three cameras (30 fps aggregate) against a single-DNN backend:
        // genuine overload. Dull-red confounders pass the backend's
        // hue-only color filter and load the DNN, but stay a minority of
        // traffic so the utility model keeps its separation (the paper's
        // operating premise).
        let videos: Vec<Video> = (0..5)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 77 + i as u64, i, 300);
                vc.traffic.vehicle_rate = vehicle_rate;
                vc.traffic.paint_weights = vec![
                    (crate::video::Paint::VividRed, 0.06),
                    (crate::video::Paint::DullRed, 0.12),
                    (crate::video::Paint::Gray, 0.37),
                    (crate::video::Paint::Silver, 0.25),
                    (crate::video::Paint::Black, 0.20),
                ];
                Video::new(vc)
            })
            .collect();
        let cfg = SimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
            backend_tokens: 1,
            policy: Policy::UtilityControlLoop,
            seed: 5,
            fps_total: 50.0,
            transport: crate::pipeline::TransportConfig::default(),
            faults: crate::pipeline::FaultPlan::default(),
            adaptation: crate::utility::AdaptationConfig::default(),
        };
        (videos, cfg)
    }

    fn run(videos: &[Video], cfg: &SimConfig) -> SimReport {
        let train_idx: Vec<usize> = (0..videos.len()).collect();
        let model = train(videos, &train_idx, &cfg.query.colors, cfg.query.combine);
        let extractor = Extractor::native(model);
        let mut backend = BackendQuery::new(
            cfg.query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), cfg.seed),
            25.0,
        );
        run_sim(
            crate::video::Streamer::new(videos),
            &backgrounds_of(videos),
            cfg,
            &extractor,
            &mut backend,
        )
        .unwrap()
    }

    #[test]
    fn conservation_of_frames() {
        let (videos, cfg) = sim_setup(0.5);
        let r = run(&videos, &cfg);
        assert_eq!(r.ingress, 1500);
        assert_eq!(r.ingress, r.transmitted + r.shed);
        // The decision log is the per-frame view of the same conservation.
        assert_eq!(r.decisions.len() as u64, r.ingress);
        let kept = r.decisions.iter().filter(|d| d.kept).count() as u64;
        assert_eq!(kept, r.transmitted);
    }

    #[test]
    fn control_loop_keeps_latency_bounded_under_load() {
        let (videos, cfg) = sim_setup(0.4);
        let r = run(&videos, &cfg);
        // Under heavy red traffic the DNN would be invoked continuously at
        // 120 ms/frame vs 100 ms frame period: without shedding latency
        // diverges. The control loop must keep violations rare.
        assert!(
            r.latency.violation_rate() < 0.05,
            "violation rate {} (max {} ms)",
            r.latency.violation_rate(),
            r.latency.max_ms()
        );
        assert!(r.shed > 0, "overload must force shedding");
    }

    #[test]
    fn no_shedding_policy_violates_under_load() {
        let (videos, mut cfg) = sim_setup(0.4);
        cfg.policy = Policy::NoShedding;
        cfg.shedder.queue_cap_max = 10_000; // effectively unbounded queue
        // Huge queue cap: frames pile up, latency diverges.
        let r = run(&videos, &cfg);
        assert!(
            r.latency.max_ms() > cfg.query.latency_bound_ms,
            "expected violations without shedding (max {} ms)",
            r.latency.max_ms()
        );
    }

    #[test]
    fn utility_beats_random_on_qor_at_similar_drop() {
        let (videos, cfg) = sim_setup(0.25);
        let util = run(&videos, &cfg);
        let mut rnd_cfg = cfg.clone();
        rnd_cfg.policy = Policy::RandomRate { assumed_proc_q_ms: 120.0 };
        let rnd = run(&videos, &rnd_cfg);
        // With comparable drop pressure the utility shedder must keep
        // more target frames.
        assert!(
            util.qor.overall() > rnd.qor.overall() + 0.1,
            "utility QoR {} vs random QoR {} (drops {} vs {})",
            util.qor.overall(),
            rnd.qor.overall(),
            util.observed_drop_rate(),
            rnd.observed_drop_rate()
        );
    }

    #[test]
    fn quiet_stream_sheds_nothing() {
        let (videos, cfg) = sim_setup(0.02);
        let r = run(&videos, &cfg);
        assert!(
            r.observed_drop_rate() < 0.1,
            "quiet stream shed {}",
            r.observed_drop_rate()
        );
        assert!(r.qor.overall() > 0.95, "qor {}", r.qor.overall());
    }

    #[test]
    fn bursty_and_churn_workloads_run_under_the_sim_clock() {
        use crate::pipeline::workloads::{CameraChurn, PoissonArrivals};
        let (videos, cfg) = sim_setup(0.3);
        let train_idx: Vec<usize> = (0..videos.len()).collect();
        let model = train(&videos, &train_idx, &cfg.query.colors, cfg.query.combine);
        let extractor = Extractor::native(model);
        let bgs = backgrounds_of(&videos);
        let mk_backend = || {
            BackendQuery::new(
                cfg.query.clone(),
                Detector::native(12, 25.0),
                CostModel::new(cfg.costs.clone(), cfg.seed),
                25.0,
            )
        };

        let mut backend = mk_backend();
        let bursty = run_sim_with(
            PoissonArrivals::new(&videos, 0xB0B, 1.0),
            &bgs,
            &cfg,
            &extractor,
            &mut backend,
        )
        .unwrap();
        assert_eq!(bursty.ingress, 1500);
        assert_eq!(bursty.ingress, bursty.transmitted + bursty.shed);
        assert!(bursty.shed > 0, "bursty overload must shed");

        let mut backend = mk_backend();
        let churn = run_sim_with(
            CameraChurn::staggered(&videos, 5_000.0, 15_000.0),
            &bgs,
            &cfg,
            &extractor,
            &mut backend,
        )
        .unwrap();
        assert!(churn.ingress > 0);
        assert_eq!(churn.ingress, churn.transmitted + churn.shed);
        // Staggered joins: ingress ramps, so the stage series must span
        // more windows than one camera's lifetime alone.
        assert!(churn.end_ms > 20_000.0, "end {}", churn.end_ms);
    }
}
