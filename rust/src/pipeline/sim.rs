//! Discrete-event simulation of the full deployment (paper Fig. 3/8):
//! cameras → Load Shedder → (token-paced) Backend Query Executor, with
//! calibrated stage costs. This regenerates the paper's long-running
//! experiments (Fig. 13/14) in seconds, deterministically.
//!
//! Time model per frame:
//!   capture ts → [camera proc] → [net cam→LS] → LS ingress (admission /
//!   queue) → token available → [net LS→Q] → backend stages → completion.
//! E2E latency (Eq. 4) = completion − capture, which includes every queue
//! and exec segment on the path.

use crate::backend::BackendQuery;
use crate::config::{CostConfig, QueryConfig, ShedderConfig};
use crate::features::{Extractor, FrameFeatures, UtilityValues};
use crate::metrics::{LatencyTracker, QorTracker, Stage, StageCounts, WindowSeries};
use crate::shedder::{Entry, LoadShedder, TokenBucket};
use crate::util::rng::Rng;
use crate::video::{Frame, Video};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Camera id → borrowed background model (H*W*3). Sharing borrows avoids
/// the historical per-call-site `background().to_vec()` duplication.
pub type BackgroundMap<'a> = HashMap<u32, &'a [f32]>;

/// Build the camera → background map for a video set (no copies).
pub fn backgrounds_of(videos: &[Video]) -> BackgroundMap<'_> {
    videos
        .iter()
        .map(|v| (v.camera_id(), v.background()))
        .collect()
}

/// Shedding policy under simulation.
#[derive(Debug, Clone)]
pub enum Policy {
    /// The paper's utility-based shedder with the full control loop.
    UtilityControlLoop,
    /// Content-agnostic baseline: uniform random drop at the rate Eq. 19
    /// prescribes for an *assumed* proc_Q (paper §V-E.2 uses 500 ms).
    RandomRate { assumed_proc_q_ms: f64 },
    /// Ablation: same admission control, but FIFO queue service (constant
    /// queue key) instead of utility-ordered eviction.
    FifoControlLoop,
    /// No shedding at all (for overload illustration).
    NoShedding,
}

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub costs: CostConfig,
    pub shedder: ShedderConfig,
    pub query: QueryConfig,
    /// Backend concurrency (token capacity); the paper's NC6 runs one DNN.
    pub backend_tokens: u32,
    pub policy: Policy,
    pub seed: u64,
    /// Nominal aggregate ingress fps (estimator fallback).
    pub fps_total: f64,
}

/// What the simulator reports (feeds the figure harnesses).
#[derive(Clone)]
pub struct SimReport {
    pub qor: QorTracker,
    pub latency: LatencyTracker,
    /// Max-latency time series for the Fig. 13 upper panel (5 s windows).
    pub latency_windows: WindowSeries,
    /// Per-stage frame counts (Fig. 13 lower panel).
    pub stages: StageCounts,
    /// Threshold + target rate over time: (ts, threshold, target_rate).
    pub control_series: Vec<(f64, f32, f64)>,
    pub ingress: u64,
    pub transmitted: u64,
    pub shed: u64,
    /// Final simulated clock (ms).
    pub end_ms: f64,
}

impl SimReport {
    pub fn observed_drop_rate(&self) -> f64 {
        if self.ingress == 0 {
            0.0
        } else {
            self.shed as f64 / self.ingress as f64
        }
    }
}

/// Frame payload carried through the shedder queue.
struct SimFrame {
    camera: u32,
    capture_ms: f64,
    target_ids: Vec<u64>,
    rgb: Vec<f32>,
    width: usize,
    height: usize,
}

enum EventKind {
    Ingress(Box<SimFrame>, f32 /* utility */),
    Completion { exec_ms: f64 },
}

/// Event heap keyed by (µs time, seq); payloads in a side map.
struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64)>>,
    events: HashMap<u64, (f64, EventKind)>,
    seq: u64,
}

impl EventQueue {
    fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), events: HashMap::new(), seq: 0 }
    }

    fn push(&mut self, t: f64, kind: EventKind) {
        let key = (t * 1e3) as u64; // µs-resolution ordering
        self.seq += 1;
        self.heap.push(Reverse((key, self.seq)));
        self.events.insert(self.seq, (t, kind));
    }

    fn pop(&mut self) -> Option<(f64, EventKind)> {
        let Reverse((_, id)) = self.heap.pop()?;
        Some(self.events.remove(&id).expect("event payload"))
    }
}

/// Run the simulation over a timestamp-ordered frame stream.
///
/// `backgrounds` maps camera id → borrowed background model (H*W*3);
/// build it with [`backgrounds_of`].
pub fn run_sim<I>(
    frames: I,
    backgrounds: &BackgroundMap<'_>,
    cfg: &SimConfig,
    extractor: &Extractor,
    backend: &mut BackendQuery,
) -> anyhow::Result<SimReport>
where
    I: IntoIterator<Item = Frame>,
{
    let mut rng = Rng::new(cfg.seed ^ 0x51B);
    let mut cost = crate::backend::CostModel::new(cfg.costs.clone(), cfg.seed ^ 0xCA11);
    let mut shedder: LoadShedder<SimFrame> = LoadShedder::new(
        &cfg.shedder,
        &cfg.costs,
        cfg.query.latency_bound_ms,
        cfg.fps_total,
    );
    let mut tokens = TokenBucket::new(cfg.backend_tokens.max(1));

    let mut qor = QorTracker::new();
    let mut latency = LatencyTracker::new(cfg.query.latency_bound_ms);
    let mut latency_windows = WindowSeries::new(5_000.0);
    let mut stages = StageCounts::new(5_000.0);
    let mut control_series = Vec::new();
    let (mut ingress_n, mut transmitted, mut shed) = (0u64, 0u64, 0u64);

    // Baseline policies pin the threshold themselves (the FIFO ablation
    // keeps the full control loop — only queue ordering changes).
    if matches!(cfg.policy, Policy::RandomRate { .. } | Policy::NoShedding) {
        shedder.auto_retune = false;
        shedder.admission.set_target_rate(0.0);
    }
    // Random-policy fixed rate (Eq. 19 with assumed proc_Q).
    let random_rate = match cfg.policy {
        Policy::RandomRate { assumed_proc_q_ms } => {
            crate::shedder::target_drop_rate(assumed_proc_q_ms, cfg.fps_total)
        }
        _ => 0.0,
    };

    let mut eq = EventQueue::new();
    let mut frame_iter = frames.into_iter();
    // Reused feature/utility buffers: the camera-side extraction is the
    // per-frame hot spot and must not allocate (paper Fig. 15 budget).
    let mut feat_buf = FrameFeatures::empty();
    let mut util_buf = UtilityValues::empty();
    // Reused drop buffer + recycled target-id vectors: after warmup the
    // event loop itself performs no per-event heap allocation beyond the
    // frames the upstream iterator materializes (and one Box per frame to
    // keep the event enum small).
    let mut dropped: Vec<Entry<SimFrame>> = Vec::new();
    let mut id_pool: Vec<Vec<u64>> = Vec::new();

    // Retire a frame's recyclable buffers into the pool.
    fn recycle(pool: &mut Vec<Vec<u64>>, f: SimFrame) {
        let mut ids = f.target_ids;
        ids.clear();
        if pool.len() < 64 {
            pool.push(ids);
        }
    }

    // Feed the next arrival from the (ts-ordered) stream into the heap.
    #[allow(clippy::too_many_arguments)]
    fn feed_next(
        eq: &mut EventQueue,
        frame_iter: &mut impl Iterator<Item = Frame>,
        backgrounds: &BackgroundMap<'_>,
        extractor: &Extractor,
        query: &QueryConfig,
        cost: &mut crate::backend::CostModel,
        feat_buf: &mut FrameFeatures,
        util_buf: &mut UtilityValues,
        id_pool: &mut Vec<Vec<u64>>,
    ) -> anyhow::Result<bool> {
        match frame_iter.next() {
            None => Ok(false),
            Some(f) => {
                let bg = *backgrounds
                    .get(&f.camera)
                    .ok_or_else(|| anyhow::anyhow!("no background for camera {}", f.camera))?;
                // Camera-aware: engages the per-camera incremental tile
                // engine when the extractor has one (bit-identical either
                // way), else the stateless fused path.
                extractor.extract_camera_into(
                    f.camera, f.width, f.height, &f.rgb, bg, feat_buf, util_buf,
                )?;
                let t_ls = f.ts_ms + cost.camera_ms() + cost.net_cam_ls_ms();
                let mut ids = id_pool.pop().unwrap_or_default();
                f.target_ids_into(&query.colors, query.min_blob_px, &mut ids);
                let sf = SimFrame {
                    camera: f.camera,
                    capture_ms: f.ts_ms,
                    target_ids: ids,
                    rgb: f.rgb,
                    width: f.width,
                    height: f.height,
                };
                eq.push(t_ls, EventKind::Ingress(Box::new(sf), util_buf.combined));
                Ok(true)
            }
        }
    }

    feed_next(
        &mut eq,
        &mut frame_iter,
        backgrounds,
        extractor,
        &cfg.query,
        &mut cost,
        &mut feat_buf,
        &mut util_buf,
        &mut id_pool,
    )?;
    let mut now = 0.0f64;
    let mut last_control_sample = f64::NEG_INFINITY;

    while let Some((t, kind)) = eq.pop() {
        now = now.max(t);
        match kind {
            EventKind::Ingress(frame, utility) => {
                ingress_n += 1;
                stages.observe(Stage::Ingress, frame.capture_ms);
                // Refill the arrival pipeline.
                feed_next(
                    &mut eq,
                    &mut frame_iter,
                    backgrounds,
                    extractor,
                    &cfg.query,
                    &mut cost,
                    &mut feat_buf,
                    &mut util_buf,
                    &mut id_pool,
                )?;

                // Content-agnostic baseline: coin flip ahead of the queue;
                // surviving frames get a constant utility (FIFO service).
                let coin_dropped = matches!(cfg.policy, Policy::RandomRate { .. })
                    && rng.chance(random_rate);
                if coin_dropped {
                    qor.observe(&frame.target_ids, false);
                    stages.observe(Stage::Shed, frame.capture_ms);
                    shed += 1;
                    recycle(&mut id_pool, *frame);
                } else {
                    // (admission utility, queue-ordering key) per policy.
                    let (u, key) = match cfg.policy {
                        Policy::UtilityControlLoop => (utility, utility),
                        Policy::FifoControlLoop => (utility, 0.5),
                        _ => (0.5, 0.5),
                    };
                    // Every dropped frame — retune evictions, displaced
                    // queue victims, and an admission/queue rejection of
                    // the offered frame itself — lands in the reused
                    // `dropped` buffer: no per-frame target-id clone.
                    dropped.clear();
                    let _ = shedder.on_ingress_keyed_into(u, key, now, *frame, &mut dropped);
                    for e in dropped.drain(..) {
                        qor.observe(&e.item.target_ids, false);
                        stages.observe(Stage::Shed, e.item.capture_ms);
                        shed += 1;
                        recycle(&mut id_pool, e.item);
                    }
                }

                // Control-series sampling (1 s cadence).
                if now - last_control_sample >= 1_000.0 {
                    control_series.push((now, shedder.threshold(), shedder.target_rate()));
                    last_control_sample = now;
                }
            }
            EventKind::Completion { exec_ms } => {
                tokens.release();
                shedder.on_backend_complete(exec_ms);
            }
        }

        // Start services while tokens and frames are available.
        while tokens.available() > 0 {
            let Some(entry) = shedder.next_to_send() else { break };
            // Transmission-time deadline check: a frame whose expected
            // completion (Eq. 20 terms) already exceeds LB is doomed —
            // shed it instead of burning backend time (utility ordering
            // can starve low-utility frames through a burst).
            let expected_done =
                now + cfg.costs.net_ls_q_ms + shedder.control.proc_q_ms();
            if expected_done - entry.item.capture_ms > cfg.query.latency_bound_ms {
                qor.observe(&entry.item.target_ids, false);
                stages.observe(Stage::Shed, entry.item.capture_ms);
                shed += 1;
                recycle(&mut id_pool, entry.item);
                continue;
            }
            assert!(tokens.try_acquire());
            let f = entry.item;
            transmitted += 1;
            qor.observe(&f.target_ids, true);
            let bg = *backgrounds.get(&f.camera).unwrap();
            let result = backend.process(&f.rgb, bg, f.width, f.height)?;
            // Stage bookkeeping: every transmitted frame reaches the blob
            // filter; deeper stages per the result.
            stages.observe(Stage::BlobFilter, f.capture_ms);
            if result.last_stage >= Stage::ColorFilter {
                stages.observe(Stage::ColorFilter, f.capture_ms);
            }
            if result.last_stage == Stage::Sink {
                // Color-filter pass implies the DNN ran, then the sink.
                stages.observe(Stage::Dnn, f.capture_ms);
                stages.observe(Stage::Sink, f.capture_ms);
            }
            let net = cost.net_ls_q_ms();
            let done_at = now + net + result.exec_ms;
            let e2e = done_at - f.capture_ms;
            latency.observe(e2e);
            latency_windows.observe(f.capture_ms, e2e);
            eq.push(done_at, EventKind::Completion { exec_ms: result.exec_ms });
            recycle(&mut id_pool, f);
        }
    }

    Ok(SimReport {
        qor,
        latency,
        latency_windows,
        stages,
        control_series,
        ingress: ingress_n,
        transmitted,
        shed,
        end_ms: now,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{CostModel, Detector};
    use crate::color::NamedColor;
    use crate::utility::{train, Combine};
    use crate::video::{Video, VideoConfig};

    fn sim_setup(vehicle_rate: f64) -> (Vec<Video>, SimConfig) {
        // Three cameras (30 fps aggregate) against a single-DNN backend:
        // genuine overload. Dull-red confounders pass the backend's
        // hue-only color filter and load the DNN, but stay a minority of
        // traffic so the utility model keeps its separation (the paper's
        // operating premise).
        let videos: Vec<Video> = (0..5)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 77 + i as u64, i, 300);
                vc.traffic.vehicle_rate = vehicle_rate;
                vc.traffic.paint_weights = vec![
                    (crate::video::Paint::VividRed, 0.06),
                    (crate::video::Paint::DullRed, 0.12),
                    (crate::video::Paint::Gray, 0.37),
                    (crate::video::Paint::Silver, 0.25),
                    (crate::video::Paint::Black, 0.20),
                ];
                Video::new(vc)
            })
            .collect();
        let cfg = SimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            query: QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0),
            backend_tokens: 1,
            policy: Policy::UtilityControlLoop,
            seed: 5,
            fps_total: 50.0,
        };
        (videos, cfg)
    }

    fn run(videos: &[Video], cfg: &SimConfig) -> SimReport {
        let train_idx: Vec<usize> = (0..videos.len()).collect();
        let model = train(videos, &train_idx, &cfg.query.colors, cfg.query.combine);
        let extractor = Extractor::native(model);
        let mut backend = BackendQuery::new(
            cfg.query.clone(),
            Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), cfg.seed),
            25.0,
        );
        run_sim(
            crate::video::Streamer::new(videos),
            &backgrounds_of(videos),
            cfg,
            &extractor,
            &mut backend,
        )
        .unwrap()
    }

    #[test]
    fn conservation_of_frames() {
        let (videos, cfg) = sim_setup(0.5);
        let r = run(&videos, &cfg);
        assert_eq!(r.ingress, 1500);
        assert_eq!(r.ingress, r.transmitted + r.shed);
    }

    #[test]
    fn control_loop_keeps_latency_bounded_under_load() {
        let (videos, cfg) = sim_setup(0.4);
        let r = run(&videos, &cfg);
        // Under heavy red traffic the DNN would be invoked continuously at
        // 120 ms/frame vs 100 ms frame period: without shedding latency
        // diverges. The control loop must keep violations rare.
        assert!(
            r.latency.violation_rate() < 0.05,
            "violation rate {} (max {} ms)",
            r.latency.violation_rate(),
            r.latency.max_ms()
        );
        assert!(r.shed > 0, "overload must force shedding");
    }

    #[test]
    fn no_shedding_policy_violates_under_load() {
        let (videos, mut cfg) = sim_setup(0.4);
        cfg.policy = Policy::NoShedding;
        cfg.shedder.queue_cap_max = 10_000; // effectively unbounded queue
        // Huge queue cap: frames pile up, latency diverges.
        let r = run(&videos, &cfg);
        assert!(
            r.latency.max_ms() > cfg.query.latency_bound_ms,
            "expected violations without shedding (max {} ms)",
            r.latency.max_ms()
        );
    }

    #[test]
    fn utility_beats_random_on_qor_at_similar_drop() {
        let (videos, cfg) = sim_setup(0.25);
        let util = run(&videos, &cfg);
        let mut rnd_cfg = cfg.clone();
        rnd_cfg.policy = Policy::RandomRate { assumed_proc_q_ms: 120.0 };
        let rnd = run(&videos, &rnd_cfg);
        // With comparable drop pressure the utility shedder must keep
        // more target frames.
        assert!(
            util.qor.overall() > rnd.qor.overall() + 0.1,
            "utility QoR {} vs random QoR {} (drops {} vs {})",
            util.qor.overall(),
            rnd.qor.overall(),
            util.observed_drop_rate(),
            rnd.observed_drop_rate()
        );
    }

    #[test]
    fn quiet_stream_sheds_nothing() {
        let (videos, cfg) = sim_setup(0.02);
        let r = run(&videos, &cfg);
        assert!(
            r.observed_drop_rate() < 0.1,
            "quiet stream shed {}",
            r.observed_drop_rate()
        );
        assert!(r.qor.overall() > 0.95, "qor {}", r.qor.overall());
    }
}

#[cfg(test)]
mod dbg {
    use super::*;
    use crate::backend::{CostModel, Detector};
    use crate::color::NamedColor;
    use crate::utility::{train, Combine};
    use crate::video::{Video, VideoConfig};

    #[test]
    #[ignore]
    fn dbg_sim() {
        let videos: Vec<Video> = (0..5)
            .map(|i| {
                let mut vc = VideoConfig::new(11, 77 + i as u64, i, 300);
                vc.traffic.vehicle_rate = 0.25;
                vc.traffic.paint_weights = vec![
                    (crate::video::Paint::VividRed, 0.06),
                    (crate::video::Paint::DullRed, 0.12),
                    (crate::video::Paint::Gray, 0.37),
                    (crate::video::Paint::Silver, 0.25),
                    (crate::video::Paint::Black, 0.20),
                ];
                Video::new(vc)
            })
            .collect();
        let query = QueryConfig::single(NamedColor::Red).with_latency_bound(1500.0);
        let model = train(&videos, &[0, 1, 2, 3, 4], &query.colors, Combine::Single);
        let extractor = Extractor::native(model);
        // print utility distribution pos vs neg
        let v = &videos[0];
        let mut pos = vec![]; let mut neg = vec![];
        let mut pos_frames = 0;
        for t in 0..v.len() {
            let f = v.render(t);
            let (_, u) = extractor.extract(&f.rgb, v.background()).unwrap();
            if f.is_positive(NamedColor::Red, 40) { pos.push(u.combined); pos_frames += 1; } else { neg.push(u.combined); }
        }
        pos.sort_by(|a,b| a.partial_cmp(b).unwrap());
        neg.sort_by(|a,b| a.partial_cmp(b).unwrap());
        let q = |v: &Vec<f32>, p: f64| if v.is_empty() {0.0} else {v[(p*(v.len()-1) as f64) as usize]};
        eprintln!("pos frames {} / 300; pos u: p10 {:.3} p50 {:.3} p90 {:.3}", pos_frames, q(&pos,0.1), q(&pos,0.5), q(&pos,0.9));
        eprintln!("neg u: p10 {:.3} p50 {:.3} p90 {:.3} max {:.3}", q(&neg,0.1), q(&neg,0.5), q(&neg,0.9), q(&neg,1.0));

        let cfg = SimConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            query,
            backend_tokens: 1,
            policy: Policy::UtilityControlLoop,
            seed: 5,
            fps_total: 50.0,
        };
        let mut backend = BackendQuery::new(cfg.query.clone(), Detector::native(12, 25.0),
            CostModel::new(cfg.costs.clone(), cfg.seed), 25.0);
        let r = run_sim(crate::video::Streamer::new(&videos), &backgrounds_of(&videos), &cfg, &extractor, &mut backend).unwrap();
        eprintln!("ingress {} transmitted {} shed {} qor {:.3} drop {:.3}", r.ingress, r.transmitted, r.shed, r.qor.overall(), r.observed_drop_rate());
        eprintln!("violations {} / {} max {:.0}ms", r.latency.violations(), r.latency.count(), r.latency.max_ms());
        for (t, th, rate) in r.control_series.iter().take(40) {
            eprintln!("t={:6.0} th={:.3} rate={:.3}", t, th, rate);
        }
        let objs = r.qor.per_object_all();
        eprintln!("objects: {:?}", objs.iter().map(|(_,q)| (q*100.0) as i32).collect::<Vec<_>>());
    }
}
