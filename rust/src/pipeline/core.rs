//! The clock-abstracted streaming core: **one** frame lifecycle
//! (capture → extract → utility → admission → queue → dispatch → backend
//! → completion) shared by every pipeline driver.
//!
//! The paper's deployment (Fig. 3) is a single dataflow; historically this
//! repo implemented it three times (`sim`, `realtime`, `parallel`), each
//! with its own admission logic, payload struct and metrics accumulation.
//! This module hosts the single implementation, parameterized by:
//!
//! * [`Clock`] — how virtual (stream-time) events map onto execution:
//!   [`SimClock`] applies them instantly (discrete-event simulation),
//!   [`WallClock`] paces them against real time (the threaded runtime).
//!   Decisions depend only on the virtual-time event order, which is
//!   identical under both clocks — pinned by `rust/tests/core_equivalence.rs`.
//! * [`ArrivalModel`] — the workload: a timestamp-ordered frame source
//!   plus its nominal aggregate rate. `pipeline::workloads` ships the
//!   plain interleaved stream, bursty Poisson ingress, and mid-run camera
//!   churn; new scenarios are new impls of this trait.
//! * [`BackendExecutor`] — how the backend query runs: synchronously
//!   in-process ([`SyncBackend`]) or on a worker thread with the real
//!   detector on the hot path (`realtime::ThreadedBackend`).
//!
//! Every driver feeds the same metrics sink: [`QorTracker`],
//! [`LatencyTracker`], [`StageCounts`], [`WindowSeries`] and the per-frame
//! decision log, aggregated into one [`PipelineReport`].

use crate::backend::BackendQuery;
use crate::config::{CostConfig, QueryConfig, ShedderConfig};
use crate::features::{Extractor, FrameFeatures, UtilityValues};
use crate::metrics::{LatencyTracker, QorTracker, Stage, StageCounts, WindowSeries};
use crate::pipeline::faults::{FaultPlan, FaultStats, PoisonKind};
use crate::pipeline::transport::{TransportConfig, TransportState};
use crate::shedder::{Entry, LoadShedder, QueryMask, TokenBucket};
use crate::util::rng::Rng;
use crate::utility::{AdaptationConfig, AdaptationStats, OnlineAdapter};
use crate::video::{Frame, Video};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::time::{Duration, Instant};

/// Camera id → borrowed background model (H*W*3). Sharing borrows avoids
/// the historical per-call-site `background().to_vec()` duplication.
pub type BackgroundMap<'a> = HashMap<u32, &'a [f32]>;

/// Build the camera → background map for a video set (no copies).
pub fn backgrounds_of(videos: &[Video]) -> BackgroundMap<'_> {
    videos
        .iter()
        .map(|v| (v.camera_id(), v.background()))
        .collect()
}

/// Shedding policy of the core lifecycle.
#[derive(Debug, Clone)]
pub enum Policy {
    /// The paper's utility-based shedder with the full control loop.
    UtilityControlLoop,
    /// Content-agnostic baseline: uniform random drop at the rate Eq. 19
    /// prescribes for an *assumed* proc_Q (paper §V-E.2 uses 500 ms).
    RandomRate { assumed_proc_q_ms: f64 },
    /// Ablation: same admission control, but FIFO queue service (constant
    /// queue key) instead of utility-ordered eviction.
    FifoControlLoop,
    /// No shedding at all (for overload illustration).
    NoShedding,
}

/// Core lifecycle parameters (identical under every clock/driver).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Per-stage execution/transfer cost distributions (paper Table I).
    pub costs: CostConfig,
    /// Load-shedder tuning (admission CDF, queue capacity, control gains).
    pub shedder: ShedderConfig,
    /// The query: colors of interest, filter thresholds, latency bound.
    pub query: QueryConfig,
    /// Backend concurrency (token capacity); the paper's NC6 runs one DNN.
    pub backend_tokens: u32,
    /// Shedding policy (the paper's control loop or an ablation baseline).
    pub policy: Policy,
    /// Seed for the cost model and policy coin — the whole run is a
    /// deterministic function of (seed, stream).
    pub seed: u64,
    /// Nominal aggregate ingress fps (estimator fallback).
    pub fps_total: f64,
    /// Modeled shedder→backend link + wire encoding. The default (ideal
    /// link, raw encoding) reproduces the pre-transport pipeline
    /// bit-for-bit; see [`crate::pipeline::transport`].
    pub transport: TransportConfig,
    /// Scheduled fault windows. The default empty plan is the
    /// verification mode: bit-identical to a faultless pipeline (no
    /// extra RNG draws or EWMA updates); see [`crate::pipeline::faults`].
    pub faults: FaultPlan,
    /// Online utility-model adaptation (shadow evaluation + guarded
    /// rollback). Disabled by default: the engine then constructs no
    /// adapter, attaches no features to payloads, and is bit-identical
    /// to the frozen-model pipeline; see [`crate::utility::adapt`].
    pub adaptation: AdaptationConfig,
}

/// The shared lifecycle parameters every driver consumes — the one
/// config the historical `SimConfig` / `MultiSimConfig` /
/// `RealtimeConfig` trio used to hand-copy field by field. Each driver
/// config is now a projection of this template:
///
/// * [`SimConfig`] is field-for-field this struct (lossless
///   [`From`] conversions both ways).
/// * [`MultiSimConfig`](crate::pipeline::MultiSimConfig) drops the
///   single-query-only fields (`query`, `policy`, `adaptation`) and adds
///   the arbiter — see `MultiSimConfig::from_pipeline`.
/// * [`RealtimeConfig`](crate::pipeline::realtime::RealtimeConfig) adds
///   the wall-clock extras (pacing, cost emulation, artifact choice,
///   worker supervision) — see `RealtimeConfig::from_pipeline`.
/// * The fleet config ([`crate::pipeline::fleet::FleetConfig`]) embeds
///   one `PipelineConfig` per tier instead of adding a fourth copy.
///
/// Construct it through [`Pipeline::builder`](crate::pipeline::Pipeline)
/// or as a struct literal; [`PipelineConfig::default`] is pinned by
/// `rust/tests/builder_defaults.rs` to be decision-log-bit-identical to
/// the historical per-driver defaults.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Per-stage execution/transfer cost distributions (paper Table I).
    pub costs: CostConfig,
    /// Load-shedder tuning (admission CDF, queue capacity, control gains).
    pub shedder: ShedderConfig,
    /// Single-query drivers' query; multi-query drivers keep per-query
    /// configs in their `QuerySet` and ignore this field.
    pub query: QueryConfig,
    /// Backend concurrency (token capacity); the paper's NC6 runs one DNN.
    pub backend_tokens: u32,
    /// Shedding policy (single-query drivers; the multi engine always
    /// runs the utility control loop per query).
    pub policy: Policy,
    /// Seed for the cost model and policy coin — the whole run is a
    /// deterministic function of (seed, stream).
    pub seed: u64,
    /// Nominal aggregate ingress fps (estimator fallback). Drivers fed by
    /// an [`ArrivalModel`] override it with `arrivals.fps_total()`.
    pub fps_total: f64,
    /// Modeled shedder→backend link + wire encoding (ideal by default).
    pub transport: TransportConfig,
    /// Scheduled fault windows (empty by default — bit-identical to a
    /// faultless pipeline; see [`crate::pipeline::faults`]).
    pub faults: FaultPlan,
    /// Online utility-model adaptation (off by default; single-query
    /// drivers only — see [`crate::utility::adapt`]).
    pub adaptation: AdaptationConfig,
}

impl Default for PipelineConfig {
    /// The historical driver defaults, in one place: the same values
    /// `RealtimeConfig::default()` has always carried for the shared
    /// fields (seed `0xB_E`, single red query, one backend token, the
    /// full utility control loop, ideal link, no faults, no adaptation),
    /// with `fps_total` at one camera's native 10 fps.
    fn default() -> Self {
        PipelineConfig {
            costs: CostConfig::default(),
            shedder: ShedderConfig::default(),
            query: QueryConfig::single(crate::color::NamedColor::Red),
            backend_tokens: 1,
            policy: Policy::UtilityControlLoop,
            seed: 0xB_E,
            fps_total: 10.0,
            transport: TransportConfig::default(),
            faults: FaultPlan::default(),
            adaptation: AdaptationConfig::default(),
        }
    }
}

impl From<PipelineConfig> for SimConfig {
    fn from(p: PipelineConfig) -> SimConfig {
        SimConfig {
            costs: p.costs,
            shedder: p.shedder,
            query: p.query,
            backend_tokens: p.backend_tokens,
            policy: p.policy,
            seed: p.seed,
            fps_total: p.fps_total,
            transport: p.transport,
            faults: p.faults,
            adaptation: p.adaptation,
        }
    }
}

impl From<SimConfig> for PipelineConfig {
    fn from(c: SimConfig) -> PipelineConfig {
        PipelineConfig {
            costs: c.costs,
            shedder: c.shedder,
            query: c.query,
            backend_tokens: c.backend_tokens,
            policy: c.policy,
            seed: c.seed,
            fps_total: c.fps_total,
            transport: c.transport,
            faults: c.faults,
            adaptation: c.adaptation,
        }
    }
}

impl Default for SimConfig {
    /// [`PipelineConfig::default`] under the historical name.
    fn default() -> Self {
        PipelineConfig::default().into()
    }
}

/// The one frame payload carried through admission, queue and dispatch —
/// replaces the historical `SimFrame` / `WorkItem` / shard-local structs.
pub struct FramePayload {
    /// Source camera id.
    pub camera: u32,
    /// Capture timestamp (ms, stream clock).
    pub capture_ms: f64,
    /// Ground-truth target ids (QoR accounting only, never the shedder).
    /// The multi-query path keeps per-query id sets beside its queue
    /// entries instead and leaves this empty.
    pub target_ids: Vec<u64>,
    /// Query-admission bitset: the queries this frame is admitted toward.
    /// The multi-query engine fills it from each query's admission gate
    /// and backend executors run only admitted queries on the frame;
    /// single-query drivers pin bit 0 at capture.
    pub admitted: QueryMask,
    /// Measured camera→shedder transfer (ms) sampled for this frame —
    /// paired with the link's measured shedder→backend transfer when the
    /// transport stage feeds `ControlLoop::observe_network`.
    pub net_cam_ls_ms: f64,
    /// Interleaved RGB pixels (`width * height * 3` f32s, row-major).
    pub rgb: Vec<f32>,
    /// Frame width in pixels.
    pub width: usize,
    /// Frame height in pixels.
    pub height: usize,
    /// The frame's extracted features, carried only when online
    /// adaptation is enabled: the dispatch path turns them into a
    /// delayed ground-truth label at backend completion. `None` (and
    /// zero-cost) otherwise.
    pub features: Option<Box<FrameFeatures>>,
}

/// Terminal outcome of one ingress frame (shed anywhere vs transmitted).
#[derive(Debug, Clone, PartialEq)]
pub struct FrameDecision {
    /// Source camera id.
    pub camera: u32,
    /// Capture timestamp (ms, stream clock).
    pub capture_ms: f64,
    /// `true` = transmitted to the backend, `false` = shed (anywhere).
    pub kept: bool,
}

/// What every driver reports: the shared metrics sink, aggregated.
#[derive(Clone)]
pub struct PipelineReport {
    /// Quality-of-result accounting (detected vs missed targets).
    pub qor: QorTracker,
    /// End-to-end frame latency distribution (stream-time ms).
    pub latency: LatencyTracker,
    /// Max-latency time series for the Fig. 13 upper panel (5 s windows).
    pub latency_windows: WindowSeries,
    /// Per-stage frame counts (Fig. 13 lower panel).
    pub stages: StageCounts,
    /// Threshold + target rate over time: (ts, threshold, target_rate).
    pub control_series: Vec<(f64, f32, f64)>,
    /// Terminal shed/transmit decision per ingress frame, in event order
    /// for a single run. Merged sharded reports concatenate the per-shard
    /// logs in camera order (see `pipeline::parallel::merge_reports`),
    /// so ordering there is per-camera, not globally chronological.
    pub decisions: Vec<FrameDecision>,
    /// Frames that arrived at the Load Shedder.
    pub ingress: u64,
    /// Frames delivered to the backend.
    pub transmitted: u64,
    /// Frames shed (admission gate, queue eviction, or deadline check).
    pub shed: u64,
    /// Frames dropped *on the link* (lossy transport exhausting its
    /// retransmit budget). `ingress = transmitted + shed + link_dropped`.
    pub link_dropped: u64,
    /// Bytes serialized onto the shedder→backend link (actual wire
    /// sizes; raw-u8 equivalent under an ideal link).
    pub bytes_on_wire: u64,
    /// Total measured shedder→backend transfer (ms) across delivered
    /// frames: link queue wait + serialization + propagation.
    pub transmit_ms_total: f64,
    /// Final virtual clock (ms).
    pub end_ms: f64,
    /// Total camera-side extraction wall time (ms) across all frames.
    pub extract_ms_total: f64,
    /// Fault / graceful-degradation counters (all zero on a faultless
    /// run). Conservation extends to `ingress == transmitted + shed +
    /// link_dropped + faults.fault_dropped`.
    pub faults: FaultStats,
    /// Online-adaptation counters + event log (all zero/empty when
    /// adaptation is disabled or never fired).
    pub adaptation: AdaptationStats,
}

impl PipelineReport {
    /// Fraction of ingress frames shed (the Eq. 19 output, as realized).
    pub fn observed_drop_rate(&self) -> f64 {
        if self.ingress == 0 {
            0.0
        } else {
            self.shed as f64 / self.ingress as f64
        }
    }

    /// Mean camera-side extraction latency per frame (ms).
    pub fn extract_ms_mean(&self) -> f64 {
        if self.ingress == 0 {
            0.0
        } else {
            self.extract_ms_total / self.ingress as f64
        }
    }

    /// Mean measured shedder→backend transfer per delivered frame (ms).
    pub fn transmit_ms_mean(&self) -> f64 {
        if self.transmitted == 0 {
            0.0
        } else {
            self.transmit_ms_total / self.transmitted as f64
        }
    }

    /// Mean wire bytes per frame that entered the link.
    pub fn bytes_per_wire_frame(&self) -> f64 {
        let n = self.transmitted + self.link_dropped;
        if n == 0 {
            0.0
        } else {
            self.bytes_on_wire as f64 / n as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Clock abstraction
// ---------------------------------------------------------------------------

/// The class of lifecycle event a clock is asked to pace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventClass {
    /// A frame arriving at the Load Shedder.
    Ingress,
    /// The backend finishing a frame.
    Completion,
}

/// Maps the core's virtual (stream-time) schedule onto execution.
///
/// The core processes events strictly in virtual-time order under every
/// clock; a clock only decides *when in the real world* each event is
/// applied and how end-to-end latency is measured. Per-frame shed and
/// transmit decisions are therefore clock-invariant.
pub trait Clock {
    /// Block (wall clocks) until the event at virtual `t_ms` is due.
    fn advance_to(&mut self, t_ms: f64, class: EventClass);

    /// End-to-end latency (stream-time ms) for a frame captured at
    /// `capture_ms` whose completion event fires at virtual `done_ms`.
    fn measure_e2e(&mut self, capture_ms: f64, done_ms: f64) -> f64;
}

/// Discrete-event clock: virtual time advances instantly.
pub struct SimClock;

impl Clock for SimClock {
    fn advance_to(&mut self, _t_ms: f64, _class: EventClass) {}

    fn measure_e2e(&mut self, capture_ms: f64, done_ms: f64) -> f64 {
        done_ms - capture_ms
    }
}

/// Wall clock: virtual time t maps to wall time `t0 + t × time_scale`
/// (1.0 = real time, 0.1 = 10× fast-forward). Latency is *measured* from
/// the wall clock and descaled back to stream time.
pub struct WallClock {
    t0: Instant,
    time_scale: f64,
    /// When false, completion events are applied as soon as the event
    /// order allows (pure compute speed — cost emulation off); ingress
    /// pacing still follows the stream timestamps.
    pace_completions: bool,
}

impl WallClock {
    /// Anchor the clock at "now" with the given stream→wall scale
    /// (completion pacing on — see [`Self::with_completion_pacing`]).
    pub fn new(time_scale: f64) -> Self {
        WallClock { t0: Instant::now(), time_scale, pace_completions: true }
    }

    /// Enable/disable wall pacing of backend completions (cost emulation).
    pub fn with_completion_pacing(mut self, on: bool) -> Self {
        self.pace_completions = on;
        self
    }
}

impl Clock for WallClock {
    fn advance_to(&mut self, t_ms: f64, class: EventClass) {
        if self.time_scale <= 0.0 {
            return;
        }
        if class == EventClass::Completion && !self.pace_completions {
            return;
        }
        let due = Duration::from_secs_f64(t_ms / 1000.0 * self.time_scale);
        let elapsed = self.t0.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
    }

    fn measure_e2e(&mut self, capture_ms: f64, done_ms: f64) -> f64 {
        if self.time_scale <= 0.0 {
            return done_ms - capture_ms;
        }
        // Wall elapsed since the frame's capture instant, descaled.
        let capture_wall_s = capture_ms / 1000.0 * self.time_scale;
        let now_s = self.t0.elapsed().as_secs_f64();
        (now_s - capture_wall_s).max(0.0) * 1000.0 / self.time_scale
    }
}

// ---------------------------------------------------------------------------
// Arrival model (workload) abstraction
// ---------------------------------------------------------------------------

/// A workload: a stream of frames in nondecreasing `ts_ms` order plus its
/// nominal aggregate rate. Implementations live in
/// [`crate::pipeline::workloads`]; a new scenario is a new impl.
pub trait ArrivalModel {
    /// Next frame, or `None` when the stream ends. Frames MUST be emitted
    /// in nondecreasing `ts_ms` order.
    fn next_frame(&mut self) -> Option<Frame>;

    /// Nominal aggregate ingress rate (frames/sec) — seeds the Eq. 19
    /// rate-estimator fallback before measurements warm up.
    fn fps_total(&self) -> f64;
}

// ---------------------------------------------------------------------------
// Backend executor abstraction
// ---------------------------------------------------------------------------

/// How dispatched frames run through the backend query.
pub trait BackendExecutor {
    /// Run (or plan) the query for a dispatched frame. Returns the deepest
    /// stage reached and the execution time (ms) charged to the backend.
    /// Called in dispatch order; cost-model sampling order is part of the
    /// contract (drivers with split planners must preserve it).
    fn submit(&mut self, payload: FramePayload, background: &[f32]) -> anyhow::Result<(Stage, f64)>;

    /// The completion event for a submitted frame fired. `seq` is the
    /// frame's 0-based dispatch ordinal (the n-th `submit` call), so
    /// executors can pair each completion with the right outstanding
    /// submission even when `backend_tokens > 1` reorders completions;
    /// `dnn` is true when that frame reached the DNN stage. Wall
    /// executors rendezvous with their worker thread here.
    fn on_complete(&mut self, seq: u64, dnn: bool) -> anyhow::Result<()>;

    /// A **measured** network sample for the frame whose completion just
    /// rendezvoused: `(camera→shedder ms, shedder→backend ms)`, pulled by
    /// the core right after [`Self::on_complete`] and fed to
    /// `ControlLoop::observe_network` in place of a modeled-link sample.
    /// Only executors that move frames over a real transport return
    /// `Some` (see [`crate::pipeline::reactor`]); the default `None`
    /// leaves the control loop untouched, keeping modeled/sync executors
    /// bit-identical to the pre-hook engine.
    fn take_network_sample(&mut self, _seq: u64) -> Option<(f64, f64)> {
        None
    }

    /// Stream ended and every completion has been applied.
    fn finish(&mut self) -> anyhow::Result<()>;
}

/// Synchronous in-process executor over a [`BackendQuery`] — the
/// discrete-event drivers' backend.
pub struct SyncBackend<'a> {
    backend: &'a mut BackendQuery,
}

impl<'a> SyncBackend<'a> {
    /// Wrap a backend query for synchronous in-event execution.
    pub fn new(backend: &'a mut BackendQuery) -> Self {
        SyncBackend { backend }
    }
}

impl BackendExecutor for SyncBackend<'_> {
    fn submit(
        &mut self,
        payload: FramePayload,
        background: &[f32],
    ) -> anyhow::Result<(Stage, f64)> {
        let r = self
            .backend
            .process(&payload.rgb, background, payload.width, payload.height)?;
        Ok((r.last_stage, r.exec_ms))
    }

    fn on_complete(&mut self, _seq: u64, _dnn: bool) -> anyhow::Result<()> {
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Event queue
// ---------------------------------------------------------------------------

/// Deferred ground-truth label riding on a completion event: the
/// detector's verdict for the frame becomes visible to the online
/// adapter `label_delay_ms` after the completion fires.
type CompletionLabel = (u32 /* camera */, Box<FrameFeatures>, bool /* positive */);

enum EventKind {
    Ingress(Box<FramePayload>, f32 /* utility */),
    Completion {
        seq: u64,
        capture_ms: f64,
        exec_ms: f64,
        dnn: bool,
        /// `Some` only when online adaptation is enabled.
        label: Option<CompletionLabel>,
    },
    /// A frame destroyed by an injected fault. `release_token = false`
    /// for frames that never reached the shedder (camera dropout, at
    /// capture time); `true` for in-flight frames lost to a crashed
    /// worker — the event fires at the recovery time, returns the
    /// backend token the doomed dispatch held, and marks progress (the
    /// supervised restart discovering its lost work).
    FaultDrop { camera: u32, capture_ms: f64, ids: Vec<u64>, release_token: bool },
}

/// Event heap keyed by (µs time, seq); payloads in a side map. Generic
/// over the event kind so the single- and multi-query engines share the
/// deterministic near-tie ordering rules.
pub(crate) struct EventQueue<K> {
    heap: BinaryHeap<Reverse<(u64, u64)>>,
    events: HashMap<u64, (f64, K)>,
    seq: u64,
}

impl<K> EventQueue<K> {
    pub(crate) fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), events: HashMap::new(), seq: 0 }
    }

    pub(crate) fn push(&mut self, t: f64, kind: K) {
        // µs-resolution ordering key. Rounding (not truncation) keeps
        // near-tie events deterministic across platforms; negative or
        // non-finite timestamps are a scheduling bug upstream.
        debug_assert!(
            t.is_finite() && t >= 0.0,
            "event time must be finite and non-negative, got {t}"
        );
        let key = (t.max(0.0) * 1e3).round() as u64;
        self.seq += 1;
        self.heap.push(Reverse((key, self.seq)));
        self.events.insert(self.seq, (t, kind));
    }

    pub(crate) fn pop(&mut self) -> Option<(f64, K)> {
        let Reverse((_, id)) = self.heap.pop()?;
        // Invariant: `push` inserts the payload under the same seq it
        // pushes onto the heap, and ids are never reused — a miss here is
        // queue corruption, not a recoverable condition.
        #[allow(clippy::expect_used)]
        Some(self.events.remove(&id).expect("event payload"))
    }
}

// ---------------------------------------------------------------------------
// The lifecycle engine
// ---------------------------------------------------------------------------

/// Arrival-side state: reused extraction buffers + target-id recycling.
/// After warmup the feed path performs no per-frame heap allocation beyond
/// the frames the arrival model materializes (and one Box per frame to
/// keep the event enum small).
struct ArrivalFeeder {
    feat_buf: FrameFeatures,
    util_buf: UtilityValues,
    id_pool: Vec<Vec<u64>>,
    extract_ms_total: f64,
    /// Last delivered pixels per camera — only populated when the fault
    /// plan contains a camera-freeze window (a frozen camera keeps
    /// streaming these stale pixels while the scene moves on).
    last_rgb: HashMap<u32, Vec<f32>>,
}

impl ArrivalFeeder {
    fn new() -> Self {
        ArrivalFeeder {
            feat_buf: FrameFeatures::empty(),
            util_buf: UtilityValues::empty(),
            id_pool: Vec::new(),
            extract_ms_total: 0.0,
            last_rgb: HashMap::new(),
        }
    }

    /// Retire a frame's recyclable target-id buffer into the pool.
    fn recycle(&mut self, mut ids: Vec<u64>) {
        ids.clear();
        if self.id_pool.len() < 64 {
            self.id_pool.push(ids);
        }
    }

    /// Feed the next arrival from the (ts-ordered) workload into the heap:
    /// capture → camera-side extract → network → LS-ingress event.
    fn feed_next(
        &mut self,
        eq: &mut EventQueue<EventKind>,
        arrivals: &mut impl ArrivalModel,
        backgrounds: &BackgroundMap<'_>,
        extractor: &Extractor,
        query: &QueryConfig,
        cost: &mut crate::backend::CostModel,
        faults: &FaultPlan,
        want_features: bool,
    ) -> anyhow::Result<bool> {
        let Some(mut f) = arrivals.next_frame() else {
            return Ok(false);
        };
        // Fault: camera dropout — the frame never leaves the device. No
        // extraction, no cost-model draws (the RNG sequences stay aligned
        // with the healthy stream); the frame is accounted at its capture
        // time as `fault_dropped`.
        if faults.camera_dropped(f.camera, f.ts_ms) {
            let mut ids = self.id_pool.pop().unwrap_or_default();
            f.target_ids_into(&query.colors, query.min_blob_px, &mut ids);
            eq.push(
                f.ts_ms,
                EventKind::FaultDrop {
                    camera: f.camera,
                    capture_ms: f.ts_ms,
                    ids,
                    release_token: false,
                },
            );
            return Ok(true);
        }
        // Fault: camera freeze — stale pixels, live ground truth. The
        // retention buffer only exists when the plan has freeze windows,
        // so the empty plan clones nothing.
        if faults.has_camera_freeze() {
            if faults.camera_frozen(f.camera, f.ts_ms) {
                if let Some(prev) = self.last_rgb.get(&f.camera) {
                    f.rgb.clear();
                    f.rgb.extend_from_slice(prev);
                }
            } else {
                let slot = self.last_rgb.entry(f.camera).or_default();
                slot.clear();
                slot.extend_from_slice(&f.rgb);
            }
        }
        let bg = *backgrounds
            .get(&f.camera)
            .ok_or_else(|| anyhow::anyhow!("no background for camera {}", f.camera))?;
        // Camera-aware: engages the per-camera incremental tile engine
        // when the extractor has one (bit-identical either way), else the
        // stateless fused path.
        let te = Instant::now();
        extractor.extract_camera_into(
            f.camera,
            f.width,
            f.height,
            &f.rgb,
            bg,
            &mut self.feat_buf,
            &mut self.util_buf,
        )?;
        self.extract_ms_total += te.elapsed().as_secs_f64() * 1e3;
        // Sampled in the historical order (camera, then cam→LS) so the
        // cost-RNG sequence is unchanged; the cam→LS sample rides on the
        // payload as this frame's measured camera→shedder transfer.
        let cam_ms = cost.camera_ms();
        let net_cam_ls_ms = cost.net_cam_ls_ms();
        let t_ls = f.ts_ms + cam_ms + net_cam_ls_ms;
        let mut ids = self.id_pool.pop().unwrap_or_default();
        f.target_ids_into(&query.colors, query.min_blob_px, &mut ids);
        let payload = FramePayload {
            camera: f.camera,
            capture_ms: f.ts_ms,
            target_ids: ids,
            admitted: QueryMask::single(0),
            net_cam_ls_ms,
            rgb: f.rgb,
            width: f.width,
            height: f.height,
            features: want_features.then(|| Box::new(self.feat_buf.clone())),
        };
        eq.push(t_ls, EventKind::Ingress(Box::new(payload), self.util_buf.combined));
        Ok(true)
    }
}

/// Run the shared frame lifecycle over a workload, under a clock, against
/// a backend executor. Every driver (`run_sim`, `run_realtime`,
/// `run_sharded_sim`) is a thin wrapper around this function.
pub fn run_pipeline<A, E, C>(
    mut arrivals: A,
    backgrounds: &BackgroundMap<'_>,
    cfg: &SimConfig,
    extractor: &Extractor,
    executor: &mut E,
    clock: &mut C,
) -> anyhow::Result<PipelineReport>
where
    A: ArrivalModel,
    E: BackendExecutor,
    C: Clock,
{
    let mut rng = Rng::new(cfg.seed ^ 0x51B);
    let mut cost = crate::backend::CostModel::new(cfg.costs.clone(), cfg.seed ^ 0xCA11);
    let mut shedder: LoadShedder<FramePayload> = LoadShedder::new(
        &cfg.shedder,
        &cfg.costs,
        cfg.query.latency_bound_ms,
        cfg.fps_total,
    );
    let mut tokens = TokenBucket::new(cfg.backend_tokens.max(1));

    let mut qor = QorTracker::new();
    let mut latency = LatencyTracker::new(cfg.query.latency_bound_ms);
    let mut latency_windows = WindowSeries::new(5_000.0);
    let mut stages = StageCounts::new(5_000.0);
    let mut control_series = Vec::new();
    let mut decisions: Vec<FrameDecision> = Vec::new();
    let (mut ingress_n, mut transmitted, mut shed) = (0u64, 0u64, 0u64);
    let mut link_dropped = 0u64;
    let mut transport = TransportState::new(&cfg.transport, cfg.seed);

    // Online adaptation: constructed only when enabled, so the default
    // config adds no state, no feature clones and no per-frame work —
    // the frozen-model pipeline stays bit-identical.
    let mut adapter = cfg
        .adaptation
        .enabled
        .then(|| OnlineAdapter::new(cfg.adaptation.clone(), extractor.model().clone()));
    let want_features = adapter.is_some();
    let mut rescored: Vec<f32> = Vec::new();

    // Fault-injection + graceful-degradation state. With the default
    // empty plan and the default INFINITY watchdog/liveness thresholds
    // none of this is ever consulted beyond a cheap short-circuit, so
    // the faultless pipeline stays bit-identical.
    let faults = &cfg.faults;
    let mut fstats = FaultStats::default();
    // Watchdog: last virtual time the backend demonstrably made progress
    // (a completion applied or a crashed worker's token recovered).
    let mut last_progress = 0.0f64;
    // Declared degraded mode: entered when completions stall past the
    // watchdog with every token busy; threshold frozen, everything shed.
    let mut degraded_since: Option<f64> = None;
    let watchdog_on = cfg.shedder.watchdog_ms.is_finite();
    // Per-camera liveness: re-normalize the nominal fps when cameras
    // silently vanish (unplanned dropout) so Eq. 19's rate fallback
    // tracks the cameras actually alive.
    let liveness_on = cfg.shedder.camera_liveness_ms.is_finite();
    let mut last_seen: HashMap<u32, f64> = HashMap::new();
    let camera_total = backgrounds.len().max(1);
    let mut last_alive = camera_total;

    // Baseline policies pin the threshold themselves (the FIFO ablation
    // keeps the full control loop — only queue ordering changes).
    if matches!(cfg.policy, Policy::RandomRate { .. } | Policy::NoShedding) {
        shedder.auto_retune = false;
        shedder.admission.set_target_rate(0.0);
    }
    // Random-policy fixed rate (Eq. 19 with assumed proc_Q).
    let random_rate = match cfg.policy {
        Policy::RandomRate { assumed_proc_q_ms } => {
            crate::shedder::target_drop_rate(assumed_proc_q_ms, cfg.fps_total)
        }
        _ => 0.0,
    };

    let mut eq = EventQueue::new();
    let mut feeder = ArrivalFeeder::new();
    // Reused drop buffer: every frame shed by an ingress call — retune
    // evictions, displaced queue victims, and the offered frame itself —
    // lands here without per-frame cloning.
    let mut dropped: Vec<Entry<FramePayload>> = Vec::new();

    feeder.feed_next(
        &mut eq,
        &mut arrivals,
        backgrounds,
        extractor,
        &cfg.query,
        &mut cost,
        faults,
        want_features,
    )?;
    let mut now = 0.0f64;
    let mut last_control_sample = f64::NEG_INFINITY;
    // 0-based dispatch ordinal, incremented once per `submit` — executors
    // pair completions with submissions through it (see `on_complete`).
    let mut dispatch_seq = 0u64;

    while let Some((t, kind)) = eq.pop() {
        let class = match kind {
            EventKind::Ingress(..) => EventClass::Ingress,
            EventKind::Completion { .. } => EventClass::Completion,
            EventKind::FaultDrop { release_token, .. } => {
                if release_token {
                    EventClass::Completion
                } else {
                    EventClass::Ingress
                }
            }
        };
        clock.advance_to(t, class);
        now = now.max(t);
        match kind {
            EventKind::Ingress(frame, utility) => {
                ingress_n += 1;
                stages.observe(Stage::Ingress, frame.capture_ms);
                if liveness_on {
                    last_seen.insert(frame.camera, now);
                }
                // Refill the arrival pipeline.
                feeder.feed_next(
                    &mut eq,
                    &mut arrivals,
                    backgrounds,
                    extractor,
                    &cfg.query,
                    &mut cost,
                    faults,
                    want_features,
                )?;

                // Online adaptation: apply labels whose delay elapsed; a
                // swap or rollback re-anchors the admission CDF on the
                // new model's scores. Then score this frame with the
                // camera's live model — version 0 abstains, so until the
                // first swap the precomputed utility (and every frozen-
                // pipeline decision) stands untouched.
                let utility = match adapter.as_mut() {
                    Some(ad) => {
                        if ad.drain_due(now) {
                            ad.rescore_recent(&mut rescored);
                            shedder.reseed_history(&rescored);
                            ad.record_reseed();
                        }
                        match frame.features.as_deref() {
                            Some(feats) => {
                                ad.observe_ingress(frame.camera, feats);
                                ad.utility_for(frame.camera, feats).unwrap_or(utility)
                            }
                            None => utility,
                        }
                    }
                    None => utility,
                };

                // Watchdog: completions have stalled past the threshold
                // with every backend token busy — declare degraded mode.
                if watchdog_on
                    && degraded_since.is_none()
                    && tokens.available() == 0
                    && now - last_progress > cfg.shedder.watchdog_ms
                {
                    degraded_since = Some(now);
                }
                if degraded_since.is_some() {
                    // Degraded mode: freeze the threshold (the shedder is
                    // bypassed entirely, so no retune and no EWMA drift)
                    // and shed everything until progress resumes.
                    let f = *frame;
                    qor.observe(&f.target_ids, false);
                    stages.observe(Stage::Shed, f.capture_ms);
                    decisions.push(FrameDecision {
                        camera: f.camera,
                        capture_ms: f.capture_ms,
                        kept: false,
                    });
                    shed += 1;
                    fstats.degraded_shed += 1;
                    feeder.recycle(f.target_ids);
                } else {
                    // Content-agnostic baseline: coin flip ahead of the
                    // queue; surviving frames get a constant utility
                    // (FIFO service).
                    let coin_dropped = matches!(cfg.policy, Policy::RandomRate { .. })
                        && rng.chance(random_rate);
                    if coin_dropped {
                        let f = *frame;
                        qor.observe(&f.target_ids, false);
                        stages.observe(Stage::Shed, f.capture_ms);
                        decisions.push(FrameDecision {
                            camera: f.camera,
                            capture_ms: f.capture_ms,
                            kept: false,
                        });
                        shed += 1;
                        feeder.recycle(f.target_ids);
                    } else {
                        // (admission utility, queue-ordering key) per policy.
                        let (u, key) = match cfg.policy {
                            Policy::UtilityControlLoop => (utility, utility),
                            Policy::FifoControlLoop => (utility, 0.5),
                            _ => (0.5, 0.5),
                        };
                        dropped.clear();
                        let _ =
                            shedder.on_ingress_keyed_into(u, key, now, *frame, &mut dropped);
                        for e in dropped.drain(..) {
                            qor.observe(&e.item.target_ids, false);
                            stages.observe(Stage::Shed, e.item.capture_ms);
                            decisions.push(FrameDecision {
                                camera: e.item.camera,
                                capture_ms: e.item.capture_ms,
                                kept: false,
                            });
                            shed += 1;
                            feeder.recycle(e.item.target_ids);
                        }
                    }
                }

                // Control-series sampling (1 s cadence).
                if now - last_control_sample >= 1_000.0 {
                    control_series.push((now, shedder.threshold(), shedder.target_rate()));
                    last_control_sample = now;
                    // Per-camera liveness: when the set of live cameras
                    // changes, re-normalize the nominal fps fallback to
                    // the share of cameras actually heard from.
                    if liveness_on {
                        let alive = backgrounds
                            .keys()
                            .filter(|c| {
                                now - last_seen.get(c).copied().unwrap_or(0.0)
                                    <= cfg.shedder.camera_liveness_ms
                            })
                            .count();
                        if alive != last_alive && alive > 0 {
                            shedder.set_nominal_fps(
                                cfg.fps_total * alive as f64 / camera_total as f64,
                            );
                            fstats.liveness_renorms += 1;
                            last_alive = alive;
                        }
                    }
                }
            }
            EventKind::Completion { seq, capture_ms, exec_ms, dnn, label } => {
                tokens.release();
                last_progress = now;
                if let Some(since) = degraded_since.take() {
                    // Progress resumed: close the declared degraded span.
                    fstats.degraded_windows.push((since, now));
                }
                // Fault: poisoned control observation — the backend-time
                // sample the control loop sees is corrupted (NaN) or
                // stale (a negative clock-skewed duration). The loop's
                // input validation must reject it; the *metrics* latency
                // below stays honest.
                let observed_ms = match faults.poison(now) {
                    Some(PoisonKind::Nan) => f64::NAN,
                    Some(PoisonKind::Stale) => -exec_ms.max(1.0),
                    None => exec_ms,
                };
                shedder.on_backend_complete(observed_ms);
                executor.on_complete(seq, dnn)?;
                // Reactor-mode executors measured this frame's *real*
                // socket transfer during the rendezvous above; it enters
                // the Eq. 19/20 budget here, in place of a modeled-link
                // sample (default executors return None — no-op).
                if let Some((cam_ms, tx_ms)) = executor.take_network_sample(seq) {
                    shedder.control.observe_network(cam_ms, tx_ms);
                }
                // The detector's verdict becomes ground truth for the
                // online adapter after the annotation delay.
                if let (Some(ad), Some((camera, feats, positive))) = (adapter.as_mut(), label) {
                    ad.enqueue_label(t + ad.config().label_delay_ms, camera, *feats, positive);
                }
                let e2e = clock.measure_e2e(capture_ms, t);
                latency.observe(e2e);
                latency_windows.observe(capture_ms, e2e);
            }
            EventKind::FaultDrop { camera, capture_ms, ids, release_token } => {
                if release_token {
                    // A crashed worker's in-flight frame: the restart
                    // recovered the backend slot and discovered the loss.
                    tokens.release();
                    last_progress = now;
                    if let Some(since) = degraded_since.take() {
                        fstats.degraded_windows.push((since, now));
                    }
                } else {
                    // Camera dropout: the frame is accounted at capture.
                    ingress_n += 1;
                    stages.observe(Stage::Ingress, capture_ms);
                    feeder.feed_next(
                        &mut eq,
                        &mut arrivals,
                        backgrounds,
                        extractor,
                        &cfg.query,
                        &mut cost,
                        faults,
                        want_features,
                    )?;
                }
                fstats.fault_dropped += 1;
                qor.observe(&ids, false);
                stages.observe(Stage::Shed, capture_ms);
                decisions.push(FrameDecision { camera, capture_ms, kept: false });
                feeder.recycle(ids);
            }
        }

        // Start services while tokens and frames are available.
        while tokens.available() > 0 {
            let Some(entry) = shedder.next_to_send() else { break };
            // Transmission-time deadline check: a frame whose expected
            // completion (Eq. 20 terms) already exceeds LB is doomed —
            // shed it instead of burning backend time (utility ordering
            // can starve low-utility frames through a burst). The network
            // term is the control loop's EWMA: exactly the configured
            // constant under an ideal link, the measured link latency
            // (congestion included) under a constrained one.
            let expected_done =
                now + shedder.control.net_ls_q_ms() + shedder.control.proc_q_ms();
            if expected_done - entry.item.capture_ms > cfg.query.latency_bound_ms {
                qor.observe(&entry.item.target_ids, false);
                stages.observe(Stage::Shed, entry.item.capture_ms);
                decisions.push(FrameDecision {
                    camera: entry.item.camera,
                    capture_ms: entry.item.capture_ms,
                    kept: false,
                });
                shed += 1;
                feeder.recycle(entry.item.target_ids);
                continue;
            }
            // Fault: link blackout — the wire is down, the frame is lost
            // before the backend ever sees it. No token is consumed.
            if faults.link_blackout(now) {
                let mut f = entry.item;
                fstats.fault_dropped += 1;
                qor.observe(&f.target_ids, false);
                stages.observe(Stage::Shed, f.capture_ms);
                decisions.push(FrameDecision {
                    camera: f.camera,
                    capture_ms: f.capture_ms,
                    kept: false,
                });
                feeder.recycle(std::mem::take(&mut f.target_ids));
                continue;
            }
            // Fault: backend worker crash — the dispatched frame dies with
            // the worker and the backend slot stays occupied until the
            // restart completes at the window's end; a `FaultDrop` event
            // scheduled there releases the token and books the loss.
            if let Some(recover_at) = faults.worker_down_until(now) {
                assert!(tokens.try_acquire());
                let mut f = entry.item;
                eq.push(
                    recover_at.max(now),
                    EventKind::FaultDrop {
                        camera: f.camera,
                        capture_ms: f.capture_ms,
                        ids: std::mem::take(&mut f.target_ids),
                        release_token: true,
                    },
                );
                continue;
            }
            assert!(tokens.try_acquire());
            let mut f = entry.item;
            let capture_ms = f.capture_ms;
            // Transmit stage: the frame leaves the shedder for the link.
            stages.observe(Stage::Transmit, capture_ms);
            // Fault: bandwidth collapse forces the modeled-link path even
            // on an ideal link (the collapse *is* a modeled link).
            let bw_override = faults.bandwidth_override(now);
            let arrival_ms = if transport.is_ideal() && bw_override.is_none() {
                // Byte accounting only — the legacy cost-model draw below
                // keeps the pre-transport RNG sequence bit-identical.
                transport.account_ideal(&f);
                None
            } else {
                let tx = transport.ship(now, &f, bw_override);
                if !tx.delivered {
                    // Lost on the wire after bounded retransmits: the
                    // backend never sees it; the token frees immediately.
                    tokens.release();
                    link_dropped += 1;
                    qor.observe(&f.target_ids, false);
                    stages.observe(Stage::Shed, capture_ms);
                    decisions.push(FrameDecision {
                        camera: f.camera,
                        capture_ms,
                        kept: false,
                    });
                    feeder.recycle(std::mem::take(&mut f.target_ids));
                    continue;
                }
                // Feed the measured pair into the control loop: Eq. 20's
                // queue sizing and Eq. 19's effective service time now
                // see real link congestion.
                shedder.control.observe_network(f.net_cam_ls_ms, tx.transfer_ms);
                Some(tx.arrival_ms)
            };
            transmitted += 1;
            qor.observe(&f.target_ids, true);
            decisions.push(FrameDecision {
                camera: f.camera,
                capture_ms: f.capture_ms,
                kept: true,
            });
            // Delayed ground truth for the online adapter: the backend's
            // verdict ("a target was present") is captured here and
            // delivered `label_delay_ms` after the completion fires.
            // Only transmitted frames ever produce a label — exactly the
            // feedback a real deployment has.
            let label = f
                .features
                .take()
                .map(|feats| (f.camera, feats, !f.target_ids.is_empty()));
            feeder.recycle(std::mem::take(&mut f.target_ids));
            let bg = *backgrounds
                .get(&f.camera)
                .ok_or_else(|| anyhow::anyhow!("no background for camera {}", f.camera))?;
            let (last_stage, exec_ms) = executor.submit(f, bg)?;
            // Fault: straggler slowdown — the backend's service time is
            // inflated while the window covers the dispatch instant. The
            // `!= 1.0` guard keeps the faultless arithmetic untouched.
            let slow = faults.slowdown(now);
            let exec_ms = if slow != 1.0 { exec_ms * slow } else { exec_ms };
            // Stage bookkeeping: every transmitted frame reaches the blob
            // filter; deeper stages per the result.
            stages.observe(Stage::BlobFilter, capture_ms);
            if last_stage >= Stage::ColorFilter {
                stages.observe(Stage::ColorFilter, capture_ms);
            }
            let dnn = last_stage == Stage::Sink;
            if dnn {
                // Color-filter pass implies the DNN ran, then the sink.
                stages.observe(Stage::Dnn, capture_ms);
                stages.observe(Stage::Sink, capture_ms);
            }
            let seq = dispatch_seq;
            dispatch_seq += 1;
            let done_at = match arrival_ms {
                // Ideal link: the historical constant-latency hop.
                None => now + cost.net_ls_q_ms() + exec_ms,
                // Modeled link: backend work starts when the frame lands.
                Some(a) => a + exec_ms,
            };
            eq.push(done_at, EventKind::Completion { seq, capture_ms, exec_ms, dnn, label });
        }
    }
    executor.finish()?;

    // A degraded span still open at stream end is closed at `now` so the
    // report always declares every degraded interval.
    if let Some(since) = degraded_since.take() {
        fstats.degraded_windows.push((since, now));
    }
    fstats.poisoned_rejected = shedder.control.rejected_samples();

    Ok(PipelineReport {
        qor,
        latency,
        latency_windows,
        stages,
        control_series,
        decisions,
        ingress: ingress_n,
        transmitted,
        shed,
        link_dropped,
        faults: fstats,
        adaptation: adapter.map(OnlineAdapter::into_stats).unwrap_or_default(),
        bytes_on_wire: transport.bytes_on_wire,
        transmit_ms_total: transport.transmit_ms_total,
        end_ms: now,
        extract_ms_total: feeder.extract_ms_total,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // test assertions
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_sequence() {
        let mk = || EventKind::Completion {
            seq: 0,
            capture_ms: 0.0,
            exec_ms: 1.0,
            dnn: false,
            label: None,
        };
        let mut eq = EventQueue::new();
        eq.push(5.0, mk());
        eq.push(1.0, mk());
        eq.push(5.0, mk());
        eq.push(3.0, mk());
        let times: Vec<f64> = std::iter::from_fn(|| eq.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0, 5.0]);
    }

    #[test]
    fn event_queue_rounds_keys_for_near_ties() {
        // Two timestamps separated only by sub-µs float noise must order
        // by insertion sequence, not by that noise: 2.0010000001 ms and
        // 2.0009999999 ms both round to the 2001 µs key (truncation would
        // split them into 2001 vs 2000 and pop the *later-inserted* event
        // first, purely because of the noise).
        let mut eq = EventQueue::new();
        eq.push(
            2.001_000_000_1,
            EventKind::Completion { seq: 0, capture_ms: 1.0, exec_ms: 1.0, dnn: false, label: None },
        );
        eq.push(
            2.000_999_999_9,
            EventKind::Completion { seq: 1, capture_ms: 2.0, exec_ms: 1.0, dnn: true, label: None },
        );
        let (_, first) = eq.pop().unwrap();
        match first {
            EventKind::Completion { capture_ms, .. } => assert_eq!(capture_ms, 1.0),
            _ => panic!("wrong event"),
        }
        let (_, second) = eq.pop().unwrap();
        match second {
            EventKind::Completion { dnn, .. } => assert!(dnn),
            _ => panic!("wrong event"),
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "finite and non-negative"))]
    fn event_queue_rejects_bad_times_in_debug() {
        let mut eq = EventQueue::new();
        eq.push(
            -1.0,
            EventKind::Completion { seq: 0, capture_ms: 0.0, exec_ms: 0.0, dnn: false, label: None },
        );
        // Release builds saturate to key 0 instead of wrapping: the event
        // still pops (first), deterministically.
        assert!(eq.pop().is_some());
    }

    #[test]
    fn sim_clock_measures_virtual_e2e() {
        let mut c = SimClock;
        assert_eq!(c.measure_e2e(100.0, 350.0), 250.0);
    }

    #[test]
    fn wall_clock_fast_forward_paces_and_measures() {
        let mut c = WallClock::new(1e-6); // effectively no sleeping
        c.advance_to(50.0, EventClass::Ingress);
        let e2e = c.measure_e2e(0.0, 10.0);
        assert!(e2e >= 0.0);
        // Degenerate scale falls back to virtual measurement.
        let mut z = WallClock::new(0.0);
        assert_eq!(z.measure_e2e(5.0, 30.0), 25.0);
    }
}
