//! Runtime-dispatched SIMD kernels for the per-pixel hot loops.
//!
//! The steady-state cost of the whole stack is three byte loops: the
//! fused counting kernel behind [`crate::features::fast`] /
//! [`crate::features::incremental`] (background gate + LUT classify +
//! histogram bump), the incremental engine's 16×16 tile diff, and the
//! dirty-tile scan in [`crate::video::wire`]'s delta encoder. This module
//! gives each an explicit SIMD path behind **runtime ISA detection**:
//!
//! * x86_64 — SSE2 unconditionally (part of the architecture baseline),
//!   AVX2 behind `is_x86_feature_detected!`;
//! * aarch64 — NEON unconditionally (part of the architecture baseline);
//! * anything else — the scalar kernels, which are also kept as the
//!   property-test oracle on every architecture.
//!
//! The toolchain is pinned to stable 1.85 (`rust-toolchain.toml`), so the
//! implementation uses stable `core::arch` intrinsics rather than the
//! still-unstable `std::simd`.
//!
//! ## Exactness
//!
//! Every wrapper here is **bit-identical to the scalar path on all
//! inputs** — the same bar as the LUT fast path and the incremental
//! engine. That is possible because all three kernels are integer-exact:
//!
//! * the counting kernel accumulates `u32` counts (integer adds commute,
//!   so lane order cannot change any total), and the per-pixel foreground
//!   gate `max(|Δr|,|Δg|,|Δb|) > floor` is equivalent to the byte-wise
//!   test `∃ channel: saturating_sub(|Δ|, floor) != 0`, evaluated with
//!   saturating-subtract/compare vectors;
//! * the quantizer's accept test ("is this f32 exactly an integer in
//!   0..=255?") is a truncating convert, a range check, and an exact f32
//!   compare per lane — any failing lane makes the whole call return
//!   `false`, exactly like the scalar early-out;
//! * the tile diff is pure byte equality.
//!
//! There is no float accumulation anywhere, so there is no reassociation
//! escape hatch to hide behind — and none is needed. The equivalence is
//! property-pinned by `rust/tests/simd.rs` at every [`Level`] available
//! on the host.
//!
//! ## Dispatch
//!
//! The [`Level`] is resolved **once** (env override first, then
//! detection) and cached in a `OnceLock`; hot-path callers go through
//! [`level`]. Every kernel also takes an explicit `Level` so tests and
//! benches can pin a path without re-resolving. The `UALS_SIMD`
//! environment variable (`scalar`, `sse2`, `avx2`, `neon`) forces a
//! level — for bisecting a regression to an ISA path, or for running the
//! scalar oracle in CI on any runner. Invalid or unsupported values are
//! rejected with a clear error instead of being silently ignored.
//!
//! ## Tail handling
//!
//! Vector loops consume whole 16/32-pixel (or byte) blocks per row of
//! the target rect; the ragged remainder of each row is delegated to the
//! scalar kernel on a 1-row sub-rect, so awkward geometries (widths or
//! rect extents that are not multiples of the vector width, 1-px-wide
//! rects) share one code path with the oracle by construction.

mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

use crate::color::ColorLut;
#[cfg(target_arch = "x86_64")]
use crate::features::HIST;

/// A dirty/target rectangle in pixels: `(x0, y0, x1, y1)`, half-open.
pub type Rect = (usize, usize, usize, usize);

/// Instruction-set level a kernel call runs at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// The scalar byte loops — the oracle, available everywhere.
    Scalar,
    /// 128-bit x86 vectors; part of the x86_64 baseline.
    Sse2,
    /// 256-bit x86 vectors; runtime-detected.
    Avx2,
    /// 128-bit ARM vectors; part of the aarch64 baseline.
    Neon,
}

impl Level {
    /// Lowercase name, as accepted by the `UALS_SIMD` override and as
    /// recorded in `BENCH_micro.json`'s `"isa"` field.
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }

    /// Parse an override value (case-insensitive). Unknown values are an
    /// error naming the accepted set.
    pub fn parse(s: &str) -> Result<Level, String> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(Level::Scalar),
            "sse2" => Ok(Level::Sse2),
            "avx2" => Ok(Level::Avx2),
            "neon" => Ok(Level::Neon),
            _ => Err(format!(
                "invalid UALS_SIMD value {s:?}: expected one of scalar|sse2|avx2|neon"
            )),
        }
    }

    /// Can this level actually execute on the current host?
    pub fn is_supported(self) -> bool {
        match self {
            Level::Scalar => true,
            Level::Sse2 => cfg!(target_arch = "x86_64"),
            Level::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Level::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Every level the host can execute, scalar first (test matrices
    /// iterate this to pin SIMD == scalar at each reachable ISA).
    pub fn available() -> Vec<Level> {
        [Level::Scalar, Level::Sse2, Level::Avx2, Level::Neon]
            .into_iter()
            .filter(|l| l.is_supported())
            .collect()
    }

    /// The best level the host supports.
    pub fn detect() -> Level {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Level::Avx2
            } else {
                Level::Sse2
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            Level::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            Level::Scalar
        }
    }
}

/// Resolve the level from an optional `UALS_SIMD` override value:
/// `None` detects the best supported level; `Some` must name a level the
/// host supports. Split out of [`level`] so the policy is unit-testable
/// without touching process environment.
pub fn resolve(env_override: Option<&str>) -> Result<Level, String> {
    match env_override {
        None => Ok(Level::detect()),
        Some(s) => {
            let lvl = Level::parse(s)?;
            if lvl.is_supported() {
                Ok(lvl)
            } else {
                Err(format!(
                    "UALS_SIMD={s} requested but this host does not support it \
                     (available: {})",
                    Level::available()
                        .iter()
                        .map(|l| l.name())
                        .collect::<Vec<_>>()
                        .join("|")
                ))
            }
        }
    }
}

/// The process-wide dispatch level: `UALS_SIMD` override if set (a bad
/// value aborts with a clear message rather than silently running the
/// wrong path — regressions must be bisectable to an ISA), otherwise the
/// best detected level. Resolved once and cached.
pub fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| match resolve(std::env::var("UALS_SIMD").ok().as_deref()) {
        Ok(l) => l,
        Err(e) => panic!("{e}"),
    })
}

/// The per-pixel counting kernel over `rect` (half-open, row-major frame
/// of `width` px): background gate + LUT classify + histogram bump.
/// `pf` (`k*HIST`) and `in_color` (`k`) accumulate in place; returns the
/// foreground-pixel count. Bit-identical to [`Level::Scalar`] at every
/// level; panics if `level` is not supported on this host.
#[allow(clippy::too_many_arguments)]
pub fn count_rect(
    level: Level,
    lut: &ColorLut,
    frame: &[u8],
    bg: &[u8],
    width: usize,
    rect: Rect,
    k: usize,
    pf: &mut [u32],
    in_color: &mut [u32],
) -> u32 {
    assert!(level.is_supported(), "SIMD level {} unsupported on this host", level.name());
    match level {
        Level::Scalar => scalar::count_rect(lut, frame, bg, width, rect, k, pf, in_color),
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => x86::count_rect_sse2(lut, frame, bg, width, rect, k, pf, in_color),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `is_supported` verified AVX2 via runtime detection.
        Level::Avx2 => unsafe {
            x86::count_rect_avx2(lut, frame, bg, width, rect, k, pf, in_color)
        },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => neon::count_rect(lut, frame, bg, width, rect, k, pf, in_color),
        _ => unreachable!("supported level must have a kernel"),
    }
}

/// Quantize `src` into `dst` (cleared first); returns `false` — with
/// `dst` content unspecified — as soon as any channel is not exactly
/// representable as u8. Decision-identical to [`Level::Scalar`] at every
/// level; panics if `level` is not supported on this host.
pub fn quantize(level: Level, src: &[f32], dst: &mut Vec<u8>) -> bool {
    assert!(level.is_supported(), "SIMD level {} unsupported on this host", level.name());
    match level {
        Level::Scalar => scalar::quantize(src, dst),
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => x86::quantize_sse2(src, dst),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `is_supported` verified AVX2 via runtime detection.
        Level::Avx2 => unsafe { x86::quantize_avx2(src, dst) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => neon::quantize(src, dst),
        _ => unreachable!("supported level must have a kernel"),
    }
}

/// Do two frames differ anywhere inside `rect`? The memcmp-grade tile
/// test shared by the incremental feature engine's diff and the wire
/// delta encoder's dirty-tile scan. Bit-identical to [`Level::Scalar`]
/// at every level; panics if `level` is not supported on this host.
pub fn rect_differs(level: Level, a: &[u8], b: &[u8], width: usize, rect: Rect) -> bool {
    assert!(level.is_supported(), "SIMD level {} unsupported on this host", level.name());
    match level {
        Level::Scalar => scalar::rect_differs(a, b, width, rect),
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => x86::rect_differs_sse2(a, b, width, rect),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `is_supported` verified AVX2 via runtime detection.
        Level::Avx2 => unsafe { x86::rect_differs_avx2(a, b, width, rect) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => neon::rect_differs(a, b, width, rect),
        _ => unreachable!("supported level must have a kernel"),
    }
}

/// Classify one surviving (foreground) pixel and bump the count vectors.
/// The scalar kernel's branchless `for c in 0..k` bump and this set-bit
/// iteration add exactly the same integers to the same slots — the mask
/// only has bits below `k` set, and `(mask >> c) & 1` is 1 precisely for
/// the bits iterated here. (Only the x86 kernels iterate survivor
/// bitmasks; NEON has no movemask and re-runs the scalar kernel on any
/// block with a foreground byte instead.)
#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn classify_survivor(
    lut: &ColorLut,
    r: u8,
    g: u8,
    b: u8,
    k: usize,
    pf: &mut [u32],
    in_color: &mut [u32],
) {
    let (mask, bin) = lut.classify(r, g, b);
    let mut m = (mask as u32) & ((1u32 << k) - 1);
    while m != 0 {
        let c = m.trailing_zeros() as usize;
        m &= m - 1;
        in_color[c] += 1;
        pf[c * HIST + bin as usize] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_every_level_name() {
        for (s, l) in [
            ("scalar", Level::Scalar),
            ("sse2", Level::Sse2),
            ("avx2", Level::Avx2),
            ("neon", Level::Neon),
            ("SCALAR", Level::Scalar),
            ("Avx2", Level::Avx2),
        ] {
            assert_eq!(Level::parse(s), Ok(l), "{s}");
        }
    }

    #[test]
    fn parse_rejects_unknown_values_with_a_clear_error() {
        for bad in ["", "sse", "avx512", "fast", "1"] {
            let err = Level::parse(bad).unwrap_err();
            assert!(err.contains("UALS_SIMD"), "error names the env var: {err}");
            assert!(err.contains("scalar|sse2|avx2|neon"), "error names the options: {err}");
        }
    }

    #[test]
    fn resolve_without_override_detects() {
        assert_eq!(resolve(None), Ok(Level::detect()));
        assert!(Level::detect().is_supported());
    }

    #[test]
    fn resolve_scalar_override_works_everywhere() {
        assert_eq!(resolve(Some("scalar")), Ok(Level::Scalar));
    }

    #[test]
    fn resolve_rejects_bad_override() {
        assert!(resolve(Some("bogus")).is_err());
    }

    #[test]
    fn resolve_rejects_unsupported_level() {
        // At least one of sse2/neon is foreign on any single host.
        let foreign = if cfg!(target_arch = "x86_64") { "neon" } else { "sse2" };
        let err = resolve(Some(foreign)).unwrap_err();
        assert!(err.contains("not support"), "{err}");
        assert!(err.contains("available:"), "{err}");
    }

    #[test]
    fn available_starts_with_scalar_and_is_supported() {
        let levels = Level::available();
        assert_eq!(levels[0], Level::Scalar);
        assert!(levels.contains(&Level::detect()));
        for l in levels {
            assert!(l.is_supported());
        }
    }

    #[test]
    fn cached_level_is_supported() {
        // Whatever the process resolved (incl. a UALS_SIMD override set
        // by the harness), it must be executable here.
        assert!(level().is_supported());
        assert_eq!(level(), level(), "resolution is cached and stable");
    }
}
