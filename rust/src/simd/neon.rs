//! aarch64 NEON kernels. NEON is part of the aarch64 baseline, so every
//! wrapper here is a safe fn.
//!
//! The counting kernel uses the same flat-byte trick as the x86 path
//! (`max(d0,d1,d2) > floor ⇔ ∃i: dᵢ > floor`, no RGB de-interleave),
//! with one NEON-shaped difference: there is no `movemask`, so blocks
//! are first screened with a horizontal max (`vmaxvq_u8`) — an
//! all-background block, the overwhelmingly common case on redundant
//! streams, is rejected in a handful of instructions — and a block
//! containing any foreground byte falls through to the scalar oracle
//! for exactly those 16 pixels.

use core::arch::aarch64::*;

use super::{scalar, Rect};
use crate::color::ColorLut;

/// NEON counting kernel: 16 pixels (48 bytes) screened per iteration.
#[allow(clippy::too_many_arguments)]
pub(super) fn count_rect(
    lut: &ColorLut,
    frame: &[u8],
    bg: &[u8],
    width: usize,
    rect: Rect,
    k: usize,
    pf: &mut [u32],
    in_color: &mut [u32],
) -> u32 {
    let floor = lut.fg_floor();
    if floor < 0 {
        // Every pixel is foreground: nothing for the gate to reject.
        return scalar::count_rect(lut, frame, bg, width, rect, k, pf, in_color);
    }
    let floor_u8 = floor.min(255) as u8;
    let (x0, y0, x1, y1) = rect;
    let n = x1.saturating_sub(x0);
    let mut fg = 0u32;
    // SAFETY: NEON is part of the aarch64 baseline; loads read 48 bytes
    // from `off`, in bounds by the `px + 16 <= n` loop condition.
    unsafe {
        let floor_v = vdupq_n_u8(floor_u8);
        for y in y0..y1 {
            let base = 3 * (y * width + x0);
            let mut px = 0usize;
            while px + 16 <= n {
                let off = base + 3 * px;
                let mut any = vdupq_n_u8(0);
                for v in 0..3 {
                    let f = vld1q_u8(frame.as_ptr().add(off + 16 * v));
                    let b = vld1q_u8(bg.as_ptr().add(off + 16 * v));
                    any = vorrq_u8(any, vcgtq_u8(vabdq_u8(f, b), floor_v));
                }
                if vmaxvq_u8(any) != 0 {
                    // Some byte in the block exceeds the floor: classify
                    // these 16 pixels through the scalar oracle.
                    fg += scalar::count_rect(
                        lut,
                        frame,
                        bg,
                        width,
                        (x0 + px, y, x0 + px + 16, y + 1),
                        k,
                        pf,
                        in_color,
                    );
                }
                px += 16;
            }
            if px < n {
                fg += scalar::count_rect(
                    lut,
                    frame,
                    bg,
                    width,
                    (x0 + px, y, x1, y + 1),
                    k,
                    pf,
                    in_color,
                );
            }
        }
    }
    fg
}

/// NEON exact-u8 quantizer: 16 f32 lanes per iteration. `vcvtq_s32_f32`
/// truncates toward zero (NaN → 0, saturating), so a lane passes iff
/// the convert round-trips exactly and the integer is in `0..=255` —
/// the scalar `q as f32 == x` accept test.
pub(super) fn quantize(src: &[f32], dst: &mut Vec<u8>) -> bool {
    let n = src.len();
    dst.clear();
    dst.resize(n, 0);
    let mut i = 0usize;
    // SAFETY: NEON is part of the aarch64 baseline; loads read
    // `src[i..i+16]`, the store writes `dst[i..i+16]`, in bounds by the
    // `i + 16 <= n` loop condition.
    unsafe {
        let zero = vdupq_n_s32(0);
        let lim = vdupq_n_s32(255);
        macro_rules! cvt_ok {
            ($x:expr) => {{
                let t = vcvtq_s32_f32($x);
                let exact = vceqq_f32(vcvtq_f32_s32(t), $x);
                let range = vandq_u32(vcgeq_s32(t, zero), vcleq_s32(t, lim));
                (t, vandq_u32(exact, range))
            }};
        }
        while i + 16 <= n {
            let x0 = vld1q_f32(src.as_ptr().add(i));
            let x1 = vld1q_f32(src.as_ptr().add(i + 4));
            let x2 = vld1q_f32(src.as_ptr().add(i + 8));
            let x3 = vld1q_f32(src.as_ptr().add(i + 12));
            let (t0, ok0) = cvt_ok!(x0);
            let (t1, ok1) = cvt_ok!(x1);
            let (t2, ok2) = cvt_ok!(x2);
            let (t3, ok3) = cvt_ok!(x3);
            let all = vandq_u32(vandq_u32(ok0, ok1), vandq_u32(ok2, ok3));
            if vminvq_u32(all) != u32::MAX {
                return false;
            }
            // Values are proven 0..=255: plain narrowing keeps the low
            // byte, which IS the value.
            let s16a = vcombine_s16(vmovn_s32(t0), vmovn_s32(t1));
            let s16b = vcombine_s16(vmovn_s32(t2), vmovn_s32(t3));
            let p8 = vcombine_u8(
                vreinterpret_u8_s8(vmovn_s16(s16a)),
                vreinterpret_u8_s8(vmovn_s16(s16b)),
            );
            vst1q_u8(dst.as_mut_ptr().add(i), p8);
            i += 16;
        }
    }
    for j in i..n {
        let x = src[j];
        let q = x as u8; // saturating cast; NaN → 0
        if q as f32 != x {
            return false;
        }
        dst[j] = q;
    }
    true
}

/// NEON rect compare: 16-byte XOR + horizontal-max blocks per row,
/// byte-slice tail.
pub(super) fn rect_differs(a: &[u8], b: &[u8], width: usize, rect: Rect) -> bool {
    let (x0, y0, x1, y1) = rect;
    let len = 3 * x1.saturating_sub(x0);
    // SAFETY: NEON is part of the aarch64 baseline; loads stay inside
    // `a[s..s+len]` / `b[s..s+len]` by the `off + 16 <= len` condition.
    unsafe {
        for y in y0..y1 {
            let s = 3 * (y * width + x0);
            let mut off = 0usize;
            while off + 16 <= len {
                let va = vld1q_u8(a.as_ptr().add(s + off));
                let vb = vld1q_u8(b.as_ptr().add(s + off));
                if vmaxvq_u8(veorq_u8(va, vb)) != 0 {
                    return true;
                }
                off += 16;
            }
            if a[s + off..s + len] != b[s + off..s + len] {
                return true;
            }
        }
    }
    false
}
