//! x86_64 kernels: SSE2 (always available — it is part of the x86_64
//! baseline, so the wrappers are safe fns) and AVX2 (`unsafe fn`s gated
//! by runtime detection in the dispatcher).
//!
//! The counting kernel's trick: the foreground gate never needs the
//! per-pixel channel *maximum*, only whether it exceeds the floor — and
//! `max(d0,d1,d2) > floor ⇔ ∃i: dᵢ > floor`, which is a flat byte-wise
//! test with no RGB de-interleave. Each 16/32-pixel block produces a
//! foreground bitmask (one bit per *byte*); surviving pixels — usually
//! few — are classified scalar via the shared LUT, which keeps the
//! result bit-identical to the oracle.

use core::arch::x86_64::*;

use super::{classify_survivor, scalar, Rect};
use crate::color::ColorLut;

/// SSE2 counting kernel: 16 pixels (48 bytes) per iteration.
#[allow(clippy::too_many_arguments)]
pub(super) fn count_rect_sse2(
    lut: &ColorLut,
    frame: &[u8],
    bg: &[u8],
    width: usize,
    rect: Rect,
    k: usize,
    pf: &mut [u32],
    in_color: &mut [u32],
) -> u32 {
    let floor = lut.fg_floor();
    if floor < 0 {
        // Every pixel is foreground: the vector gate can reject nothing,
        // so the scalar kernel (which skips the gate work) is optimal.
        return scalar::count_rect(lut, frame, bg, width, rect, k, pf, in_color);
    }
    let floor_u8 = floor.min(255) as u8;
    let (x0, y0, x1, y1) = rect;
    let n = x1.saturating_sub(x0);
    let mut fg = 0u32;
    // SAFETY: SSE2 is part of the x86_64 baseline; all loads are
    // unaligned (`loadu`) and stay in bounds: `off + 48 <= 3*(row+x1)
    // <= frame.len()` by the `px + 16 <= n` loop condition.
    unsafe {
        let floor_v = _mm_set1_epi8(floor_u8 as i8);
        let zero = _mm_setzero_si128();
        for y in y0..y1 {
            let base = 3 * (y * width + x0);
            let mut px = 0usize;
            while px + 16 <= n {
                let off = base + 3 * px;
                // 48 contiguous bytes → one fg bit per byte; pixel p is
                // foreground iff any of bits {3p, 3p+1, 3p+2} is set.
                let mut m = 0u64;
                for v in 0..3 {
                    let f = _mm_loadu_si128(frame.as_ptr().add(off + 16 * v) as *const __m128i);
                    let b = _mm_loadu_si128(bg.as_ptr().add(off + 16 * v) as *const __m128i);
                    let d = _mm_or_si128(_mm_subs_epu8(f, b), _mm_subs_epu8(b, f));
                    let gated = _mm_subs_epu8(d, floor_v);
                    let is_bg = _mm_cmpeq_epi8(gated, zero);
                    let fg_bits = !(_mm_movemask_epi8(is_bg) as u32) & 0xFFFF;
                    m |= (fg_bits as u64) << (16 * v);
                }
                while m != 0 {
                    let p = (m.trailing_zeros() / 3) as usize;
                    m &= !(0b111u64 << (3 * p));
                    let i = off + 3 * p;
                    fg += 1;
                    classify_survivor(lut, frame[i], frame[i + 1], frame[i + 2], k, pf, in_color);
                }
                px += 16;
            }
            // Scalar tail for the ragged right edge of the rect row.
            if px < n {
                fg += scalar::count_rect(
                    lut,
                    frame,
                    bg,
                    width,
                    (x0 + px, y, x1, y + 1),
                    k,
                    pf,
                    in_color,
                );
            }
        }
    }
    fg
}

/// AVX2 counting kernel: 32 pixels (96 bytes) per iteration, SSE2 +
/// scalar on the per-row tail.
///
/// # Safety
///
/// The host must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(super) unsafe fn count_rect_avx2(
    lut: &ColorLut,
    frame: &[u8],
    bg: &[u8],
    width: usize,
    rect: Rect,
    k: usize,
    pf: &mut [u32],
    in_color: &mut [u32],
) -> u32 {
    let floor = lut.fg_floor();
    if floor < 0 {
        return scalar::count_rect(lut, frame, bg, width, rect, k, pf, in_color);
    }
    let floor_u8 = floor.min(255) as u8;
    let (x0, y0, x1, y1) = rect;
    let n = x1.saturating_sub(x0);
    let mut fg = 0u32;
    let floor_v = _mm256_set1_epi8(floor_u8 as i8);
    let zero = _mm256_setzero_si256();
    for y in y0..y1 {
        let base = 3 * (y * width + x0);
        let mut px = 0usize;
        while px + 32 <= n {
            let off = base + 3 * px;
            let mut m = 0u128;
            for v in 0..3 {
                let f = _mm256_loadu_si256(frame.as_ptr().add(off + 32 * v) as *const __m256i);
                let b = _mm256_loadu_si256(bg.as_ptr().add(off + 32 * v) as *const __m256i);
                let d = _mm256_or_si256(_mm256_subs_epu8(f, b), _mm256_subs_epu8(b, f));
                let gated = _mm256_subs_epu8(d, floor_v);
                let is_bg = _mm256_cmpeq_epi8(gated, zero);
                let fg_bits = !(_mm256_movemask_epi8(is_bg) as u32);
                m |= (fg_bits as u128) << (32 * v);
            }
            while m != 0 {
                let p = (m.trailing_zeros() / 3) as usize;
                m &= !(0b111u128 << (3 * p));
                let i = off + 3 * p;
                fg += 1;
                classify_survivor(lut, frame[i], frame[i + 1], frame[i + 2], k, pf, in_color);
            }
            px += 32;
        }
        if px < n {
            fg += count_rect_sse2(lut, frame, bg, width, (x0 + px, y, x1, y + 1), k, pf, in_color);
        }
    }
    fg
}

/// SSE2 exact-u8 quantizer: 16 f32 lanes per iteration. A lane passes
/// iff truncation to i32 round-trips (`cvtepi32_ps(i) == x`, which NaN
/// and fractions fail) and the integer is in `0..=255` — exactly the
/// scalar `q as f32 == x` accept test.
pub(super) fn quantize_sse2(src: &[f32], dst: &mut Vec<u8>) -> bool {
    let n = src.len();
    dst.clear();
    dst.resize(n, 0);
    let mut i = 0usize;
    // SAFETY: SSE2 is part of the x86_64 baseline; unaligned loads read
    // `src[i..i+16]` and the store writes `dst[i..i+16]`, both in bounds
    // by the `i + 16 <= n` loop condition.
    unsafe {
        let neg1 = _mm_set1_epi32(-1);
        let lim = _mm_set1_epi32(256);
        while i + 16 <= n {
            let x0 = _mm_loadu_ps(src.as_ptr().add(i));
            let x1 = _mm_loadu_ps(src.as_ptr().add(i + 4));
            let x2 = _mm_loadu_ps(src.as_ptr().add(i + 8));
            let x3 = _mm_loadu_ps(src.as_ptr().add(i + 12));
            let t0 = _mm_cvttps_epi32(x0);
            let t1 = _mm_cvttps_epi32(x1);
            let t2 = _mm_cvttps_epi32(x2);
            let t3 = _mm_cvttps_epi32(x3);
            let ok = |t: __m128i, x: __m128| -> __m128i {
                let exact = _mm_castps_si128(_mm_cmpeq_ps(_mm_cvtepi32_ps(t), x));
                let range = _mm_and_si128(_mm_cmpgt_epi32(t, neg1), _mm_cmplt_epi32(t, lim));
                _mm_and_si128(exact, range)
            };
            let all = _mm_and_si128(
                _mm_and_si128(ok(t0, x0), ok(t1, x1)),
                _mm_and_si128(ok(t2, x2), ok(t3, x3)),
            );
            if _mm_movemask_ps(_mm_castsi128_ps(all)) != 0xF {
                return false;
            }
            let p16a = _mm_packs_epi32(t0, t1);
            let p16b = _mm_packs_epi32(t2, t3);
            let p8 = _mm_packus_epi16(p16a, p16b);
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, p8);
            i += 16;
        }
    }
    for j in i..n {
        let x = src[j];
        let q = x as u8; // saturating cast; NaN → 0
        if q as f32 != x {
            return false;
        }
        dst[j] = q;
    }
    true
}

/// AVX2 exact-u8 quantizer: 32 f32 lanes per iteration (the `packs` /
/// `packus` lane interleave is undone with `permute4x64(0b11011000)`).
///
/// # Safety
///
/// The host must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn quantize_avx2(src: &[f32], dst: &mut Vec<u8>) -> bool {
    let n = src.len();
    dst.clear();
    dst.resize(n, 0);
    let mut i = 0usize;
    let neg1 = _mm256_set1_epi32(-1);
    let lim = _mm256_set1_epi32(256);
    while i + 32 <= n {
        let x0 = _mm256_loadu_ps(src.as_ptr().add(i));
        let x1 = _mm256_loadu_ps(src.as_ptr().add(i + 8));
        let x2 = _mm256_loadu_ps(src.as_ptr().add(i + 16));
        let x3 = _mm256_loadu_ps(src.as_ptr().add(i + 24));
        let t0 = _mm256_cvttps_epi32(x0);
        let t1 = _mm256_cvttps_epi32(x1);
        let t2 = _mm256_cvttps_epi32(x2);
        let t3 = _mm256_cvttps_epi32(x3);
        // A macro, not a closure: on Rust 1.85 closures do not inherit
        // the enclosing fn's #[target_feature], which would block
        // inlining of the AVX2 intrinsics.
        macro_rules! lane_ok {
            ($t:expr, $x:expr) => {{
                let back = _mm256_cvtepi32_ps($t);
                let exact = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_EQ_OQ>(back, $x));
                let ge0 = _mm256_cmpgt_epi32($t, neg1);
                let le255 = _mm256_cmpgt_epi32(lim, $t);
                _mm256_and_si256(exact, _mm256_and_si256(ge0, le255))
            }};
        }
        let all = _mm256_and_si256(
            _mm256_and_si256(lane_ok!(t0, x0), lane_ok!(t1, x1)),
            _mm256_and_si256(lane_ok!(t2, x2), lane_ok!(t3, x3)),
        );
        if _mm256_movemask_ps(_mm256_castsi256_ps(all)) != 0xFF {
            return false;
        }
        let p16a = _mm256_permute4x64_epi64::<0b11011000>(_mm256_packs_epi32(t0, t1));
        let p16b = _mm256_permute4x64_epi64::<0b11011000>(_mm256_packs_epi32(t2, t3));
        let p8 = _mm256_permute4x64_epi64::<0b11011000>(_mm256_packus_epi16(p16a, p16b));
        _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p8);
        i += 32;
    }
    for j in i..n {
        let x = src[j];
        let q = x as u8; // saturating cast; NaN → 0
        if q as f32 != x {
            return false;
        }
        dst[j] = q;
    }
    true
}

/// SSE2 rect compare: 16-byte equality blocks per row, byte-slice tail.
pub(super) fn rect_differs_sse2(a: &[u8], b: &[u8], width: usize, rect: Rect) -> bool {
    let (x0, y0, x1, y1) = rect;
    let len = 3 * x1.saturating_sub(x0);
    // SAFETY: SSE2 is part of the x86_64 baseline; loads stay inside
    // `a[s..s+len]` / `b[s..s+len]` by the `off + 16 <= len` condition.
    unsafe {
        for y in y0..y1 {
            let s = 3 * (y * width + x0);
            let mut off = 0usize;
            while off + 16 <= len {
                let va = _mm_loadu_si128(a.as_ptr().add(s + off) as *const __m128i);
                let vb = _mm_loadu_si128(b.as_ptr().add(s + off) as *const __m128i);
                if _mm_movemask_epi8(_mm_cmpeq_epi8(va, vb)) != 0xFFFF {
                    return true;
                }
                off += 16;
            }
            if a[s + off..s + len] != b[s + off..s + len] {
                return true;
            }
        }
    }
    false
}

/// AVX2 rect compare: 32-byte equality blocks per row, byte-slice tail.
///
/// # Safety
///
/// The host must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn rect_differs_avx2(a: &[u8], b: &[u8], width: usize, rect: Rect) -> bool {
    let (x0, y0, x1, y1) = rect;
    let len = 3 * x1.saturating_sub(x0);
    for y in y0..y1 {
        let s = 3 * (y * width + x0);
        let mut off = 0usize;
        while off + 32 <= len {
            let va = _mm256_loadu_si256(a.as_ptr().add(s + off) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(s + off) as *const __m256i);
            if _mm256_movemask_epi8(_mm256_cmpeq_epi8(va, vb)) != -1 {
                return true;
            }
            off += 32;
        }
        if a[s + off..s + len] != b[s + off..s + len] {
            return true;
        }
    }
    false
}
