//! The scalar byte loops — the bit-level oracle every SIMD kernel is
//! property-pinned against (`rust/tests/simd.rs`), and the dispatch
//! target for [`super::Level::Scalar`] / architectures without a vector
//! path. The bodies are the pre-SIMD hot loops, kept verbatim.

use super::Rect;
use crate::color::ColorLut;
use crate::features::HIST;

/// Background gate + table classify + branchless histogram bump over
/// `rect` (half-open, in a row-major frame of `width` px per row).
/// `pf` (`k*HIST`) and `in_color` (`k`) accumulate in place; returns the
/// foreground-pixel count. u32 counts are exact for any frame below
/// 2³² px (and the final f32 conversion is only exact below 2²⁴ anyway).
#[allow(clippy::too_many_arguments)]
pub(super) fn count_rect(
    lut: &ColorLut,
    frame: &[u8],
    bg: &[u8],
    width: usize,
    rect: Rect,
    k: usize,
    pf: &mut [u32],
    in_color: &mut [u32],
) -> u32 {
    let (x0, y0, x1, y1) = rect;
    let mut fg = 0u32;
    for y in y0..y1 {
        let row = y * width;
        for x in x0..x1 {
            let i = 3 * (row + x);
            let (r, g, b) = (frame[i], frame[i + 1], frame[i + 2]);
            let diff = r
                .abs_diff(bg[i])
                .max(g.abs_diff(bg[i + 1]))
                .max(b.abs_diff(bg[i + 2]));
            if !lut.is_foreground(diff) {
                continue;
            }
            fg += 1;
            let (mask, bin) = lut.classify(r, g, b);
            // Branchless bump: each color adds 0 or 1 from its mask bit.
            for c in 0..k {
                let on = ((mask >> c) & 1) as u32;
                in_color[c] += on;
                pf[c * HIST + bin as usize] += on;
            }
        }
    }
    fg
}

/// Quantize `src` into `dst`; returns false (dst content unspecified) as
/// soon as a channel is not exactly representable as u8.
pub(super) fn quantize(src: &[f32], dst: &mut Vec<u8>) -> bool {
    dst.clear();
    dst.reserve(src.len());
    for &x in src {
        let q = x as u8; // saturating cast; NaN → 0
        if q as f32 != x {
            return false;
        }
        dst.push(q);
    }
    true
}

/// Row-slice compares over the rect, so the inner loop is memcmp-grade —
/// the pre-SIMD tile-diff strategy of the incremental feature engine and
/// the wire delta encoder.
pub(super) fn rect_differs(a: &[u8], b: &[u8], width: usize, rect: Rect) -> bool {
    let (x0, y0, x1, y1) = rect;
    for y in y0..y1 {
        let s = 3 * (y * width + x0);
        let e = 3 * (y * width + x1);
        if a[s..e] != b[s..e] {
            return true;
        }
    }
    false
}
