//! `uals` — CLI for the Utility-Aware Load Shedding reproduction.
//!
//! Subcommands:
//!   figures   regenerate the paper's evaluation figures (CSV + stdout)
//!   train     train a utility model on a synthetic dataset → JSON
//!   dataset   print per-video dataset statistics
//!   run       run the end-to-end simulated scenario and print a summary
//!   overhead  camera-side overhead breakdown (Fig. 15)
//!
//! Examples:
//!   uals figures --all --scale small
//!   uals figures --fig 9a --fig 10c --out results
//!   uals train --color red --out models/red.json
//!   uals run --scenario fig13a --scale small

use anyhow::{bail, Result};
use std::path::PathBuf;
use uals::cli::Args;
use uals::color::NamedColor;
use uals::experiments::{self, Scale, ALL_FIGURES, OVERHEAD_FIGURE, SCENARIOS};
use uals::utility::Combine;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("figures") => cmd_figures(&args),
        Some("train") => cmd_train(&args),
        Some("dataset") => cmd_dataset(&args),
        Some("run") => cmd_run(&args),
        Some("overhead") => {
            let scale = parse_scale(&args)?;
            experiments::run_and_save(&["15"], scale, &out_dir(&args), args.has("quiet"))
        }
        Some(other) => bail!("unknown subcommand '{other}'"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "uals — Utility-Aware Load Shedding for real-time video analytics\n\
         \n\
         usage: uals <figures|train|dataset|run|overhead> [flags]\n\
         \n\
         figures  --all | --fig <id>…   [--scale tiny|small|paper] [--out DIR] [--quiet]\n\
         train    --color red[,yellow] [--combine single|or|and] [--out FILE] [--scale S]\n\
         dataset  [--scale S] [--color red]\n\
         run      --scenario fig13a|smart-city|bursty|churn|multiquery|bandwidth|faults|drift|reactor|fleet [--scale S]\n\
         overhead [--scale S]\n"
    );
}

fn parse_scale(args: &Args) -> Result<Scale> {
    let s = args.get_or("scale", "small");
    Scale::parse(&s).ok_or_else(|| anyhow::anyhow!("bad --scale '{s}' (tiny|small|paper)"))
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

fn parse_colors(args: &Args) -> Result<Vec<NamedColor>> {
    let spec = args.get_or("color", "red");
    spec.split(',')
        .map(|c| {
            NamedColor::parse(c.trim())
                .ok_or_else(|| anyhow::anyhow!("unknown color '{c}'"))
        })
        .collect()
}

fn cmd_figures(args: &Args) -> Result<()> {
    let scale = parse_scale(args)?;
    let ids: Vec<&str> = if args.has("all") {
        ALL_FIGURES
            .iter()
            .copied()
            .chain([OVERHEAD_FIGURE])
            .chain(SCENARIOS.iter().copied())
            .collect()
    } else {
        let picked = args.get_all("fig");
        if picked.is_empty() {
            bail!("pass --all or at least one --fig <id>");
        }
        picked
    };
    experiments::run_and_save(&ids, scale, &out_dir(args), args.has("quiet"))
}

fn cmd_train(args: &Args) -> Result<()> {
    let scale = parse_scale(args)?;
    let colors = parse_colors(args)?;
    let combine = match args.get("combine") {
        None => {
            if colors.len() == 1 {
                Combine::Single
            } else {
                Combine::Or
            }
        }
        Some(s) => Combine::parse(s).ok_or_else(|| anyhow::anyhow!("bad --combine '{s}'"))?,
    };
    let corpus = experiments::build_corpus(scale, &colors);
    let all: Vec<usize> = (0..corpus.videos.len()).collect();
    let model = corpus.train_on(&all, combine);
    let out = PathBuf::from(args.get_or("out", "models/model.json"));
    model.save(&out)?;
    println!(
        "trained {} model on {} videos × {} frames → {}",
        combine.name(),
        corpus.videos.len(),
        corpus.videos.first().map(|v| v.len()).unwrap_or(0),
        out.display()
    );
    for c in &model.colors {
        println!(
            "  color {}: norm {:.4}, M+ mass in high-sat half: {:.1}%",
            c.color.name(),
            c.norm,
            100.0 * c.m_pos[32..].iter().sum::<f32>()
                / c.m_pos.iter().sum::<f32>().max(1e-9)
        );
    }
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let scale = parse_scale(args)?;
    let colors = parse_colors(args)?;
    let videos = uals::video::build_dataset(&scale.dataset_config());
    println!(
        "camera  frames  positives  distinct_targets   (color = {})",
        colors[0].name()
    );
    for v in &videos {
        let s = uals::video::dataset::video_stats(v, colors[0]);
        println!(
            "{:>6}  {:>6}  {:>9}  {:>16}",
            s.camera_id, s.frames, s.positive_frames, s.distinct_targets
        );
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let scale = parse_scale(args)?;
    match args.get_or("scenario", "fig13a").as_str() {
        "fig13a" => experiments::run_and_save(&["13a"], scale, &out_dir(args), false),
        "smart-city" => experiments::run_and_save(&["13b"], scale, &out_dir(args), false),
        "bursty" => experiments::run_and_save(&["scenario-bursty"], scale, &out_dir(args), false),
        "churn" => experiments::run_and_save(&["scenario-churn"], scale, &out_dir(args), false),
        "multiquery" => {
            experiments::run_and_save(&["scenario-multiquery"], scale, &out_dir(args), false)
        }
        "bandwidth" => {
            experiments::run_and_save(&["scenario-bandwidth"], scale, &out_dir(args), false)
        }
        "faults" => experiments::run_and_save(&["scenario-faults"], scale, &out_dir(args), false),
        "drift" => experiments::run_and_save(&["scenario-drift"], scale, &out_dir(args), false),
        "reactor" => {
            experiments::run_and_save(&["scenario-reactor"], scale, &out_dir(args), false)
        }
        "fleet" => experiments::run_and_save(&["scenario-fleet"], scale, &out_dir(args), false),
        other => {
            bail!(
                "unknown --scenario '{other}' \
                 (fig13a|smart-city|bursty|churn|multiquery|bandwidth|faults|drift|reactor|fleet)"
            )
        }
    }
}
