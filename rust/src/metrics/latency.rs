//! End-to-end latency accounting (paper Eq. 4) and violation tracking,
//! plus windowed time-series for the Fig. 13 style plots.

use crate::util::stats::{Percentiles, Summary};

/// Per-frame end-to-end latency breakdown (Eq. 4): queue + exec per
/// operator the frame traversed.
#[derive(Debug, Clone)]
pub struct LatencyRecord {
    pub camera: u32,
    pub frame_index: usize,
    /// Capture timestamp (ms, stream clock).
    pub ts_ms: f64,
    /// (operator name, queue ms, exec ms) in traversal order.
    pub segments: Vec<(&'static str, f64, f64)>,
}

impl LatencyRecord {
    pub fn new(camera: u32, frame_index: usize, ts_ms: f64) -> Self {
        LatencyRecord { camera, frame_index, ts_ms, segments: Vec::new() }
    }

    pub fn push(&mut self, op: &'static str, queue_ms: f64, exec_ms: f64) {
        self.segments.push((op, queue_ms, exec_ms));
    }

    /// Total E2E latency (Eq. 4).
    pub fn total_ms(&self) -> f64 {
        self.segments.iter().map(|(_, q, e)| q + e).sum()
    }
}

/// Aggregates latency records against a bound LB.
#[derive(Debug, Clone)]
pub struct LatencyTracker {
    pub bound_ms: f64,
    summary: Summary,
    percentiles: Percentiles,
    violations: u64,
    count: u64,
}

impl LatencyTracker {
    pub fn new(bound_ms: f64) -> Self {
        LatencyTracker {
            bound_ms,
            summary: Summary::new(),
            percentiles: Percentiles::new(),
            violations: 0,
            count: 0,
        }
    }

    pub fn observe(&mut self, total_ms: f64) {
        self.summary.add(total_ms);
        self.percentiles.add(total_ms);
        self.count += 1;
        if total_ms > self.bound_ms {
            self.violations += 1;
        }
    }

    pub fn observe_record(&mut self, r: &LatencyRecord) {
        self.observe(r.total_ms());
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn violations(&self) -> u64 {
        self.violations
    }

    pub fn violation_rate(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.violations as f64 / self.count as f64
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.summary.mean()
    }

    pub fn max_ms(&self) -> f64 {
        self.summary.max()
    }

    pub fn quantile_ms(&mut self, q: f64) -> f64 {
        self.percentiles.quantile(q)
    }

    /// Absorb another tracker (same bound assumed; used by the sharded
    /// sweep driver's deterministic metric merge).
    pub fn merge(&mut self, other: &LatencyTracker) {
        self.summary.merge(&other.summary);
        self.percentiles.merge(&other.percentiles);
        self.violations += other.violations;
        self.count += other.count;
    }
}

/// Fixed-width time-window series (the paper plots 5-second windows):
/// tracks any per-window aggregate keyed by stream time.
#[derive(Debug, Clone)]
pub struct WindowSeries {
    window_ms: f64,
    /// (max, sum, count) per window index.
    windows: Vec<(f64, f64, u64)>,
}

impl WindowSeries {
    pub fn new(window_ms: f64) -> Self {
        assert!(window_ms > 0.0);
        WindowSeries { window_ms, windows: Vec::new() }
    }

    pub fn observe(&mut self, ts_ms: f64, value: f64) {
        let idx = (ts_ms / self.window_ms).floor().max(0.0) as usize;
        if idx >= self.windows.len() {
            self.windows.resize(idx + 1, (f64::NEG_INFINITY, 0.0, 0));
        }
        let w = &mut self.windows[idx];
        w.0 = w.0.max(value);
        w.1 += value;
        w.2 += 1;
    }

    /// (window start ms, max, mean, count) rows.
    pub fn rows(&self) -> Vec<(f64, f64, f64, u64)> {
        self.windows
            .iter()
            .enumerate()
            .map(|(i, &(max, sum, n))| {
                let mean = if n > 0 { sum / n as f64 } else { 0.0 };
                let max = if n > 0 { max } else { 0.0 };
                (i as f64 * self.window_ms, max, mean, n)
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Absorb another series with the same window width.
    pub fn merge(&mut self, other: &WindowSeries) {
        assert_eq!(
            self.window_ms, other.window_ms,
            "window width mismatch in merge"
        );
        if other.windows.len() > self.windows.len() {
            self.windows
                .resize(other.windows.len(), (f64::NEG_INFINITY, 0.0, 0));
        }
        for (w, &(omax, osum, on)) in self.windows.iter_mut().zip(&other.windows) {
            w.0 = w.0.max(omax);
            w.1 += osum;
            w.2 += on;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_totals_eq4() {
        let mut r = LatencyRecord::new(0, 7, 700.0);
        r.push("camera", 0.0, 30.0);
        r.push("shedder", 12.0, 0.5);
        r.push("dnn", 40.0, 120.0);
        assert!((r.total_ms() - 202.5).abs() < 1e-12);
    }

    #[test]
    fn tracker_violations() {
        let mut t = LatencyTracker::new(100.0);
        for v in [50.0, 99.0, 100.0, 101.0, 400.0] {
            t.observe(v);
        }
        assert_eq!(t.count(), 5);
        assert_eq!(t.violations(), 2); // strictly above the bound
        assert!((t.violation_rate() - 0.4).abs() < 1e-12);
        assert_eq!(t.max_ms(), 400.0);
    }

    #[test]
    fn window_series_grouping() {
        let mut w = WindowSeries::new(5000.0);
        w.observe(0.0, 10.0);
        w.observe(4999.0, 30.0);
        w.observe(5000.0, 20.0);
        w.observe(12_000.0, 5.0);
        let rows = w.rows();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], (0.0, 30.0, 20.0, 2));
        assert_eq!(rows[1], (5000.0, 20.0, 20.0, 1));
        assert_eq!(rows[2], (10_000.0, 5.0, 5.0, 1));
    }

    #[test]
    fn empty_windows_render_as_zero() {
        let mut w = WindowSeries::new(1000.0);
        w.observe(2500.0, 7.0);
        let rows = w.rows();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].3, 0);
        assert_eq!(rows[0].1, 0.0);
    }
}
