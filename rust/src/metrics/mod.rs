//! Metrics layer: QoR (paper Eq. 2/3), end-to-end latency (Eq. 4),
//! drop-rate accounting and windowed time series (Fig. 13 plots).

pub mod latency;
pub mod qor;
pub mod stage_counts;

pub use latency::{LatencyRecord, LatencyTracker, WindowSeries};
pub use qor::{DropCounter, QorTracker};
pub use stage_counts::{Stage, StageCounts};
