//! Per-stage frame counters — the lower panel of paper Fig. 13: how many
//! frames reached each query component per time window.

use crate::metrics::WindowSeries;

/// Query pipeline stages a frame can reach (paper Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Arrived at the Load Shedder.
    Ingress = 0,
    /// Dropped before reaching the backend: by the shedder (admission or
    /// queue eviction) **or lost on the transmit link**. The stage
    /// funnel's shed series is this union; `PipelineReport` keeps the
    /// `shed` vs `link_dropped` split.
    Shed = 1,
    /// Reached the blob-size filter.
    BlobFilter = 2,
    /// Reached the color filter.
    ColorFilter = 3,
    /// Reached the DNN detector.
    Dnn = 4,
    /// Reached the sink (passed all stages).
    Sink = 5,
    /// Entered the shedder→backend transmit link (appended after the
    /// query stages so `last_stage` ordering comparisons are untouched;
    /// in funnel order it sits between Shed and BlobFilter).
    Transmit = 6,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::Ingress,
        Stage::Shed,
        Stage::BlobFilter,
        Stage::ColorFilter,
        Stage::Dnn,
        Stage::Sink,
        Stage::Transmit,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Ingress => "ingress",
            Stage::Shed => "shed",
            Stage::BlobFilter => "blob_filter",
            Stage::ColorFilter => "color_filter",
            Stage::Dnn => "dnn",
            Stage::Sink => "sink",
            Stage::Transmit => "transmit",
        }
    }
}

/// Windowed per-stage frame counts.
#[derive(Debug, Clone)]
pub struct StageCounts {
    window_ms: f64,
    series: Vec<WindowSeries>,
}

impl StageCounts {
    pub fn new(window_ms: f64) -> Self {
        StageCounts {
            window_ms,
            series: Stage::ALL.iter().map(|_| WindowSeries::new(window_ms)).collect(),
        }
    }

    pub fn observe(&mut self, stage: Stage, ts_ms: f64) {
        self.series[stage as usize].observe(ts_ms, 1.0);
    }

    pub fn window_ms(&self) -> f64 {
        self.window_ms
    }

    /// Absorb another stage-count set (same window width).
    pub fn merge(&mut self, other: &StageCounts) {
        assert_eq!(self.window_ms, other.window_ms);
        for (mine, theirs) in self.series.iter_mut().zip(&other.series) {
            mine.merge(theirs);
        }
    }

    /// Count of frames per window for a stage.
    pub fn counts(&self, stage: Stage) -> Vec<(f64, u64)> {
        self.series[stage as usize]
            .rows()
            .into_iter()
            .map(|(t, _, _, n)| (t, n))
            .collect()
    }

    /// Rows of (window start, count per stage …) padded to equal length.
    pub fn table(&self) -> Vec<Vec<f64>> {
        let max_len = self.series.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut rows = Vec::with_capacity(max_len);
        for w in 0..max_len {
            let mut row = vec![w as f64 * self.window_ms];
            for s in &self.series {
                let counts = s.rows();
                row.push(counts.get(w).map(|r| r.3 as f64).unwrap_or(0.0));
            }
            rows.push(row);
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_funnel_counts() {
        let mut sc = StageCounts::new(1000.0);
        for i in 0..10 {
            let ts = i as f64 * 100.0; // all in window 0
            sc.observe(Stage::Ingress, ts);
            if i % 2 == 0 {
                sc.observe(Stage::Shed, ts);
            } else {
                sc.observe(Stage::BlobFilter, ts);
                if i % 3 != 0 {
                    sc.observe(Stage::Dnn, ts);
                }
            }
        }
        assert_eq!(sc.counts(Stage::Ingress)[0].1, 10);
        assert_eq!(sc.counts(Stage::Shed)[0].1, 5);
        assert_eq!(sc.counts(Stage::BlobFilter)[0].1, 5);
        assert_eq!(sc.counts(Stage::Dnn)[0].1, 3); // odds not divisible by 3: 1,5,7
    }

    #[test]
    fn table_pads_windows() {
        let mut sc = StageCounts::new(1000.0);
        sc.observe(Stage::Ingress, 100.0);
        sc.observe(Stage::Sink, 2500.0);
        let t = sc.table();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0][1 + Stage::Ingress as usize], 1.0);
        assert_eq!(t[2][1 + Stage::Sink as usize], 1.0);
        assert_eq!(t[1][1 + Stage::Dnn as usize], 0.0);
    }
}
