//! Quality-of-Result accounting (paper Eq. 2/3): per-target-object frame
//! recall under shedding, averaged over objects.

use std::collections::HashMap;

/// Tracks, per target object, how many of its frames existed vs. survived.
#[derive(Debug, Clone, Default)]
pub struct QorTracker {
    totals: HashMap<u64, u64>,
    kept: HashMap<u64, u64>,
}

impl QorTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observe one frame: `target_ids` = target objects present in the
    /// frame (ground truth), `kept` = did the Load Shedder send it on.
    pub fn observe(&mut self, target_ids: &[u64], kept: bool) {
        for &id in target_ids {
            *self.totals.entry(id).or_default() += 1;
            if kept {
                *self.kept.entry(id).or_default() += 1;
            }
        }
    }

    /// Number of distinct target objects seen.
    pub fn num_objects(&self) -> usize {
        self.totals.len()
    }

    /// QoR for one object (Eq. 2), if seen.
    pub fn per_object(&self, id: u64) -> Option<f64> {
        let total = *self.totals.get(&id)?;
        let kept = self.kept.get(&id).copied().unwrap_or(0);
        Some(kept as f64 / total as f64)
    }

    /// Overall QoR (Eq. 3): mean per-object QoR. 1.0 when no targets
    /// appeared (nothing to miss).
    pub fn overall(&self) -> f64 {
        if self.totals.is_empty() {
            return 1.0;
        }
        let sum: f64 = self
            .totals
            .keys()
            .map(|id| self.per_object(*id).unwrap())
            .sum();
        sum / self.totals.len() as f64
    }

    /// All per-object QoR values (for distribution plots).
    pub fn per_object_all(&self) -> Vec<(u64, f64)> {
        let mut v: Vec<(u64, f64)> = self
            .totals
            .keys()
            .map(|&id| (id, self.per_object(id).unwrap()))
            .collect();
        v.sort_by_key(|&(id, _)| id);
        v
    }

    pub fn merge(&mut self, other: &QorTracker) {
        for (&id, &n) in &other.totals {
            *self.totals.entry(id).or_default() += n;
        }
        for (&id, &n) in &other.kept {
            *self.kept.entry(id).or_default() += n;
        }
    }

    /// Retract kept-credit for one previously-kept frame containing
    /// `target_ids`: each object's kept count decrements while its total
    /// stands. This is the exact Eq. 2/3 correction for a frame a *later*
    /// tier sheds after an earlier tier already counted it as kept (the
    /// fleet aggregator's QoR accounting) — equivalent to having observed
    /// the frame as dropped in the first place, because the tracker holds
    /// per-object frame counts, not ratios.
    pub fn demote(&mut self, target_ids: &[u64]) {
        for &id in target_ids {
            if let Some(k) = self.kept.get_mut(&id) {
                *k = k.saturating_sub(1);
            }
        }
    }
}

/// Frame-drop accounting (observed drop rate).
#[derive(Debug, Clone, Copy, Default)]
pub struct DropCounter {
    pub ingress: u64,
    pub dropped: u64,
}

impl DropCounter {
    pub fn observe(&mut self, dropped: bool) {
        self.ingress += 1;
        self.dropped += dropped as u64;
    }

    pub fn drop_rate(&self) -> f64 {
        if self.ingress == 0 {
            0.0
        } else {
            self.dropped as f64 / self.ingress as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn eq2_eq3_on_known_history() {
        let mut q = QorTracker::new();
        // Object 1: 4 frames, 3 kept. Object 2: 2 frames, 0 kept.
        q.observe(&[1], true);
        q.observe(&[1], true);
        q.observe(&[1, 2], true);
        q.observe(&[1, 2], false);
        // object2 appears twice: once kept once dropped → frames kept=1? No:
        // frame3 kept (both objects), frame4 dropped.
        assert_eq!(q.num_objects(), 2);
        assert!((q.per_object(1).unwrap() - 0.75).abs() < 1e-12);
        assert!((q.per_object(2).unwrap() - 0.5).abs() < 1e-12);
        assert!((q.overall() - 0.625).abs() < 1e-12);
    }

    #[test]
    fn empty_is_perfect() {
        let q = QorTracker::new();
        assert_eq!(q.overall(), 1.0);
        assert_eq!(q.num_objects(), 0);
    }

    #[test]
    fn keep_everything_gives_one() {
        let mut q = QorTracker::new();
        for t in 0..50 {
            q.observe(&[t % 5], true);
        }
        assert_eq!(q.overall(), 1.0);
    }

    #[test]
    fn demote_matches_never_kept() {
        // Observing kept-then-demoted must equal observing dropped.
        let mut a = QorTracker::new();
        a.observe(&[1, 2], true);
        a.observe(&[1], true);
        a.demote(&[1, 2]);
        let mut b = QorTracker::new();
        b.observe(&[1, 2], false);
        b.observe(&[1], true);
        assert_eq!(a.overall(), b.overall());
        assert_eq!(a.per_object(1), b.per_object(1));
        assert_eq!(a.per_object(2), b.per_object(2));
        // Demoting an unseen id is a no-op, and kept never underflows.
        a.demote(&[99]);
        a.demote(&[2]);
        assert_eq!(a.per_object(2), Some(0.0));
    }

    #[test]
    fn drop_counter() {
        let mut d = DropCounter::default();
        for i in 0..10 {
            d.observe(i % 4 == 0);
        }
        assert!((d.drop_rate() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn property_qor_bounds_and_merge() {
        Prop::new("qor in [0,1]; merge consistent").cases(50).run(|g| {
            let mut a = QorTracker::new();
            let mut b = QorTracker::new();
            let mut whole = QorTracker::new();
            for _ in 0..g.usize_in(0..200) {
                let ids: Vec<u64> =
                    (0..g.usize_in(0..4)).map(|_| g.usize_in(0..10) as u64).collect();
                let kept = g.bool();
                let first = g.bool();
                if first {
                    a.observe(&ids, kept);
                } else {
                    b.observe(&ids, kept);
                }
                whole.observe(&ids, kept);
            }
            let q = whole.overall();
            assert!((0.0..=1.0).contains(&q));
            a.merge(&b);
            assert!((a.overall() - q).abs() < 1e-12);
        });
    }
}
