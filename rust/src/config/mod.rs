//! Configuration system: query, shedder, cost-model and deployment
//! parameters, with JSON load/save (the paper's "developer-provided"
//! inputs: target colors, hue ranges, E2E latency bound, …).

use crate::color::NamedColor;
use crate::utility::Combine;
use crate::util::json::{self, Value};
use anyhow::{bail, Result};
use std::path::Path;

/// Application-query definition (paper Fig. 1 + §II-B).
#[derive(Debug, Clone)]
pub struct QueryConfig {
    /// Target colors (1 = single-color, 2 = composite).
    pub colors: Vec<NamedColor>,
    /// OR / AND composition for 2-color queries.
    pub combine: Combine,
    /// Minimum blob size (pixels) for the query's filter stages and for
    /// ground-truth target labeling.
    pub min_blob_px: usize,
    /// End-to-end latency bound LB (ms).
    pub latency_bound_ms: f64,
}

impl QueryConfig {
    pub fn single(color: NamedColor) -> Self {
        QueryConfig {
            colors: vec![color],
            combine: Combine::Single,
            min_blob_px: crate::video::MIN_TARGET_PX,
            latency_bound_ms: 1000.0,
        }
    }

    pub fn composite(c1: NamedColor, c2: NamedColor, combine: Combine) -> Self {
        assert!(combine != Combine::Single);
        QueryConfig {
            colors: vec![c1, c2],
            combine,
            min_blob_px: crate::video::MIN_TARGET_PX,
            latency_bound_ms: 1000.0,
        }
    }

    pub fn with_latency_bound(mut self, ms: f64) -> Self {
        self.latency_bound_ms = ms;
        self
    }
}

/// Load Shedder tuning parameters (paper §IV-C/D).
#[derive(Debug, Clone)]
pub struct ShedderConfig {
    /// |H|: utility history window for the CDF (frames).
    pub history: usize,
    /// Re-derive the utility threshold every this many ingress frames.
    pub update_every: usize,
    /// Hard cap on the internal utility queue size.
    pub queue_cap_max: usize,
    /// EWMA weight for the smoothed backend processing latency proc_Q.
    pub proc_ewma_alpha: f64,
    /// Completion-stall watchdog (ms): if every backend token is busy and
    /// no completion lands for this long, the pipeline declares degraded
    /// mode (threshold frozen, everything shed) until progress resumes.
    /// `INFINITY` (the default) disables the watchdog — required for the
    /// bit-identical faultless verification mode.
    pub watchdog_ms: f64,
    /// Per-camera liveness horizon (ms): a camera silent for longer is
    /// counted dead and the nominal fps fallback re-normalizes to the
    /// live share. `INFINITY` (the default) disables liveness tracking.
    pub camera_liveness_ms: f64,
}

impl Default for ShedderConfig {
    fn default() -> Self {
        ShedderConfig {
            history: 600,
            update_every: 5,
            queue_cap_max: 16,
            proc_ewma_alpha: 0.3,
            watchdog_ms: f64::INFINITY,
            camera_liveness_ms: f64::INFINITY,
        }
    }
}

/// Per-stage execution-cost model (ms) — calibrates the simulated backend
/// to the paper's testbed class (efficientdet-d4 on an Azure NC6 / K80 for
/// the DNN stage, §V-B/V-C; Jetson TX1-class camera-side costs, §V-F).
#[derive(Debug, Clone)]
pub struct CostConfig {
    /// Camera-side processing (RGB→HSV + bg-sub + features), proc_CAM.
    pub cam_ms: f64,
    /// Blob (size) filter stage.
    pub blob_ms: f64,
    /// Color filter stage.
    pub color_ms: f64,
    /// DNN object-detection stage (the heavyweight operator).
    pub dnn_ms: f64,
    /// Label/color check + sink.
    pub sink_ms: f64,
    /// Network latencies (paper Eq. 20): camera→LS and LS→query.
    pub net_cam_ls_ms: f64,
    pub net_ls_q_ms: f64,
    /// Multiplicative jitter amplitude on stage costs (0.1 = ±10%).
    pub jitter: f64,
}

impl Default for CostConfig {
    fn default() -> Self {
        CostConfig {
            cam_ms: 30.0,       // paper Fig. 15: "below 35 ms" on Jetson TX1
            blob_ms: 4.0,
            color_ms: 1.5,
            dnn_ms: 120.0,      // efficientdet-d4-class on a K80
            sink_ms: 1.0,
            net_cam_ls_ms: 5.0,
            net_ls_q_ms: 5.0,
            jitter: 0.08,
        }
    }
}

/// Deployment scenario (paper Fig. 2): which link/resource is the
/// bottleneck. Affects the network-latency constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deployment {
    /// LS + query co-located on an edge server (compute bottleneck).
    EdgeCompute,
    /// LS on edge, query in cloud (edge↔cloud bandwidth bottleneck).
    EdgeToCloud,
    /// LS on camera, query in cloud (camera↔cloud bandwidth bottleneck).
    CameraToCloud,
}

impl Deployment {
    pub fn costs(self) -> CostConfig {
        let base = CostConfig::default();
        match self {
            Deployment::EdgeCompute => base,
            Deployment::EdgeToCloud => CostConfig { net_ls_q_ms: 35.0, ..base },
            Deployment::CameraToCloud => {
                CostConfig { net_cam_ls_ms: 1.0, net_ls_q_ms: 45.0, ..base }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// JSON round-trip for experiment configs.
// ---------------------------------------------------------------------------

impl QueryConfig {
    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set(
            "colors",
            Value::Array(
                self.colors
                    .iter()
                    .map(|c| Value::String(c.name().to_string()))
                    .collect(),
            ),
        )
        .set("combine", Value::String(self.combine.name().to_string()))
        .set("min_blob_px", Value::Number(self.min_blob_px as f64))
        .set("latency_bound_ms", Value::Number(self.latency_bound_ms));
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let colors = v
            .get("colors")?
            .as_array()?
            .iter()
            .map(|c| {
                NamedColor::parse(c.as_str()?)
                    .ok_or_else(|| anyhow::anyhow!("unknown color {c}"))
            })
            .collect::<Result<Vec<_>>>()?;
        if colors.is_empty() || colors.len() > 2 {
            bail!("queries support 1 or 2 colors, got {}", colors.len());
        }
        let combine = Combine::parse(v.get("combine")?.as_str()?)
            .ok_or_else(|| anyhow::anyhow!("bad combine"))?;
        if (combine == Combine::Single) != (colors.len() == 1) {
            bail!("combine/colors arity mismatch");
        }
        Ok(QueryConfig {
            colors,
            combine,
            min_blob_px: v.get("min_blob_px")?.as_usize()?,
            latency_bound_ms: v.get("latency_bound_ms")?.as_f64()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&json::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_json_roundtrip() {
        let q = QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Or)
            .with_latency_bound(750.0);
        let back = QueryConfig::from_json(&q.to_json()).unwrap();
        assert_eq!(back.colors, q.colors);
        assert_eq!(back.combine, Combine::Or);
        assert_eq!(back.latency_bound_ms, 750.0);
    }

    #[test]
    fn validation() {
        let q = QueryConfig::single(NamedColor::Red);
        let mut v = q.to_json();
        v.set("combine", Value::String("or".into()));
        assert!(QueryConfig::from_json(&v).is_err(), "arity mismatch accepted");
    }

    #[test]
    fn deployment_scenarios_differ_in_network() {
        let edge = Deployment::EdgeCompute.costs();
        let cloud = Deployment::EdgeToCloud.costs();
        assert!(cloud.net_ls_q_ms > edge.net_ls_q_ms);
        let cam = Deployment::CameraToCloud.costs();
        assert!(cam.net_ls_q_ms > edge.net_ls_q_ms);
    }

    #[test]
    #[should_panic]
    fn composite_requires_non_single() {
        QueryConfig::composite(NamedColor::Red, NamedColor::Yellow, Combine::Single);
    }
}
