//! Tiny CSV writer for experiment result series (`results/fig*.csv`).
//!
//! Each figure harness emits one CSV with a header row; values are
//! formatted with enough precision to re-plot the paper's series.

use std::io::Write;
use std::path::Path;

/// In-memory CSV table with typed row append and file dump.
#[derive(Debug, Clone)]
pub struct Table {
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(columns: Vec<S>) -> Self {
        Table { columns: columns.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn columns(&self) -> &[String] {
        &self.columns
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append a row of f64 cells.
    pub fn push(&mut self, cells: &[f64]) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells.iter().map(|x| format_num(*x)).collect());
    }

    /// Append a row of mixed (string) cells.
    pub fn push_raw<S: Into<String>>(&mut self, cells: Vec<S>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// Render an aligned text table for terminal output (paper-style rows).
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

fn format_num(x: f64) -> String {
    if x.is_nan() {
        "nan".to_string()
    } else if x.fract() == 0.0 && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_format() {
        let mut t = Table::new(vec!["threshold", "qor", "drop"]);
        t.push(&[0.1, 1.0, 0.55]);
        t.push(&[0.2, 0.98, 0.7]);
        let csv = t.to_csv();
        assert!(csv.starts_with("threshold,qor,drop\n"));
        assert!(csv.contains("0.200000,0.980000,0.700000"));
    }

    #[test]
    fn escaping() {
        let mut t = Table::new(vec!["a"]);
        t.push_raw(vec!["x,y\"z"]);
        assert!(t.to_csv().contains("\"x,y\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push(&[1.0]);
    }

    #[test]
    fn pretty_aligns() {
        let mut t = Table::new(vec!["x", "longcol"]);
        t.push(&[1.0, 2.0]);
        let p = t.to_pretty();
        assert!(p.lines().count() >= 3);
    }

    #[test]
    fn file_write() {
        let dir = std::env::temp_dir().join("uals_csv_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(vec!["a"]);
        t.push(&[1.0]);
        t.write(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("a\n1\n"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
