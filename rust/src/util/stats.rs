//! Streaming/statistical helpers: summaries, percentiles, histograms, EWMA.
//!
//! These back the metrics layer (latency accounting, Eq. 4), the control
//! loop's smoothed `proc_Q` estimate (Eq. 18) and the experiment harness's
//! reported series.

/// Online mean/variance (Welford) plus min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile over a stored sample set (fine at experiment scale).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true }
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Absorb another sample set (order-insensitive).
    pub fn merge(&mut self, other: &Percentiles) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    /// q in [0, 1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = pos - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

/// Exponentially-weighted moving average — the control loop's smoother.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of range");
        Ewma { alpha, value: None }
    }

    pub fn add(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Fixed-capacity sliding window of observations with O(1) push.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    buf: Vec<f64>,
    cap: usize,
    head: usize,
    len: usize,
}

impl SlidingWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        SlidingWindow { buf: vec![0.0; cap], cap, head: 0, len: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.buf[self.head] = x;
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn iter(&self) -> impl Iterator<Item = f64> + '_ {
        (0..self.len).map(move |i| {
            let idx = (self.head + self.cap - self.len + i) % self.cap;
            self.buf[idx]
        })
    }

    pub fn mean(&self) -> f64 {
        if self.len == 0 {
            return f64::NAN;
        }
        self.iter().sum::<f64>() / self.len as f64
    }

    pub fn to_vec(&self) -> Vec<f64> {
        self.iter().collect()
    }
}

/// Equal-width histogram over a fixed range; used for utility CDFs.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0, underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let bin = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64) as usize;
        let bin = bin.min(self.counts.len() - 1);
        self.counts[bin] += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// CDF(x): fraction of samples ≤ x (bin-resolution approximation).
    pub fn cdf(&self, x: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        if x < self.lo {
            return 0.0;
        }
        if x >= self.hi {
            return (self.total - self.overflow) as f64 / self.total as f64
                + self.overflow as f64 / self.total as f64;
        }
        let bin = (((x - self.lo) / (self.hi - self.lo)) * self.counts.len() as f64) as usize;
        let bin = bin.min(self.counts.len() - 1);
        let below: u64 = self.underflow + self.counts[..=bin].iter().sum::<u64>();
        below as f64 / self.total as f64
    }

    /// Smallest x with CDF(x) ≥ q (inverse CDF at bin resolution).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return self.lo;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.lo + (i + 1) as f64 * w;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            all.add(x);
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.add(10.0), 10.0); // first sample passes through
        for _ in 0..50 {
            e.add(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn sliding_window_wraps() {
        let mut w = SlidingWindow::new(3);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            w.push(x);
        }
        assert_eq!(w.to_vec(), vec![3.0, 4.0, 5.0]);
        assert!((w.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_cdf_quantile_roundtrip() {
        let mut h = Histogram::new(0.0, 1.0, 100);
        for i in 0..1000 {
            h.add(i as f64 / 1000.0);
        }
        // quantile(q) should have cdf ≈ q (bin resolution 0.01)
        for q in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let x = h.quantile(q);
            let c = h.cdf(x);
            assert!(c >= q - 1e-9, "q={q} x={x} cdf={c}");
            assert!(c <= q + 0.02, "q={q} x={x} cdf={c}");
        }
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(-5.0);
        h.add(2.0);
        h.add(0.5);
        assert_eq!(h.total(), 3);
        assert!((h.cdf(0.99) - 2.0 / 3.0).abs() < 1e-9);
    }
}
