//! Self-built micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall time per iteration with warmup, reports mean/p50/p99 and
//! derived throughput. Used by the `cargo bench` targets
//! (`rust/benches/*.rs`, `harness = false`).

use std::time::Instant;

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean_ms > 0.0 {
            1000.0 / self.mean_ms
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner with fixed warmup/measure iteration counts.
pub struct Bench {
    warmup: usize,
    iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(3, 30)
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters: iters.max(1), results: Vec::new() }
    }

    /// Run one benchmark; the closure is a single iteration.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let q = |p: f64| samples[((p * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
        let r = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ms: mean,
            p50_ms: q(0.5),
            p99_ms: q(0.99),
            min_ms: samples[0],
        };
        println!(
            "{:<44} {:>10.3} ms/iter  p50 {:>9.3}  p99 {:>9.3}  ({:>8.1}/s, {} iters)",
            r.name,
            r.mean_ms,
            r.p50_ms,
            r.p99_ms,
            r.throughput(),
            r.iters
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump results as CSV next to the experiment outputs.
    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut t = crate::util::csv::Table::new(vec![
            "name", "iters", "mean_ms", "p50_ms", "p99_ms", "min_ms", "per_sec",
        ]);
        for r in &self.results {
            t.push_raw(vec![
                r.name.clone(),
                r.iters.to_string(),
                format!("{:.6}", r.mean_ms),
                format!("{:.6}", r.p50_ms),
                format!("{:.6}", r.p99_ms),
                format!("{:.6}", r.min_ms),
                format!("{:.2}", r.throughput()),
            ]);
        }
        t.write(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders_quantiles() {
        let mut b = Bench::new(1, 10);
        let mut x = 0u64;
        let r = b.run("noop-ish", || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p99_ms);
        assert!(r.mean_ms >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn csv_dump() {
        let mut b = Bench::new(0, 2);
        b.run("a", || {});
        let dir = std::env::temp_dir().join("uals_bench_test");
        let p = dir.join("bench.csv");
        b.write_csv(&p).unwrap();
        assert!(std::fs::read_to_string(&p).unwrap().contains("a,2"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
