//! Self-built micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall time per iteration with warmup, reports mean/p50/p99 and
//! derived throughput. Used by the `cargo bench` targets
//! (`rust/benches/*.rs`, `harness = false`).

use std::time::Instant;

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean_ms > 0.0 {
            1000.0 / self.mean_ms
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner with fixed warmup/measure iteration counts.
pub struct Bench {
    warmup: usize,
    iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(3, 30)
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters: iters.max(1), results: Vec::new() }
    }

    /// Run one benchmark; the closure is a single iteration.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        let (warmup, iters) = (self.warmup, self.iters);
        self.run_n(name, warmup, iters, f)
    }

    /// Like [`Self::run`] with per-benchmark warmup/iteration counts
    /// (coarse benches — e.g. whole sweeps — want far fewer iterations
    /// than nanosecond-scale kernels).
    pub fn run_n<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> &BenchResult {
        let iters = iters.max(1);
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let q =
            |p: f64| samples[((p * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_ms: mean,
            p50_ms: q(0.5),
            p99_ms: q(0.99),
            min_ms: samples[0],
        };
        println!(
            "{:<44} {:>10.3} ms/iter  p50 {:>9.3}  p99 {:>9.3}  ({:>8.1}/s, {} iters)",
            r.name,
            r.mean_ms,
            r.p50_ms,
            r.p99_ms,
            r.throughput(),
            r.iters
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Look up a finished result by name.
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump results as machine-readable JSON (`BENCH_micro.json` schema):
    /// per-bench ns/op so the perf trajectory is trackable across PRs.
    pub fn write_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use crate::util::json::Value;
        let mut benches = Vec::with_capacity(self.results.len());
        for r in &self.results {
            // A sub-clock-resolution bench yields mean 0 → infinite
            // throughput; JSON has no Infinity, so clamp to 0.
            let per_sec = r.throughput();
            let per_sec = if per_sec.is_finite() { per_sec } else { 0.0 };
            let mut o = Value::object();
            o.set("name", Value::String(r.name.clone()))
                .set("iters", Value::Number(r.iters as f64))
                .set("mean_ns", Value::Number(r.mean_ms * 1e6))
                .set("p50_ns", Value::Number(r.p50_ms * 1e6))
                .set("p99_ns", Value::Number(r.p99_ms * 1e6))
                .set("min_ns", Value::Number(r.min_ms * 1e6))
                .set("per_sec", Value::Number(per_sec));
            benches.push(o);
        }
        let mut doc = Value::object();
        doc.set("schema", Value::String("uals-microbench-v1".into()))
            .set("unit", Value::String("ns_per_op".into()))
            .set("isa", Value::String(crate::simd::level().name().into()))
            .set("benches", Value::Array(benches));
        crate::util::json::write_file(path, &doc)
    }

    /// Dump results as CSV next to the experiment outputs.
    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut t = crate::util::csv::Table::new(vec![
            "name", "iters", "mean_ms", "p50_ms", "p99_ms", "min_ms", "per_sec",
        ]);
        for r in &self.results {
            t.push_raw(vec![
                r.name.clone(),
                r.iters.to_string(),
                format!("{:.6}", r.mean_ms),
                format!("{:.6}", r.p50_ms),
                format!("{:.6}", r.p99_ms),
                format!("{:.6}", r.min_ms),
                format!("{:.2}", r.throughput()),
            ]);
        }
        t.write(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders_quantiles() {
        let mut b = Bench::new(1, 10);
        let mut x = 0u64;
        let r = b.run("noop-ish", || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p99_ms);
        assert!(r.mean_ms >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_dump_roundtrips() {
        let mut b = Bench::new(0, 2);
        b.run("fast_thing", || {});
        b.run_n("slow_thing", 0, 1, || {});
        let dir = std::env::temp_dir().join("uals_bench_json_test");
        let p = dir.join("BENCH_micro.json");
        b.write_json(&p).unwrap();
        let v = crate::util::json::read_file(&p).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str().unwrap(), "uals-microbench-v1");
        // The resolved ISA rides along so bench_delta can flag cross-ISA
        // comparisons.
        assert_eq!(
            v.get("isa").unwrap().as_str().unwrap(),
            crate::simd::level().name()
        );
        let benches = v.get("benches").unwrap().as_array().unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].get("name").unwrap().as_str().unwrap(), "fast_thing");
        assert!(benches[0].get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(benches[1].get("iters").unwrap().as_usize().unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_dump() {
        let mut b = Bench::new(0, 2);
        b.run("a", || {});
        let dir = std::env::temp_dir().join("uals_bench_test");
        let p = dir.join("bench.csv");
        b.write_csv(&p).unwrap();
        assert!(std::fs::read_to_string(&p).unwrap().contains("a,2"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
