//! Shared substrates: JSON, CSV, PRNG, statistics, property testing.
//!
//! The offline build environment lacks serde/rand/proptest/criterion; these
//! modules are the in-repo replacements (see DESIGN.md §1).

pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Monotonic wall-clock milliseconds since process start (profiling aid).
pub fn now_ms() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e3
}
