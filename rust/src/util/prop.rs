//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Provides seeded case generation with failure *seed replay*: when a
//! property fails, the panic message includes the case seed so the exact
//! input can be reproduced with `Prop::replay(seed)`. Coordinator
//! invariants (CDF monotonicity, queue eviction order, admission-control
//! stability, …) are property-tested through this harness.
//!
//! ```no_run
//! // (no_run: doctest executables don't inherit the xla rpath and the
//! // nix loader has no ld.so.cache entry for libstdc++ — see README)
//! use uals::util::prop::Prop;
//! Prop::new("sorted idempotent").cases(64).run(|g| {
//!     let mut xs = g.vec_f64(0..50, -1e3, 1e3);
//!     xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let once = xs.clone();
//!     xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     assert_eq!(once, xs);
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Case-input generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Seed reproducing this exact case.
    pub case_seed: u64,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), case_seed: seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.rng.range(r.start, r.end)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn unit_f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec of f64 with length drawn from `len` and values in [lo, hi).
    pub fn vec_f64(&mut self, len: Range<usize>, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Vec of usize indices below `bound`.
    pub fn vec_usize(&mut self, len: Range<usize>, bound: usize) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(0..bound)).collect()
    }

    /// Borrow the underlying Rng for domain-specific generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// A named property with a configurable number of cases.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        // Deterministic per-property base seed (stable across runs) derived
        // from the name, so the suite is reproducible without env vars.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Prop { name, cases: 100, seed: h }
    }

    /// Override the number of generated cases (default 100).
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override the base seed (e.g. to replay a failure).
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run the property across all cases; panics with the failing seed.
    pub fn run<F: FnMut(&mut Gen)>(self, mut f: F) {
        for i in 0..self.cases {
            let case_seed = self.seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut g = Gen::new(case_seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut g);
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property '{}' failed at case {}/{} (replay with \
                     Prop::new(..).seed({}).cases(1)): {}",
                    self.name, i, self.cases, case_seed, msg
                );
            }
        }
    }

    /// Replay a single failing case by seed.
    pub fn replay<F: FnMut(&mut Gen)>(seed: u64, mut f: F) {
        let mut g = Gen::new(seed);
        f(&mut g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new("reverse twice is identity").cases(50).run(|g| {
            let xs = g.vec_f64(0..20, -10.0, 10.0);
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            Prop::new("always fails on big").cases(200).run(|g| {
                let x = g.f64_in(0.0, 1.0);
                assert!(x < 0.9, "x too big: {x}");
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay with"), "{msg}");
        // Extract the seed and check replay reproduces the failure.
        let seed: u64 = msg
            .split(".seed(")
            .nth(1)
            .unwrap()
            .split(')')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let replay = std::panic::catch_unwind(|| {
            Prop::replay(seed, |g| {
                let x = g.f64_in(0.0, 1.0);
                assert!(x < 0.9);
            });
        });
        assert!(replay.is_err(), "replayed case should fail again");
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        Prop::new("collect").cases(10).run(|g| first.push(g.u64()));
        let mut second = Vec::new();
        Prop::new("collect").cases(10).run(|g| second.push(g.u64()));
        assert_eq!(first, second);
    }
}
