//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! `Rng` is xoshiro256** seeded through SplitMix64 — the standard pairing:
//! SplitMix64 decorrelates arbitrary user seeds, xoshiro256** provides the
//! stream. Everything in the repo that needs randomness (scene generation,
//! the content-agnostic baseline shedder, property tests) goes through this
//! type, so every experiment is replayable from a single `u64` seed.

/// SplitMix64 step: used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from an arbitrary seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. per camera / per object).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire's multiply-shift rejection-free-enough method with one
        // rejection loop for exactness.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi) as usize.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Exponentially-distributed draw with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }
}
