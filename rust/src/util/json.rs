//! Minimal JSON substrate (serde is unavailable in the offline crate set).
//!
//! Implements the full JSON grammar (RFC 8259) minus exotic number forms:
//! a `Value` tree, a recursive-descent parser, and a serializer with
//! optional pretty-printing. Used for `artifacts/manifest.json`, trained
//! utility-model files, experiment configs and result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

/// Parse or access error.
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Access(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Access(msg) => write!(f, "json access error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Value {
    // ---- constructors -----------------------------------------------------

    pub fn object() -> Value {
        Value::Object(BTreeMap::new())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x as f64)).collect())
    }

    // ---- typed accessors --------------------------------------------------

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Number(x) => Ok(*x),
            _ => Err(JsonError::Access(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(JsonError::Access(format!("expected usize, got {x}")));
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(JsonError::Access(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::String(s) => Ok(s),
            _ => Err(JsonError::Access(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_array(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Array(a) => Ok(a),
            _ => Err(JsonError::Access(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>, JsonError> {
        match self {
            Value::Object(o) => Ok(o),
            _ => Err(JsonError::Access(format!("expected object, got {self:?}"))),
        }
    }

    /// Object field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Value, JsonError> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| JsonError::Access(format!("missing key '{key}'")))
    }

    /// Optional field.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.get(key),
            _ => None,
        }
    }

    /// Array of numbers → `Vec<f64>`.
    pub fn to_f64_vec(&self) -> Result<Vec<f64>, JsonError> {
        self.as_array()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers → `Vec<f32>`.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>, JsonError> {
        Ok(self.to_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    /// Insert into an object (panics if not an object — builder use only).
    pub fn set(&mut self, key: &str, val: Value) -> &mut Self {
        match self {
            Value::Object(o) => {
                o.insert(key.to_string(), val);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    // ---- serialization ----------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Number(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Value::String(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'n' => self.literal("null", Value::Null),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'"' => Ok(Value::String(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        // Surrogate pairs: join if a high surrogate is followed
                        // by an escaped low surrogate.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let mut low = 0u32;
                                for _ in 0..4 {
                                    let d =
                                        self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                    low = low * 16
                                        + (d as char)
                                            .to_digit(16)
                                            .ok_or_else(|| self.err("bad hex digit"))?;
                                }
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                            } else {
                                return Err(self.err("lone surrogate"));
                            }
                        } else {
                            code
                        };
                        s.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Read + parse a JSON file.
pub fn read_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(parse(&text)?)
}

/// Pretty-write a JSON file (creates parent dirs).
pub fn write_file(path: &std::path::Path, v: &Value) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"red","ranges":[0,10,170,180],"m":[[0.5,1.0]],"ok":true}"#;
        let v = parse(src).unwrap();
        for s in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo→😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→😀");
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn errors_have_position() {
        let e = parse("{\"a\": }").unwrap_err();
        match e {
            JsonError::Parse { pos, .. } => assert!(pos >= 5),
            _ => panic!("wrong error type"),
        }
        assert!(parse("[1,2").is_err());
        assert!(parse("[1,2]x").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn builder_api() {
        let mut v = Value::object();
        v.set("x", Value::Number(1.0))
            .set("ys", Value::from_f64_slice(&[1.0, 2.5]));
        let s = v.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(back.get("ys").unwrap().to_f64_vec().unwrap(), vec![1.0, 2.5]);
    }

    #[test]
    fn typed_access_errors() {
        let v = parse("{\"a\": 1}").unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("uals_json_test");
        let path = dir.join("t.json");
        let mut v = Value::object();
        v.set("k", Value::String("v".into()));
        write_file(&path, &v).unwrap();
        assert_eq!(read_file(&path).unwrap(), v);
        std::fs::remove_dir_all(&dir).ok();
    }
}
