//! ROC-AUC separability metric: how well a scalar score (utility, HF)
//! separates positive from negative frames. Used by the ablation studies
//! (bin-count sweep, feature comparisons) as a threshold-free measure.

/// Area under the ROC curve via the rank-sum (Mann–Whitney) estimator.
/// Ties contribute 0.5. A degenerate input (either class empty) returns
/// 0.5 — "no evidence of separation" — instead of NaN, so online
/// retraining over sparse label windows never propagates NaN into swap
/// margins or thresholds.
pub fn roc_auc(positives: &[f32], negatives: &[f32]) -> f64 {
    if positives.is_empty() || negatives.is_empty() {
        return 0.5;
    }
    // Sort all scores; walk in ascending order accumulating how many
    // negatives precede each positive.
    let mut all: Vec<(f32, bool)> = positives
        .iter()
        .map(|&x| (x, true))
        .chain(negatives.iter().map(|&x| (x, false)))
        .collect();
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut neg_seen = 0.0f64;
    let mut wins = 0.0f64;
    let mut i = 0;
    while i < all.len() {
        // Group ties.
        let mut j = i;
        let (mut tie_pos, mut tie_neg) = (0.0f64, 0.0f64);
        while j < all.len() && all[j].0 == all[i].0 {
            if all[j].1 {
                tie_pos += 1.0;
            } else {
                tie_neg += 1.0;
            }
            j += 1;
        }
        wins += tie_pos * (neg_seen + tie_neg * 0.5);
        neg_seen += tie_neg;
        i = j;
    }
    wins / (positives.len() as f64 * negatives.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation() {
        let auc = roc_auc(&[0.8, 0.9, 1.0], &[0.1, 0.2, 0.3]);
        assert!((auc - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_separation() {
        let auc = roc_auc(&[0.1, 0.2], &[0.8, 0.9]);
        assert!(auc.abs() < 1e-12);
    }

    #[test]
    fn random_is_half() {
        // Interleaved identical distributions.
        let pos: Vec<f32> = (0..100).map(|i| (i as f32 * 7.3) % 1.0).collect();
        let neg: Vec<f32> = (0..100).map(|i| (i as f32 * 7.3 + 3.65) % 1.0).collect();
        let auc = roc_auc(&pos, &neg);
        assert!((auc - 0.5).abs() < 0.1, "auc={auc}");
    }

    #[test]
    fn ties_count_half() {
        let auc = roc_auc(&[0.5, 0.5], &[0.5, 0.5]);
        assert!((auc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_classes_are_half_not_nan() {
        // Sparse online label windows hit these constantly; NaN here
        // would poison swap margins downstream.
        assert_eq!(roc_auc(&[], &[1.0]), 0.5);
        assert_eq!(roc_auc(&[1.0], &[]), 0.5);
        assert_eq!(roc_auc(&[], &[]), 0.5);
    }

    #[test]
    fn matches_bruteforce_on_random_data() {
        let mut rng = crate::util::rng::Rng::new(4);
        for _ in 0..20 {
            let pos: Vec<f32> = (0..30).map(|_| (rng.f32() * 8.0).round() / 8.0).collect();
            let neg: Vec<f32> = (0..40).map(|_| (rng.f32() * 8.0).round() / 8.0).collect();
            let fast = roc_auc(&pos, &neg);
            let mut brute = 0.0;
            for &p in &pos {
                for &n in &neg {
                    brute += if p > n {
                        1.0
                    } else if p == n {
                        0.5
                    } else {
                        0.0
                    };
                }
            }
            brute /= (pos.len() * neg.len()) as f64;
            assert!((fast - brute).abs() < 1e-9, "{fast} vs {brute}");
        }
    }
}
