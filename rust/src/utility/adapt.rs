//! Online utility-model adaptation with shadow evaluation and guarded
//! rollback — the drift-resilience layer.
//!
//! The paper trains the utility model offline and freezes it; under
//! content drift (illumination change, camera fouling, hue-shifted
//! stock, traffic surges — see [`crate::video::DriftPlan`]) the frozen
//! model's utility ranking decays and the shedder starts dropping the
//! wrong frames. This module closes the loop from *delayed* backend
//! ground truth back into the model, without ever letting a bad retrain
//! take the live pipeline down:
//!
//! 1. **Labels** arrive `label_delay_ms` after a transmitted frame
//!    completes at the backend (the detector's verdict is the ground
//!    truth; shed frames yield no label — exactly the feedback a real
//!    deployment has).
//! 2. **Retraining** folds labels into a per-camera
//!    [`TrainerAccumulator`] that is exponentially [`decay`]ed after
//!    every retrain, turning it into a sliding window where recent
//!    labels dominate.
//! 3. **Shadow evaluation**: a freshly finalized candidate never goes
//!    live directly. It scores the next `shadow_min_labels` labeled
//!    frames *in parallel* with the incumbent; only if its ROC-AUC
//!    beats the incumbent's by `swap_margin` does it swap in.
//! 4. **Guarded rollback**: after a swap the new model is on probation
//!    for `probation_labels` labels. If its observed AUC falls more
//!    than `rollback_margin` below what the shadow window promised, the
//!    exact previous model version is restored from the history stack.
//!
//! Determinism: every state transition is driven solely by the ordered
//! label stream (virtual completion time + constant delay), never by
//! wall-clock reads, so sim and realtime runs adapt identically.
//! With `enabled: false` (the default) the engine never constructs an
//! adapter and the pipeline is bit-identical to the frozen-model system.
//!
//! [`decay`]: TrainerAccumulator::decay

use super::auc::roc_auc;
use super::model::UtilityModel;
use super::trainer::{LabeledFeatures, TrainerAccumulator};
use crate::color::NamedColor;
use crate::features::FrameFeatures;
use std::collections::{HashMap, VecDeque};

/// Tuning for the online-adaptation loop. `enabled: false` (default)
/// keeps the pipeline bit-identical to the frozen-model system.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptationConfig {
    /// Master switch. Off ⇒ the engine never constructs an adapter.
    pub enabled: bool,
    /// Ground-truth latency: a label becomes visible this long after its
    /// frame's backend completion (annotation / verification lag).
    pub label_delay_ms: f64,
    /// Labels between retrain attempts (per camera).
    pub retrain_every: usize,
    /// Minimum (decayed) examples of *each* class a color needs before a
    /// candidate is finalized — guards against one-class retrains.
    pub min_labels: u64,
    /// Accumulator decay applied after every retrain (0 = forget all,
    /// 1 = never forget).
    pub decay: f64,
    /// Labels the shadow window scores before the swap verdict.
    pub shadow_min_labels: usize,
    /// Candidate must beat the incumbent's shadow-window AUC by this
    /// much to swap in.
    pub swap_margin: f64,
    /// Labels the post-swap probation window observes before the
    /// keep/rollback verdict.
    pub probation_labels: usize,
    /// Rollback fires when probation AUC < promised AUC − this margin.
    pub rollback_margin: f64,
    /// Ingress-feature ring the engine re-scores to reseed the
    /// admission CDF after a swap or rollback.
    pub reseed_window: usize,
}

impl Default for AdaptationConfig {
    fn default() -> Self {
        AdaptationConfig {
            enabled: false,
            label_delay_ms: 400.0,
            retrain_every: 48,
            min_labels: 4,
            decay: 0.85,
            shadow_min_labels: 32,
            swap_margin: 0.02,
            probation_labels: 32,
            rollback_margin: 0.05,
            reseed_window: 256,
        }
    }
}

/// What happened in the adaptation state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptEventKind {
    /// A candidate was finalized and entered shadow evaluation.
    Retrain,
    /// The shadow window's verdict promoted the candidate to live.
    Swap,
    /// Probation caught a post-swap regression; the previous version
    /// was restored exactly.
    Rollback,
    /// The shadow window's verdict discarded the candidate.
    ShadowReject,
}

/// One adaptation decision, stamped with the label time that drove it
/// (virtual time ⇒ identical under sim and wall clocks).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptEvent {
    /// Label time (virtual ms) that triggered the decision.
    pub t_ms: f64,
    /// Camera whose label stream drove the event.
    pub camera: u32,
    /// What the adapter decided.
    pub kind: AdaptEventKind,
    /// The model version the event concerns: the candidate for
    /// `Retrain`/`ShadowReject`, the new live version for `Swap`, the
    /// restored version for `Rollback`.
    pub version: u64,
}

/// Adaptation counters + event log for [`crate::pipeline::PipelineReport`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdaptationStats {
    /// Delayed ground-truth labels the adapter consumed.
    pub labels_observed: u64,
    /// Candidates finalized into shadow evaluation.
    pub retrains: u64,
    /// Candidates promoted to live.
    pub swaps: u64,
    /// Post-swap regressions that restored the previous version.
    pub rollbacks: u64,
    /// Candidates discarded by their shadow-window verdict.
    pub shadow_rejected: u64,
    /// Admission-CDF reseeds the engine performed (one per swap or
    /// rollback it acted on).
    pub reseeds: u64,
    /// Time-ordered event log of every adaptation decision.
    pub events: Vec<AdaptEvent>,
}

impl AdaptationStats {
    /// Fold another shard's stats in (parallel sweep merge): counters
    /// sum, event logs interleave by time.
    pub fn merge(&mut self, other: &AdaptationStats) {
        self.labels_observed += other.labels_observed;
        self.retrains += other.retrains;
        self.swaps += other.swaps;
        self.rollbacks += other.rollbacks;
        self.shadow_rejected += other.shadow_rejected;
        self.reseeds += other.reseeds;
        self.events.extend(other.events.iter().cloned());
        self.events
            .sort_by(|a, b| a.t_ms.total_cmp(&b.t_ms).then(a.camera.cmp(&b.camera)));
    }
}

/// A delayed ground-truth label in flight.
struct PendingLabel {
    due_ms: f64,
    camera: u32,
    features: FrameFeatures,
    positive: bool,
}

/// Candidate model scoring the label stream next to the incumbent.
struct Shadow {
    candidate: UtilityModel,
    version: u64,
    live_pos: Vec<f32>,
    live_neg: Vec<f32>,
    cand_pos: Vec<f32>,
    cand_neg: Vec<f32>,
}

impl Shadow {
    fn len(&self) -> usize {
        self.live_pos.len() + self.live_neg.len()
    }
}

/// Post-swap watch window for the freshly promoted model.
struct Probation {
    promised_auc: f64,
    pos: Vec<f32>,
    neg: Vec<f32>,
}

impl Probation {
    fn len(&self) -> usize {
        self.pos.len() + self.neg.len()
    }
}

/// Per-camera adaptation state. Version 0 is the offline base model;
/// while a camera sits at version 0 the adapter abstains from scoring
/// ([`OnlineAdapter::utility_for`] returns `None`) so the engine's
/// precomputed utilities — and therefore every decision — are untouched.
struct CameraAdapter {
    version: u64,
    live: UtilityModel,
    /// Stack of superseded `(version, model)` pairs; rollback pops the
    /// top and restores it bit-for-bit.
    history: Vec<(u64, UtilityModel)>,
    acc: TrainerAccumulator,
    examples: Vec<LabeledFeatures>,
    labels_since_retrain: usize,
    version_counter: u64,
    shadow: Option<Shadow>,
    probation: Option<Probation>,
}

impl CameraAdapter {
    fn new(base: &UtilityModel, colors: &[NamedColor]) -> Self {
        CameraAdapter {
            version: 0,
            live: base.clone(),
            history: Vec::new(),
            acc: TrainerAccumulator::new(colors),
            examples: Vec::new(),
            labels_since_retrain: 0,
            version_counter: 0,
            shadow: None,
            probation: None,
        }
    }
}

/// The online adaptation loop: owns per-camera model versions, the
/// delayed-label queue, and the recent-ingress feature ring used to
/// reseed the admission CDF after a swap.
pub struct OnlineAdapter {
    cfg: AdaptationConfig,
    base: UtilityModel,
    colors: Vec<NamedColor>,
    cameras: HashMap<u32, CameraAdapter>,
    pending: VecDeque<PendingLabel>,
    /// Recent ingress features (camera, features), capped at
    /// `reseed_window` — re-scored wholesale on swap/rollback.
    recent: VecDeque<(u32, FrameFeatures)>,
    stats: AdaptationStats,
}

impl OnlineAdapter {
    /// A fresh adapter: every camera starts on the `base` model.
    pub fn new(cfg: AdaptationConfig, base: UtilityModel) -> Self {
        let colors: Vec<NamedColor> = base.colors.iter().map(|c| c.color).collect();
        OnlineAdapter {
            cfg,
            base,
            colors,
            cameras: HashMap::new(),
            pending: VecDeque::new(),
            recent: VecDeque::new(),
            stats: AdaptationStats::default(),
        }
    }

    /// The adaptation knobs this adapter runs under.
    pub fn config(&self) -> &AdaptationConfig {
        &self.cfg
    }

    /// Counters + event log accumulated so far.
    pub fn stats(&self) -> &AdaptationStats {
        &self.stats
    }

    /// Consume the adapter, yielding its counters + event log for the
    /// pipeline report.
    pub fn into_stats(self) -> AdaptationStats {
        self.stats
    }

    /// The camera's current model version (0 = offline base).
    pub fn camera_version(&self, camera: u32) -> u64 {
        self.cameras.get(&camera).map_or(0, |c| c.version)
    }

    /// The camera's live model (the base until its first swap).
    pub fn live_model(&self, camera: u32) -> &UtilityModel {
        self.cameras.get(&camera).map_or(&self.base, |c| &c.live)
    }

    /// Score `features` with the camera's live model — `None` while the
    /// camera still runs the base model (version 0), which lets the
    /// engine keep its precomputed utility and stay bit-identical to
    /// the frozen pipeline until the first swap actually happens.
    pub fn utility_for(&self, camera: u32, features: &FrameFeatures) -> Option<f32> {
        let cam = self.cameras.get(&camera)?;
        if cam.version == 0 {
            return None;
        }
        Some(cam.live.utility(features).combined)
    }

    /// Remember an ingress frame's features for post-swap CDF reseeding.
    pub fn observe_ingress(&mut self, camera: u32, features: &FrameFeatures) {
        if self.cfg.reseed_window == 0 {
            return;
        }
        if self.recent.len() == self.cfg.reseed_window {
            self.recent.pop_front();
        }
        self.recent.push_back((camera, features.clone()));
    }

    /// Queue a delayed ground-truth label (called at backend completion
    /// with `due_ms = completion + label_delay_ms`). Completions are
    /// processed in virtual-time order, so due times arrive nondecreasing.
    pub fn enqueue_label(&mut self, due_ms: f64, camera: u32, features: FrameFeatures, positive: bool) {
        debug_assert!(
            self.pending.back().is_none_or(|p| p.due_ms <= due_ms),
            "label due times must be nondecreasing"
        );
        self.pending.push_back(PendingLabel { due_ms, camera, features, positive });
    }

    /// Process every label whose delay has elapsed. Returns `true` when
    /// a swap or rollback changed some camera's live model — the engine
    /// must then re-score its admission history ([`Self::rescore_recent`]).
    pub fn drain_due(&mut self, now_ms: f64) -> bool {
        let mut model_changed = false;
        while self.pending.front().is_some_and(|p| p.due_ms <= now_ms) {
            let label = self.pending.pop_front().unwrap();
            model_changed |= self.consume(label);
        }
        model_changed
    }

    /// Score the recent-ingress ring with each frame's *current* live
    /// model — the utilities the admission CDF reseeds from.
    pub fn rescore_recent(&self, out: &mut Vec<f32>) {
        out.clear();
        for (camera, features) in &self.recent {
            let u = self
                .utility_for(*camera, features)
                .unwrap_or_else(|| self.base.utility(features).combined);
            out.push(u);
        }
    }

    /// Count one admission-CDF reseed the engine performed.
    pub fn record_reseed(&mut self) {
        self.stats.reseeds += 1;
    }

    /// One delayed label through the per-camera state machine. Returns
    /// `true` if the camera's live model changed (swap or rollback).
    fn consume(&mut self, label: PendingLabel) -> bool {
        let cfg = self.cfg.clone();
        let cam = self
            .cameras
            .entry(label.camera)
            .or_insert_with(|| CameraAdapter::new(&self.base, &self.colors));
        self.stats.labels_observed += 1;
        let u_live = cam.live.utility(&label.features).combined;
        let mut changed = false;

        // Shadow evaluation: candidate and incumbent score the same
        // labeled frame; verdict at the window boundary.
        if let Some(shadow) = cam.shadow.as_mut() {
            let u_cand = shadow.candidate.utility(&label.features).combined;
            if label.positive {
                shadow.live_pos.push(u_live);
                shadow.cand_pos.push(u_cand);
            } else {
                shadow.live_neg.push(u_live);
                shadow.cand_neg.push(u_cand);
            }
            if shadow.len() >= cfg.shadow_min_labels {
                let shadow = cam.shadow.take().unwrap();
                let auc_live = roc_auc(&shadow.live_pos, &shadow.live_neg);
                let auc_cand = roc_auc(&shadow.cand_pos, &shadow.cand_neg);
                if auc_cand > auc_live + cfg.swap_margin {
                    cam.history.push((cam.version, cam.live.clone()));
                    cam.version = shadow.version;
                    cam.live = shadow.candidate;
                    cam.probation =
                        Some(Probation { promised_auc: auc_cand, pos: Vec::new(), neg: Vec::new() });
                    cam.labels_since_retrain = 0;
                    self.stats.swaps += 1;
                    self.stats.events.push(AdaptEvent {
                        t_ms: label.due_ms,
                        camera: label.camera,
                        kind: AdaptEventKind::Swap,
                        version: cam.version,
                    });
                    changed = true;
                } else {
                    self.stats.shadow_rejected += 1;
                    self.stats.events.push(AdaptEvent {
                        t_ms: label.due_ms,
                        camera: label.camera,
                        kind: AdaptEventKind::ShadowReject,
                        version: shadow.version,
                    });
                }
            }
        } else if let Some(probation) = cam.probation.as_mut() {
            // Probation: watch the promoted model's realized separation.
            if label.positive {
                probation.pos.push(u_live);
            } else {
                probation.neg.push(u_live);
            }
            if probation.len() >= cfg.probation_labels {
                let probation = cam.probation.take().unwrap();
                let post_auc = roc_auc(&probation.pos, &probation.neg);
                if post_auc < probation.promised_auc - cfg.rollback_margin {
                    if let Some((version, model)) = cam.history.pop() {
                        cam.version = version;
                        cam.live = model;
                        cam.labels_since_retrain = 0;
                        self.stats.rollbacks += 1;
                        self.stats.events.push(AdaptEvent {
                            t_ms: label.due_ms,
                            camera: label.camera,
                            kind: AdaptEventKind::Rollback,
                            version,
                        });
                        changed = true;
                    }
                }
            }
        }

        // Every label feeds the decayed accumulator regardless of the
        // state machine's phase.
        let example = LabeledFeatures {
            features: label.features,
            labels: vec![label.positive; self.colors.len()],
        };
        cam.acc.add(&example);
        if cfg.reseed_window > 0 {
            if cam.examples.len() == cfg.reseed_window {
                cam.examples.remove(0);
            }
            cam.examples.push(example);
        }
        cam.labels_since_retrain += 1;

        // Retrain trigger: only between shadow/probation windows, and
        // only once both classes carry enough (decayed) mass.
        if cam.shadow.is_none()
            && cam.probation.is_none()
            && cam.labels_since_retrain >= cfg.retrain_every
        {
            let enough = (0..self.colors.len()).all(|c| {
                cam.acc.positives(c) >= cfg.min_labels && cam.acc.negatives(c) >= cfg.min_labels
            });
            if enough {
                let candidate =
                    cam.acc
                        .finalize(self.base.combine, self.base.fg_threshold, &cam.examples);
                cam.acc.decay(cfg.decay);
                cam.version_counter += 1;
                cam.shadow = Some(Shadow {
                    candidate,
                    version: cam.version_counter,
                    live_pos: Vec::new(),
                    live_neg: Vec::new(),
                    cand_pos: Vec::new(),
                    cand_neg: Vec::new(),
                });
                cam.labels_since_retrain = 0;
                self.stats.retrains += 1;
                self.stats.events.push(AdaptEvent {
                    t_ms: label.due_ms,
                    camera: label.camera,
                    kind: AdaptEventKind::Retrain,
                    version: cam.version_counter,
                });
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::HIST;
    use crate::utility::model::{ColorModel, Combine};

    fn base_model(hot: usize) -> UtilityModel {
        let mut m_pos = [0.0; HIST];
        m_pos[hot] = 1.0;
        UtilityModel {
            colors: vec![ColorModel {
                color: NamedColor::Red,
                ranges: NamedColor::Red.ranges(),
                m_pos,
                m_neg: [0.0; HIST],
                norm: 1.0,
            }],
            combine: Combine::Single,
            fg_threshold: 25.0,
        }
    }

    fn feat(hot: usize) -> FrameFeatures {
        let mut pf = [0.0f32; HIST];
        pf[hot] = 1.0;
        FrameFeatures { hf: vec![0.5], pf: vec![pf], fg_frac: 0.2 }
    }

    fn fast_cfg() -> AdaptationConfig {
        AdaptationConfig {
            enabled: true,
            label_delay_ms: 10.0,
            retrain_every: 8,
            min_labels: 2,
            decay: 0.9,
            shadow_min_labels: 8,
            swap_margin: 0.1,
            probation_labels: 8,
            rollback_margin: 0.05,
            reseed_window: 64,
        }
    }

    /// Feed `n` alternating labels where positives sit at pf bin
    /// `pos_bin` and negatives at `neg_bin`, advancing time.
    fn feed(ad: &mut OnlineAdapter, t0: &mut f64, n: usize, pos_bin: usize, neg_bin: usize) {
        for i in 0..n {
            let positive = i % 2 == 0;
            let bin = if positive { pos_bin } else { neg_bin };
            *t0 += 10.0;
            ad.enqueue_label(*t0, 0, feat(bin), positive);
            ad.drain_due(*t0);
        }
    }

    #[test]
    fn version_zero_abstains_from_scoring() {
        let ad = OnlineAdapter::new(fast_cfg(), base_model(10));
        assert_eq!(ad.camera_version(0), 0);
        assert!(ad.utility_for(0, &feat(10)).is_none());
    }

    #[test]
    fn labels_respect_their_delay() {
        let mut ad = OnlineAdapter::new(fast_cfg(), base_model(10));
        ad.enqueue_label(100.0, 0, feat(10), true);
        assert!(!ad.drain_due(99.0));
        assert_eq!(ad.stats().labels_observed, 0);
        ad.drain_due(100.0);
        assert_eq!(ad.stats().labels_observed, 1);
    }

    #[test]
    fn drifted_labels_retrain_shadow_then_swap() {
        // Base model keys on bin 10; drifted content puts positives at
        // bin 30 and negatives at bin 10 → base AUC 0, candidate AUC 1.
        let mut ad = OnlineAdapter::new(fast_cfg(), base_model(10));
        let mut t = 0.0;
        // 8 labels → retrain (shadow opens), 8 more → swap verdict.
        feed(&mut ad, &mut t, 16, 30, 10);
        let s = ad.stats();
        assert_eq!(s.retrains, 1, "events: {:?}", s.events);
        assert_eq!(s.swaps, 1, "events: {:?}", s.events);
        assert_eq!(s.rollbacks, 0);
        assert_eq!(ad.camera_version(0), 1);
        // The promoted model now ranks the drifted positives on top.
        let u_pos = ad.utility_for(0, &feat(30)).unwrap();
        let u_neg = ad.utility_for(0, &feat(10)).unwrap();
        assert!(u_pos > u_neg, "u_pos {u_pos} u_neg {u_neg}");
        // Another camera is untouched.
        assert_eq!(ad.camera_version(3), 0);
        assert!(ad.utility_for(3, &feat(30)).is_none());
    }

    #[test]
    fn regressing_swap_rolls_back_to_the_exact_prior_version() {
        let mut ad = OnlineAdapter::new(fast_cfg(), base_model(10));
        let base = base_model(10);
        let mut t = 0.0;
        feed(&mut ad, &mut t, 16, 30, 10); // retrain + swap
        assert_eq!(ad.camera_version(0), 1);
        // Probation sees inverted reality: the promoted model's hot bin
        // is now the *negative* signature → post AUC ≈ 0 → rollback.
        feed(&mut ad, &mut t, 8, 10, 30);
        let s = ad.stats();
        assert_eq!(s.rollbacks, 1, "events: {:?}", s.events);
        assert_eq!(ad.camera_version(0), 0);
        // Restored bit-for-bit: the live model is the base again.
        let live = ad.live_model(0);
        assert_eq!(live.colors[0].m_pos, base.colors[0].m_pos);
        assert_eq!(live.colors[0].m_neg, base.colors[0].m_neg);
        assert_eq!(live.colors[0].norm, base.colors[0].norm);
        // And version 0 abstains again.
        assert!(ad.utility_for(0, &feat(30)).is_none());
        let kinds: Vec<AdaptEventKind> = s.events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![AdaptEventKind::Retrain, AdaptEventKind::Swap, AdaptEventKind::Rollback]
        );
    }

    #[test]
    fn non_improving_candidate_is_shadow_rejected() {
        // Base model already separates perfectly: candidate cannot beat
        // it by the margin, so the shadow window rejects it and the
        // live model never changes.
        let mut ad = OnlineAdapter::new(fast_cfg(), base_model(10));
        let mut t = 0.0;
        feed(&mut ad, &mut t, 16, 10, 30);
        let s = ad.stats();
        assert_eq!(s.retrains, 1);
        assert_eq!(s.swaps, 0);
        assert_eq!(s.shadow_rejected, 1);
        assert_eq!(ad.camera_version(0), 0);
        assert!(ad.utility_for(0, &feat(10)).is_none());
    }

    #[test]
    fn rescore_recent_uses_the_live_model() {
        let mut ad = OnlineAdapter::new(fast_cfg(), base_model(10));
        ad.observe_ingress(0, &feat(30));
        ad.observe_ingress(0, &feat(10));
        let mut out = Vec::new();
        ad.rescore_recent(&mut out);
        // Before any swap, the base model scores the ring.
        assert_eq!(out, vec![0.0, 1.0]);
        let mut t = 0.0;
        feed(&mut ad, &mut t, 16, 30, 10);
        assert_eq!(ad.camera_version(0), 1);
        ad.rescore_recent(&mut out);
        assert_eq!(out.len(), 2);
        assert!(out[0] > out[1], "swapped model must invert the ranking: {out:?}");
    }

    #[test]
    fn reseed_ring_is_bounded() {
        let mut ad = OnlineAdapter::new(
            AdaptationConfig { reseed_window: 4, ..fast_cfg() },
            base_model(10),
        );
        for _ in 0..10 {
            ad.observe_ingress(0, &feat(10));
        }
        let mut out = Vec::new();
        ad.rescore_recent(&mut out);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn stats_merge_sums_counters_and_orders_events() {
        let ev = |t_ms: f64, kind| AdaptEvent { t_ms, camera: 0, kind, version: 1 };
        let mut a = AdaptationStats {
            labels_observed: 3,
            retrains: 1,
            events: vec![ev(50.0, AdaptEventKind::Retrain)],
            ..Default::default()
        };
        let b = AdaptationStats {
            labels_observed: 2,
            swaps: 1,
            reseeds: 1,
            events: vec![ev(10.0, AdaptEventKind::Swap)],
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.labels_observed, 5);
        assert_eq!(a.retrains, 1);
        assert_eq!(a.swaps, 1);
        assert_eq!(a.reseeds, 1);
        assert_eq!(a.events[0].t_ms, 10.0);
        assert_eq!(a.events[1].t_ms, 50.0);
    }
}
