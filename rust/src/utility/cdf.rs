//! Utility CDF over recent history (paper §IV-C, Eq. 16/17): maps a target
//! drop rate to a utility threshold.
//!
//! The history H is a sliding window of recent frame utilities (seeded from
//! the training set at startup). `threshold_for(r)` returns the minimum
//! utility u_th with CDF(u_th) ≥ r, evaluated exactly over the window.
//!
//! The sorted view is maintained **incrementally**: each `add` does one
//! binary-search insert plus (once the window is full) one binary-search
//! remove of the evicted element — two O(|H|) memmoves on a flat `Vec`
//! instead of the historical O(|H|·log|H|) full re-sort per refresh. The
//! queries themselves are read-only binary searches, so per-frame cost is
//! flat and jitter-free (no periodic sort spikes on the hot path). The
//! equivalence with the old rebuild is pinned by a randomized test below.

use std::collections::VecDeque;

/// Sliding-window empirical CDF of frame utilities.
#[derive(Debug, Clone)]
pub struct UtilityCdf {
    window: VecDeque<f32>,
    cap: usize,
    /// Ascending multiset of `window`'s values, kept in sync by `add`.
    sorted: Vec<f32>,
}

impl UtilityCdf {
    /// `cap`: history size |H| (frames).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        UtilityCdf {
            window: VecDeque::with_capacity(cap),
            cap,
            sorted: Vec::with_capacity(cap),
        }
    }

    /// Seed the history from the training set's utilities (paper:
    /// "initially, the training data set itself can be used as H").
    pub fn seed(&mut self, utilities: &[f32]) {
        for &u in utilities {
            self.add(u);
        }
    }

    /// Drop the entire history (capacity retained). Used when a model
    /// swap invalidates the utility distribution: [`Self::seed`] appends,
    /// so re-seeding from shadow-scored utilities must clear first.
    pub fn clear(&mut self) {
        self.window.clear();
        self.sorted.clear();
    }

    /// Observe a new frame utility.
    pub fn add(&mut self, u: f32) {
        // NaN would poison the ordered view (the old rebuild panicked on
        // it at sort time; fail at the source instead).
        assert!(!u.is_nan(), "utility must not be NaN");
        if self.window.len() == self.cap {
            let old = self.window.pop_front().unwrap();
            // First index holding a value == old (value equality is all
            // the multiset needs; ties are interchangeable).
            let i = self.sorted.partition_point(|&x| x < old);
            debug_assert!(i < self.sorted.len() && self.sorted[i] == old);
            self.sorted.remove(i);
        }
        self.window.push_back(u);
        let j = self.sorted.partition_point(|&x| x <= u);
        self.sorted.insert(j, u);
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Empirical CDF(u) = |{x ∈ H : x ≤ u}| / |H| (Eq. 16).
    pub fn cdf(&self, u: f32) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        // partition_point gives the count of elements ≤ u.
        let count = self.sorted.partition_point(|&x| x <= u);
        count as f64 / self.sorted.len() as f64
    }

    /// Minimum utility threshold u_th with CDF(u_th) ≥ r (Eq. 17).
    ///
    /// r = 0 maps to threshold 0 (shed nothing: utilities are ≥ 0 and the
    /// shedder drops only frames with u < threshold). r = 1 maps to just
    /// above the window maximum (shed everything seen so far).
    pub fn threshold_for(&self, r: f64) -> f32 {
        let r = r.clamp(0.0, 1.0);
        if r == 0.0 {
            return 0.0;
        }
        if self.sorted.is_empty() {
            return 0.0;
        }
        let n = self.sorted.len();
        // Smallest sample index k with (k+1)/n ≥ r.
        let k = ((r * n as f64).ceil() as usize).max(1) - 1;
        let k = k.min(n - 1);
        let u = self.sorted[k];
        if r >= 1.0 {
            // Strictly above the max so even max-utility frames drop.
            f32::from_bits(u.to_bits() + 1)
        } else {
            u
        }
    }

    /// The fraction of the history that would drop at threshold `th`
    /// (frames with u < th).
    pub fn drop_fraction_at(&self, th: f32) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let count = self.sorted.partition_point(|&x| x < th);
        count as f64 / self.sorted.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    fn uniform_cdf() -> UtilityCdf {
        let mut c = UtilityCdf::new(1000);
        for i in 0..1000 {
            c.add(i as f32 / 1000.0);
        }
        c
    }

    #[test]
    fn cdf_basics() {
        let c = uniform_cdf();
        assert!((c.cdf(0.5) - 0.501).abs() < 2e-3);
        assert_eq!(c.cdf(-1.0), 0.0);
        assert_eq!(c.cdf(2.0), 1.0);
    }

    #[test]
    fn threshold_satisfies_eq17() {
        let c = uniform_cdf();
        for r in [0.1, 0.25, 0.5, 0.77, 0.9, 0.99] {
            let th = c.threshold_for(r);
            assert!(c.cdf(th) >= r, "r={r} th={th} cdf={}", c.cdf(th));
            // Minimality: the next-smaller sample violates Eq. 17.
            let eps = 1e-4;
            assert!(c.cdf(th - eps) < r, "threshold not minimal at r={r}");
        }
    }

    #[test]
    fn boundary_rates() {
        let c = uniform_cdf();
        assert_eq!(c.threshold_for(0.0), 0.0);
        let th1 = c.threshold_for(1.0);
        assert_eq!(c.drop_fraction_at(th1), 1.0, "r=1 must shed all history");
    }

    #[test]
    fn sliding_window_evicts() {
        let mut c = UtilityCdf::new(4);
        for u in [0.1, 0.2, 0.3, 0.4, 0.9, 0.9, 0.9, 0.9] {
            c.add(u);
        }
        assert_eq!(c.len(), 4);
        // All old low values evicted: threshold for 50% is now 0.9.
        assert_eq!(c.threshold_for(0.5), 0.9);
    }

    #[test]
    fn property_threshold_contract() {
        // ∀ random windows + rates: CDF(threshold_for(r)) ≥ r, and the
        // implied drop fraction never exceeds what ties force.
        Prop::new("cdf threshold contract").cases(60).run(|g| {
            let n = g.usize_in(1..400);
            let mut c = UtilityCdf::new(n.max(1));
            for _ in 0..n {
                c.add(g.f64_in(0.0, 1.0) as f32);
            }
            let r = g.unit_f64();
            let th = c.threshold_for(r);
            assert!(c.cdf(th) >= r - 1e-12, "cdf {} < r {}", c.cdf(th), r);
            // Dropping strictly-below-threshold never drops the whole
            // window unless r == 1 (there's always a frame with u == th).
            if r < 1.0 {
                assert!(c.drop_fraction_at(th) < 1.0);
            }
        });
    }

    #[test]
    fn property_threshold_monotone_in_rate() {
        Prop::new("threshold monotone in r").cases(40).run(|g| {
            let mut c = UtilityCdf::new(256);
            for _ in 0..g.usize_in(10..256) {
                c.add(g.f64_in(0.0, 1.0) as f32);
            }
            let (a, b) = (g.unit_f64(), g.unit_f64());
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            assert!(c.threshold_for(lo) <= c.threshold_for(hi));
        });
    }

    #[test]
    fn incremental_sort_matches_full_rebuild() {
        // The incremental insert/remove maintenance must be observationally
        // identical to the historical "rebuild + sort on refresh" at every
        // step of arbitrary add sequences (including window evictions).
        Prop::new("incremental cdf ≡ full rebuild").cases(40).run(|g| {
            let cap = 1 + g.usize_in(0..64);
            let mut c = UtilityCdf::new(cap);
            let mut shadow: Vec<f32> = Vec::new(); // the old window model
            let n_ops = g.usize_in(1..200);
            for _ in 0..n_ops {
                // Duplicates are likely (coarse grid) to stress tie paths.
                let u = (g.usize_in(0..16) as f32) / 16.0;
                c.add(u);
                shadow.push(u);
                if shadow.len() > cap {
                    shadow.remove(0);
                }
                // Old behavior: sort the window snapshot, then query it.
                let mut rebuilt = shadow.clone();
                rebuilt.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = rebuilt.len();
                let probe = (g.usize_in(0..18) as f32) / 16.0 - 0.0625;
                let count = rebuilt.partition_point(|&x| x <= probe);
                assert_eq!(c.cdf(probe), count as f64 / n as f64);
                let below = rebuilt.partition_point(|&x| x < probe);
                assert_eq!(c.drop_fraction_at(probe), below as f64 / n as f64);
                let r = g.unit_f64();
                let k = ((r * n as f64).ceil() as usize).max(1).min(n) - 1;
                let expect = if r >= 1.0 {
                    f32::from_bits(rebuilt[k].to_bits() + 1)
                } else if r == 0.0 {
                    0.0
                } else {
                    rebuilt[k]
                };
                assert_eq!(c.threshold_for(r), expect, "r={r} window={rebuilt:?}");
                assert_eq!(c.len(), n);
            }
        });
    }

    #[test]
    fn clear_then_reseed_replaces_history() {
        let mut c = uniform_cdf();
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.threshold_for(0.5), 0.0);
        c.seed(&[0.9; 10]);
        assert_eq!(c.len(), 10);
        assert_eq!(c.threshold_for(0.5), 0.9);
    }

    #[test]
    fn ties_handled() {
        let mut c = UtilityCdf::new(10);
        for _ in 0..10 {
            c.add(0.5);
        }
        // Any r>0 gives threshold 0.5; dropping u<0.5 drops nothing —
        // observed drop < target is expected with degenerate history
        // (paper §IV-C: observed rate "might not equal" target).
        assert_eq!(c.threshold_for(0.3), 0.5);
        assert_eq!(c.drop_fraction_at(0.5), 0.0);
    }
}
