//! Automatic hue-range selection (paper §VI "Automatic selection of Hue
//! ranges for a query"): instead of the developer providing hue ranges,
//! derive them from the training data by dominant-color analysis of
//! target-object bounding boxes.
//!
//! Method: histogram the hue of foreground pixels inside target bboxes
//! (vivid pixels only, mirroring what the utility function will key on),
//! subtract the background-traffic hue distribution, and return the top
//! contiguous hue intervals — with wrap-around handling so red maps onto
//! [0,10) ∪ [170,180) style pairs.

use crate::color::hsv::rgb_to_hsv;
use crate::color::{HueRanges, HUE_MAX};
use crate::video::{Video, VisibleObject};

/// Hue histogram resolution (degrees-of-half-circle per bin).
const BINS: usize = 36; // 5 hue-units per bin

/// Accumulates target vs non-target hue mass.
#[derive(Debug, Clone)]
pub struct HueSelector {
    target: [f64; BINS],
    other: [f64; BINS],
}

impl Default for HueSelector {
    fn default() -> Self {
        Self::new()
    }
}

impl HueSelector {
    pub fn new() -> Self {
        HueSelector { target: [0.0; BINS], other: [0.0; BINS] }
    }

    /// Observe one frame: pixels inside `targets` bboxes count as target
    /// mass; remaining foreground pixels as other mass.
    pub fn observe(
        &mut self,
        rgb: &[f32],
        background: &[f32],
        width: usize,
        height: usize,
        fg_threshold: f32,
        targets: &[VisibleObject],
    ) {
        for y in 0..height {
            for x in 0..width {
                let p = y * width + x;
                let d = (rgb[3 * p] - background[3 * p])
                    .abs()
                    .max((rgb[3 * p + 1] - background[3 * p + 1]).abs())
                    .max((rgb[3 * p + 2] - background[3 * p + 2]).abs());
                if d <= fg_threshold {
                    continue;
                }
                let (h, s, v) = rgb_to_hsv(rgb[3 * p], rgb[3 * p + 1], rgb[3 * p + 2]);
                // Key on vivid pixels: dominant *paint*, not shadows/glass.
                if s < 96.0 || v < 64.0 {
                    continue;
                }
                let bin = ((h / HUE_MAX * BINS as f32) as usize).min(BINS - 1);
                let inside = targets.iter().any(|o| {
                    let (x0, y0, x1, y1) = o.bbox;
                    x >= x0 && x < x1 && y >= y0 && y < y1
                });
                if inside {
                    self.target[bin] += 1.0;
                } else {
                    self.other[bin] += 1.0;
                }
            }
        }
    }

    /// Discriminative score per bin: target mass minus other mass (both
    /// normalized), clamped at zero.
    fn scores(&self) -> [f64; BINS] {
        let tsum: f64 = self.target.iter().sum::<f64>().max(1.0);
        let osum: f64 = self.other.iter().sum::<f64>().max(1.0);
        let mut s = [0.0; BINS];
        for i in 0..BINS {
            s[i] = (self.target[i] / tsum - self.other[i] / osum).max(0.0);
        }
        s
    }

    /// Select hue ranges covering at least `coverage` of the target mass
    /// (default use: 0.8). Returns up to two contiguous intervals
    /// (wrap-around treated as contiguous across 180→0).
    pub fn select(&self, coverage: f64) -> Option<HueRanges> {
        let scores = self.scores();
        let total: f64 = scores.iter().sum();
        if total <= 0.0 {
            return None;
        }
        // Greedily take bins by score until coverage reached.
        let mut order: Vec<usize> = (0..BINS).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        let mut picked = [false; BINS];
        let mut acc = 0.0;
        for &b in &order {
            if acc / total >= coverage {
                break;
            }
            if scores[b] <= 0.0 {
                break;
            }
            picked[b] = true;
            acc += scores[b];
        }
        // Merge picked bins into circular runs.
        let mut runs: Vec<(usize, usize)> = Vec::new(); // [start, end) in bins
        let mut i = 0;
        while i < BINS {
            if picked[i] && (i == 0 || !picked[i - 1]) {
                let mut j = i;
                while j < BINS && picked[j] {
                    j += 1;
                }
                runs.push((i, j));
                i = j;
            } else {
                i += 1;
            }
        }
        if runs.is_empty() {
            return None;
        }
        // Wrap-around: a run ending at BINS and one starting at 0 join.
        let wraps = runs.len() >= 2
            && runs.first().unwrap().0 == 0
            && runs.last().unwrap().1 == BINS;
        // Keep the two highest-mass runs (a HueRanges holds two intervals).
        let mass =
            |r: &(usize, usize)| -> f64 { scores[r.0..r.1].iter().sum() };
        runs.sort_by(|a, b| mass(b).partial_cmp(&mass(a)).unwrap());
        runs.truncate(2);
        runs.sort();
        let w = HUE_MAX / BINS as f32;
        let to_range = |r: &(usize, usize)| (r.0 as f32 * w, r.1 as f32 * w);
        Some(match runs.len() {
            1 => {
                let (lo, hi) = to_range(&runs[0]);
                HueRanges::single(lo, hi)
            }
            _ => {
                let (lo1, hi1) = to_range(&runs[0]);
                let (lo2, hi2) = to_range(&runs[1]);
                let _ = wraps; // both intervals returned either way
                HueRanges::pair(lo1, hi1, lo2, hi2)
            }
        })
    }

    /// Convenience: run over a set of labeled videos for target paints of
    /// a color the caller knows only by ground truth (object-level).
    pub fn from_videos<F: Fn(&VisibleObject) -> bool>(
        videos: &[Video],
        is_target: F,
        fg_threshold: f32,
    ) -> Self {
        let mut sel = HueSelector::new();
        for v in videos {
            for t in 0..v.len() {
                let f = v.render(t);
                let targets: Vec<VisibleObject> = f
                    .truth
                    .iter()
                    .filter(|o| is_target(o))
                    .cloned()
                    .collect();
                sel.observe(
                    &f.rgb,
                    v.background(),
                    f.width,
                    f.height,
                    fg_threshold,
                    &targets,
                );
            }
        }
        sel
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::NamedColor;
    use crate::video::{Paint, VideoConfig};

    fn videos_with(paint: Paint) -> Vec<Video> {
        let mut vc = VideoConfig::new(0x4E1, 3, 0, 120);
        vc.traffic.vehicle_rate = 0.6;
        vc.traffic.paint_weights = vec![
            (paint, 0.4),
            (Paint::Gray, 0.3),
            (Paint::DullRed, 0.15),
            (Paint::Silver, 0.15),
        ];
        vec![Video::new(vc)]
    }

    #[test]
    fn recovers_red_ranges_from_red_targets() {
        let videos = videos_with(Paint::VividRed);
        let sel = HueSelector::from_videos(
            &videos,
            |o| o.is_vehicle && o.paint == Paint::VividRed,
            25.0,
        );
        let ranges = sel.select(0.8).expect("ranges found");
        // The vivid red paint's hue (~0.9 half-degrees) must be covered.
        let (h, _, _) = {
            let [r, g, b] = Paint::VividRed.rgb();
            crate::color::hsv::rgb_to_hsv(r, g, b)
        };
        assert!(ranges.contains(h), "selected {ranges:?} misses target hue {h}");
        // And it must not span the whole hue circle.
        assert!(ranges.width() < 60.0, "ranges too wide: {ranges:?}");
    }

    #[test]
    fn recovers_yellow_ranges() {
        let videos = videos_with(Paint::VividYellow);
        let sel = HueSelector::from_videos(
            &videos,
            |o| o.is_vehicle && o.paint == Paint::VividYellow,
            25.0,
        );
        let ranges = sel.select(0.8).expect("ranges found");
        let yellow = NamedColor::Yellow.ranges();
        // Selected range must overlap the canonical yellow range.
        let mid = (yellow.lo1 + yellow.hi1) / 2.0;
        assert!(ranges.contains(mid), "selected {ranges:?} misses yellow {mid}");
    }

    #[test]
    fn no_targets_yields_none() {
        let videos = videos_with(Paint::Gray);
        let sel = HueSelector::from_videos(&videos, |_| false, 25.0);
        assert!(sel.select(0.8).is_none());
    }
}
