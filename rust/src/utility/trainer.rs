//! Training workflow (paper §IV-B, Fig. 7): from a labeled training set to
//! a [`UtilityModel`].
//!
//! Steps:
//!   1. extract PF matrices for every training frame (native oracle path —
//!      bit-equal to the artifacts, and training is offline anyway);
//!   2. average PF over positive / negative frames per color (Eq. 12/13);
//!   3. set the per-color normalization to the max raw utility seen in
//!      training, so normalized utilities peak at 1.0 (enables Eq. 15).

use super::model::{ColorModel, Combine, UtilityModel};
use crate::color::NamedColor;
use crate::features::{reference, FrameFeatures, HIST};
use crate::video::dataset::MIN_TARGET_PX;
use crate::video::Video;

/// A labeled training example: features + per-color positivity.
#[derive(Debug, Clone)]
pub struct LabeledFeatures {
    pub features: FrameFeatures,
    /// `labels[c]` = frame contains a target of color c.
    pub labels: Vec<bool>,
}

/// Accumulates Eq. 12/13 averages incrementally (streaming-friendly).
///
/// Counts are `f64` rather than integers so [`Self::decay`] can
/// exponentially down-weight history — the online-adaptation loop calls
/// it after every retrain, turning the accumulator into a decayed
/// sliding window over delayed backend ground truth.
#[derive(Debug, Clone)]
pub struct TrainerAccumulator {
    colors: Vec<NamedColor>,
    sum_pos: Vec<[f64; HIST]>,
    sum_neg: Vec<[f64; HIST]>,
    n_pos: Vec<f64>,
    n_neg: Vec<f64>,
}

impl TrainerAccumulator {
    pub fn new(colors: &[NamedColor]) -> Self {
        let k = colors.len();
        TrainerAccumulator {
            colors: colors.to_vec(),
            sum_pos: vec![[0.0; HIST]; k],
            sum_neg: vec![[0.0; HIST]; k],
            n_pos: vec![0.0; k],
            n_neg: vec![0.0; k],
        }
    }

    pub fn add(&mut self, ex: &LabeledFeatures) {
        assert_eq!(ex.labels.len(), self.colors.len());
        for c in 0..self.colors.len() {
            let (sum, n) = if ex.labels[c] {
                (&mut self.sum_pos[c], &mut self.n_pos[c])
            } else {
                (&mut self.sum_neg[c], &mut self.n_neg[c])
            };
            for (s, p) in sum.iter_mut().zip(&ex.features.pf[c]) {
                *s += *p as f64;
            }
            *n += 1.0;
        }
    }

    /// Exponentially decay all accumulated mass by `factor` ∈ [0, 1]:
    /// sums and counts scale together, so the per-bin averages (and
    /// therefore a finalize'd model) are unchanged until new examples
    /// arrive — newer labels then dominate older ones.
    pub fn decay(&mut self, factor: f64) {
        let factor = factor.clamp(0.0, 1.0);
        for c in 0..self.colors.len() {
            for s in self.sum_pos[c].iter_mut().chain(self.sum_neg[c].iter_mut()) {
                *s *= factor;
            }
            self.n_pos[c] *= factor;
            self.n_neg[c] *= factor;
        }
    }

    /// Positive-example mass for color `c`, rounded to a whole count
    /// (exact until the first [`Self::decay`]).
    pub fn positives(&self, c: usize) -> u64 {
        self.n_pos[c].round() as u64
    }

    /// Negative-example mass for color `c`, rounded to a whole count.
    pub fn negatives(&self, c: usize) -> u64 {
        self.n_neg[c].round() as u64
    }

    /// Finalize into a model; `examples` is re-scanned to compute the
    /// normalization constant (max raw utility over training frames).
    /// A class with zero mass yields an all-zero matrix (and the norm
    /// guard below keeps utilities finite), so sparse online windows
    /// can never produce NaN.
    pub fn finalize(
        &self,
        combine: Combine,
        fg_threshold: f32,
        examples: &[LabeledFeatures],
    ) -> UtilityModel {
        let k = self.colors.len();
        let mut colors = Vec::with_capacity(k);
        for c in 0..k {
            let avg = |sum: &[f64; HIST], n: f64| -> [f32; HIST] {
                let mut m = [0.0f32; HIST];
                if n > 0.0 {
                    for (mi, s) in m.iter_mut().zip(sum.iter()) {
                        *mi = (*s / n) as f32;
                    }
                }
                m
            };
            let m_pos = avg(&self.sum_pos[c], self.n_pos[c]);
            let m_neg = avg(&self.sum_neg[c], self.n_neg[c]);
            let mut cm = ColorModel {
                color: self.colors[c],
                ranges: self.colors[c].ranges(),
                m_pos,
                m_neg,
                norm: 1.0,
            };
            // Normalization: max raw utility across ALL training frames
            // (positive or negative — the CDF must cover both).
            let mut max_u = 0.0f32;
            for ex in examples {
                max_u = max_u.max(cm.utility_raw(&ex.features.pf[c]));
            }
            cm.norm = if max_u > 0.0 { max_u } else { 1.0 };
            colors.push(cm);
        }
        UtilityModel { colors, combine, fg_threshold }
    }
}

/// Extract labeled features from a set of videos (the offline training
/// pass). Labels use ground truth with the standard min-blob gate.
pub fn extract_labeled(
    videos: &[Video],
    indices: &[usize],
    colors: &[NamedColor],
    fg_threshold: f32,
) -> Vec<LabeledFeatures> {
    let ranges: Vec<_> = colors.iter().map(|c| c.ranges()).collect();
    let mut out = Vec::new();
    for &vi in indices {
        let video = &videos[vi];
        let bg = video.background();
        for t in 0..video.len() {
            let frame = video.render(t);
            let features = reference::compute_features(&frame.rgb, bg, &ranges, fg_threshold);
            let labels = colors
                .iter()
                .map(|&c| frame.is_positive(c, MIN_TARGET_PX))
                .collect();
            out.push(LabeledFeatures { features, labels });
        }
    }
    out
}

/// End-to-end training entry point (paper Fig. 7 "training stage").
pub fn train(
    videos: &[Video],
    train_indices: &[usize],
    colors: &[NamedColor],
    combine: Combine,
) -> UtilityModel {
    let fg_threshold = reference::FG_THRESHOLD;
    let examples = extract_labeled(videos, train_indices, colors, fg_threshold);
    let mut acc = TrainerAccumulator::new(colors);
    for ex in &examples {
        acc.add(ex);
    }
    acc.finalize(combine, fg_threshold, &examples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::{DatasetConfig, Paint, VideoConfig};

    fn target_rich_videos() -> Vec<Video> {
        // Two videos with plenty of red targets + dull-red confounders.
        (0..2)
            .map(|i| {
                let mut cfg = VideoConfig::new(3, 100 + i, i as u32, 250);
                cfg.traffic.vehicle_rate = 0.7;
                cfg.traffic.paint_weights = vec![
                    (Paint::VividRed, 0.3),
                    (Paint::DullRed, 0.2),
                    (Paint::Gray, 0.3),
                    (Paint::Silver, 0.2),
                ];
                Video::new(cfg)
            })
            .collect()
    }

    #[test]
    fn trained_model_separates_positive_and_negative() {
        let videos = target_rich_videos();
        let colors = [NamedColor::Red];
        let model = train(&videos, &[0], &colors, Combine::Single);
        assert_eq!(model.colors.len(), 1);
        assert!(model.colors[0].norm > 0.0);

        // Score the *held-out* video.
        let test = &videos[1];
        let ranges = model.ranges();
        let mut pos = Vec::new();
        let mut neg = Vec::new();
        for t in 0..test.len() {
            let f = test.render(t);
            let feats = reference::compute_features(
                &f.rgb,
                test.background(),
                &ranges,
                model.fg_threshold,
            );
            let u = model.utility(&feats).combined;
            if f.is_positive(NamedColor::Red, MIN_TARGET_PX) {
                pos.push(u);
            } else {
                neg.push(u);
            }
        }
        assert!(pos.len() > 10, "not enough positives: {}", pos.len());
        assert!(neg.len() > 10, "not enough negatives: {}", neg.len());
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        let (mp, mn) = (mean(&pos), mean(&neg));
        assert!(
            mp > 2.0 * mn,
            "positives not separated: pos {mp:.3} vs neg {mn:.3}"
        );
    }

    #[test]
    fn m_pos_concentrates_in_high_sat_bins() {
        // Paper Fig. 6: "bins with high saturation are better
        // differentiators of positive frames".
        let videos = target_rich_videos();
        let model = train(&videos, &[0, 1], &[NamedColor::Red], Combine::Single);
        let m = &model.colors[0].m_pos;
        let high_sat: f32 = (4..8).flat_map(|s| (0..8).map(move |v| m[s * 8 + v])).sum();
        let low_sat: f32 = (0..4).flat_map(|s| (0..8).map(move |v| m[s * 8 + v])).sum();
        assert!(
            high_sat > low_sat,
            "M+ should weight high-sat bins: hi {high_sat} lo {low_sat}"
        );
    }

    #[test]
    fn accumulator_counts() {
        let mut acc = TrainerAccumulator::new(&[NamedColor::Red]);
        let mk = |label: bool| LabeledFeatures {
            features: FrameFeatures {
                hf: vec![0.1],
                pf: vec![[1.0 / HIST as f32; HIST]],
                fg_frac: 0.2,
            },
            labels: vec![label],
        };
        acc.add(&mk(true));
        acc.add(&mk(true));
        acc.add(&mk(false));
        assert_eq!(acc.positives(0), 2);
        assert_eq!(acc.negatives(0), 1);
        let model = acc.finalize(Combine::Single, 25.0, &[mk(true)]);
        // Uniform PF everywhere → M⁺ uniform → utility = 1 after norm.
        let u = model.utility(&mk(true).features).combined;
        assert!((u - 1.0).abs() < 1e-5);
    }

    #[test]
    fn degenerate_classes_finalize_nan_free() {
        // Sparse online windows constantly see zero-positive (or
        // zero-negative) classes; the model must stay finite.
        let mk = |label: bool| LabeledFeatures {
            features: FrameFeatures {
                hf: vec![0.1],
                pf: vec![[1.0 / HIST as f32; HIST]],
                fg_frac: 0.2,
            },
            labels: vec![label],
        };
        for label in [true, false] {
            let mut acc = TrainerAccumulator::new(&[NamedColor::Red]);
            acc.add(&mk(label));
            acc.add(&mk(label));
            let examples = [mk(label)];
            let model = acc.finalize(Combine::Single, 25.0, &examples);
            let cm = &model.colors[0];
            assert!(cm.m_pos.iter().chain(cm.m_neg.iter()).all(|x| x.is_finite()));
            assert!(cm.norm.is_finite() && cm.norm > 0.0, "norm {}", cm.norm);
            let u = model.utility(&mk(label).features).combined;
            assert!(u.is_finite(), "utility {u}");
        }
        // Fully empty accumulator finalizes finite too.
        let acc = TrainerAccumulator::new(&[NamedColor::Red]);
        let model = acc.finalize(Combine::Single, 25.0, &[]);
        assert_eq!(model.colors[0].norm, 1.0);
        assert_eq!(model.utility(&mk(true).features).combined, 0.0);
    }

    #[test]
    fn decay_preserves_averages_then_new_labels_dominate() {
        let mk = |hot: usize, label: bool| {
            let mut pf = [0.0f32; HIST];
            pf[hot] = 1.0;
            LabeledFeatures {
                features: FrameFeatures { hf: vec![0.5], pf: vec![pf], fg_frac: 0.2 },
                labels: vec![label],
            }
        };
        let mut acc = TrainerAccumulator::new(&[NamedColor::Red]);
        for _ in 0..8 {
            acc.add(&mk(10, true));
            acc.add(&mk(20, false));
        }
        let before = acc.finalize(Combine::Single, 25.0, &[]);
        acc.decay(0.5);
        let after = acc.finalize(Combine::Single, 25.0, &[]);
        // Decay alone scales sums and counts together: averages intact.
        assert_eq!(before.colors[0].m_pos, after.colors[0].m_pos);
        assert_eq!(before.colors[0].m_neg, after.colors[0].m_neg);
        assert_eq!(acc.positives(0), 4);
        // A regime change after heavy decay dominates the old bin.
        acc.decay(0.1);
        for _ in 0..8 {
            acc.add(&mk(30, true));
        }
        let shifted = acc.finalize(Combine::Single, 25.0, &[]);
        assert!(
            shifted.colors[0].m_pos[30] > 10.0 * shifted.colors[0].m_pos[10],
            "new regime must dominate: {:?}",
            &shifted.colors[0].m_pos[..]
        );
    }

    #[test]
    fn dataset_integration_small() {
        let videos = crate::video::build_dataset(&DatasetConfig::tiny());
        let model = train(&videos, &[0, 1, 2], &[NamedColor::Red], Combine::Single);
        assert!(model.colors[0].norm > 0.0);
    }
}
