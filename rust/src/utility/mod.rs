//! Utility model: training (Eq. 12/13), scoring (Eq. 14), composition
//! (Eq. 15) and the drop-rate → threshold CDF mapping (Eq. 16/17).

pub mod adapt;
pub mod auc;
pub mod cdf;
pub mod hue_select;
pub mod model;
pub mod trainer;

pub use adapt::{AdaptEvent, AdaptEventKind, AdaptationConfig, AdaptationStats, OnlineAdapter};
pub use auc::roc_auc;
pub use cdf::UtilityCdf;
pub use hue_select::HueSelector;
pub use model::{ColorModel, Combine, UtilityModel};
pub use trainer::{extract_labeled, train, LabeledFeatures, TrainerAccumulator};
