//! Utility model: training (Eq. 12/13), scoring (Eq. 14), composition
//! (Eq. 15) and the drop-rate → threshold CDF mapping (Eq. 16/17).

pub mod adapt;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod auc;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod cdf;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod hue_select;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod model;
#[allow(missing_docs)] // item docs pending; module docs present
pub mod trainer;

pub use adapt::{AdaptEvent, AdaptEventKind, AdaptationConfig, AdaptationStats, OnlineAdapter};
pub use auc::roc_auc;
pub use cdf::UtilityCdf;
pub use hue_select::HueSelector;
pub use model::{ColorModel, Combine, UtilityModel};
pub use trainer::{extract_labeled, train, LabeledFeatures, TrainerAccumulator};
