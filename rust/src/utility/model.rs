//! The trained utility model: per-color M matrices (paper Eq. 12/13),
//! normalization, composition (Eq. 15), and (de)serialization.

use crate::color::{HueRanges, NamedColor};
use crate::features::{FrameFeatures, UtilityValues, HIST};
use crate::util::json::{self, Value};
use anyhow::{bail, Result};
use std::path::Path;

/// How per-color utilities compose into the query utility (paper §IV-B.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Combine {
    /// Single-color query.
    Single,
    /// Frames containing at least one target color: max of utilities.
    Or,
    /// Frames containing all target colors: min of utilities.
    And,
}

impl Combine {
    pub fn name(self) -> &'static str {
        match self {
            Combine::Single => "single",
            Combine::Or => "or",
            Combine::And => "and",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "single" => Some(Combine::Single),
            "or" => Some(Combine::Or),
            "and" => Some(Combine::And),
            _ => None,
        }
    }
}

/// Per-color trained parameters.
#[derive(Debug, Clone)]
pub struct ColorModel {
    pub color: NamedColor,
    pub ranges: HueRanges,
    /// M_{C,+ve}: mean PF over positive training frames (Eq. 12).
    pub m_pos: [f32; HIST],
    /// M_{C,-ve}: mean PF over negative training frames (Eq. 13; used for
    /// Fig. 6 and diagnostics, not for scoring).
    pub m_neg: [f32; HIST],
    /// Normalization constant: max raw utility over the training set, so
    /// normalized utilities peak at 1.0 (enables Eq. 15 composition).
    pub norm: f32,
}

impl ColorModel {
    /// Raw (unnormalized) utility U_C(f) = Σ M⁺ ⊙ PF (Eq. 14).
    pub fn utility_raw(&self, pf: &[f32; HIST]) -> f32 {
        self.m_pos.iter().zip(pf.iter()).map(|(m, p)| m * p).sum()
    }

    /// Normalized utility Ū_C(f).
    pub fn utility(&self, pf: &[f32; HIST]) -> f32 {
        if self.norm > 0.0 {
            self.utility_raw(pf) / self.norm
        } else {
            0.0
        }
    }

    /// M⁺ / norm — the matrix fed to the AOT artifacts so that the
    /// artifact's output is already the normalized utility.
    pub fn m_normalized(&self) -> [f32; HIST] {
        let mut m = self.m_pos;
        if self.norm > 0.0 {
            for x in m.iter_mut() {
                *x /= self.norm;
            }
        }
        m
    }
}

/// A trained utility model for a (possibly composite) query.
#[derive(Debug, Clone)]
pub struct UtilityModel {
    pub colors: Vec<ColorModel>,
    pub combine: Combine,
    /// Background-subtraction threshold the features were trained with.
    pub fg_threshold: f32,
}

impl UtilityModel {
    /// Hue ranges in artifact layout ([K][4]).
    pub fn ranges(&self) -> Vec<HueRanges> {
        self.colors.iter().map(|c| c.ranges).collect()
    }

    /// Compute utilities from features (native path; the artifact path
    /// computes the same values on-device).
    pub fn utility(&self, f: &FrameFeatures) -> UtilityValues {
        let mut out = UtilityValues::empty();
        self.utility_into(f, &mut out);
        out
    }

    /// Zero-allocation variant of [`Self::utility`]: reuses the caller's
    /// [`UtilityValues`] buffers.
    pub fn utility_into(&self, f: &FrameFeatures, out: &mut UtilityValues) {
        assert_eq!(f.num_colors(), self.colors.len(), "feature/color arity");
        out.per_color.clear();
        out.per_color
            .extend(self.colors.iter().zip(&f.pf).map(|(c, pf)| c.utility(pf)));
        out.combined = match self.combine {
            Combine::Single => out.per_color[0],
            Combine::Or => out.per_color.iter().cloned().fold(f32::MIN, f32::max),
            Combine::And => out.per_color.iter().cloned().fold(f32::MAX, f32::min),
        };
    }

    /// Which AOT artifact serves this model.
    pub fn artifact_name(&self) -> &'static str {
        match self.colors.len() {
            1 => "shedder_k1",
            2 => "shedder_k2",
            n => panic!("no artifact compiled for {n}-color queries"),
        }
    }

    // ---- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut colors = Vec::new();
        for c in &self.colors {
            let mut o = Value::object();
            o.set("color", Value::String(c.color.name().to_string()))
                .set("ranges", Value::from_f32_slice(&c.ranges.to_array()))
                .set("m_pos", Value::from_f32_slice(&c.m_pos))
                .set("m_neg", Value::from_f32_slice(&c.m_neg))
                .set("norm", Value::Number(c.norm as f64));
            colors.push(o);
        }
        let mut v = Value::object();
        v.set("combine", Value::String(self.combine.name().to_string()))
            .set("fg_threshold", Value::Number(self.fg_threshold as f64))
            .set("colors", Value::Array(colors));
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let combine = Combine::parse(v.get("combine")?.as_str()?)
            .ok_or_else(|| anyhow::anyhow!("bad combine"))?;
        let fg_threshold = v.get("fg_threshold")?.as_f64()? as f32;
        let mut colors = Vec::new();
        for c in v.get("colors")?.as_array()? {
            let name = c.get("color")?.as_str()?;
            let color = NamedColor::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown color '{name}'"))?;
            let r = c.get("ranges")?.to_f32_vec()?;
            if r.len() != 4 {
                bail!("ranges must have 4 entries");
            }
            let to_arr = |v: Vec<f32>| -> Result<[f32; HIST]> {
                if v.len() != HIST {
                    bail!("matrix must have {HIST} entries, got {}", v.len());
                }
                let mut a = [0.0; HIST];
                a.copy_from_slice(&v);
                Ok(a)
            };
            colors.push(ColorModel {
                color,
                ranges: HueRanges::pair(r[0], r[1], r[2], r[3]),
                m_pos: to_arr(c.get("m_pos")?.to_f32_vec()?)?,
                m_neg: to_arr(c.get("m_neg")?.to_f32_vec()?)?,
                norm: c.get("norm")?.as_f64()? as f32,
            });
        }
        if colors.is_empty() {
            bail!("model has no colors");
        }
        if combine == Combine::Single && colors.len() != 1 {
            bail!("single combine with {} colors", colors.len());
        }
        Ok(UtilityModel { colors, combine, fg_threshold })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&json::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model(combine: Combine, k: usize) -> UtilityModel {
        let mut colors = Vec::new();
        for i in 0..k {
            let mut m_pos = [0.0; HIST];
            m_pos[60 + i] = 0.8; // high-sat bins correlate with positives
            colors.push(ColorModel {
                color: if i == 0 { NamedColor::Red } else { NamedColor::Yellow },
                ranges: if i == 0 {
                    NamedColor::Red.ranges()
                } else {
                    NamedColor::Yellow.ranges()
                },
                m_pos,
                m_neg: [0.01; HIST],
                norm: 0.8,
            });
        }
        UtilityModel { colors, combine, fg_threshold: 25.0 }
    }

    fn features(hot: &[usize]) -> FrameFeatures {
        let mut pf = Vec::new();
        for &h in hot {
            let mut m = [0.0; HIST];
            m[h] = 1.0;
            pf.push(m);
        }
        FrameFeatures { hf: vec![0.5; hot.len()], pf, fg_frac: 0.1 }
    }

    #[test]
    fn single_color_utility_normalized() {
        let m = toy_model(Combine::Single, 1);
        let u = m.utility(&features(&[60]));
        assert!((u.combined - 1.0).abs() < 1e-6); // 0.8/0.8
        let u0 = m.utility(&features(&[10]));
        assert_eq!(u0.combined, 0.0);
    }

    #[test]
    fn or_takes_max_and_takes_min() {
        let or = toy_model(Combine::Or, 2);
        // color0 hits its hot bin (u=1), color1 misses (u=0).
        let u = or.utility(&features(&[60, 10]));
        assert!((u.combined - 1.0).abs() < 1e-6);
        let and = toy_model(Combine::And, 2);
        let u = and.utility(&features(&[60, 10]));
        assert_eq!(u.combined, 0.0);
        let u = and.utility(&features(&[60, 61]));
        assert!((u.combined - 1.0).abs() < 1e-6);
    }

    #[test]
    fn artifact_dispatch() {
        assert_eq!(toy_model(Combine::Single, 1).artifact_name(), "shedder_k1");
        assert_eq!(toy_model(Combine::Or, 2).artifact_name(), "shedder_k2");
    }

    #[test]
    fn json_roundtrip() {
        let m = toy_model(Combine::Or, 2);
        let v = m.to_json();
        let back = UtilityModel::from_json(&v).unwrap();
        assert_eq!(back.combine, Combine::Or);
        assert_eq!(back.colors.len(), 2);
        assert_eq!(back.colors[0].m_pos, m.colors[0].m_pos);
        assert_eq!(back.colors[0].norm, m.colors[0].norm);
        assert_eq!(back.colors[1].ranges, m.colors[1].ranges);
    }

    #[test]
    fn m_normalized_scales() {
        let m = toy_model(Combine::Single, 1);
        let mn = m.colors[0].m_normalized();
        assert!((mn[60] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("uals_model_test");
        let path = dir.join("model.json");
        let m = toy_model(Combine::Single, 1);
        m.save(&path).unwrap();
        let back = UtilityModel::load(&path).unwrap();
        assert_eq!(back.colors[0].m_pos, m.colors[0].m_pos);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_json_validation() {
        assert!(UtilityModel::from_json(&crate::util::json::parse("{}").unwrap()).is_err());
        let m = toy_model(Combine::Single, 1);
        let mut v = m.to_json();
        v.set("combine", Value::String("nope".into()));
        assert!(UtilityModel::from_json(&v).is_err());
    }
}
