//! The Load Shedder — the paper's system contribution (§IV).
//!
//! Composition (Fig. 3): per-frame utility arrives from the feature
//! extractor; [`AdmissionControl`] gates on the CDF-derived threshold
//! (Eq. 16–19); survivors enter the bounded [`UtilityQueue`] whose size the
//! [`ControlLoop`] tunes per Eq. 20; frames leave highest-utility-first,
//! paced by the backend's [`TokenBucket`].
//!
//! [`multi`] scales this to N concurrent queries over the same streams:
//! per-query shedder state behind a shared [`CapacityArbiter`], with one
//! feature extraction and one [`RateEstimator`] serving every query.

pub mod admission;
pub mod control_loop;
pub mod multi;
pub mod queue;
pub mod tokens;

pub use admission::{supported_throughput, target_drop_rate, AdmissionControl};
pub use control_loop::{ControlLoop, RateEstimator};
pub use multi::{
    ArbiterPolicy, CapacityArbiter, CompiledQuery, MultiShedder, QueryMask, QuerySet, QueryShedder,
    QuerySpec,
};
pub use queue::{Entry, Offer, UtilityQueue};
pub use tokens::TokenBucket;

use crate::config::{CostConfig, ShedderConfig};
use crate::metrics::DropCounter;

/// Why a frame was (not) shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Below the utility threshold (admission control).
    ShedAdmission,
    /// Queue full and lowest-utility (second-layer admission).
    ShedQueueReject,
    /// Enqueued (may still be evicted later by a better frame or shrink).
    Enqueued,
}

/// The full Load Shedder: admission + dynamic utility queue + pacing.
///
/// Generic over the frame payload `T` so the pipeline runners can carry
/// whatever bookkeeping they need (timestamps, ground truth, …).
pub struct LoadShedder<T> {
    pub admission: AdmissionControl,
    pub queue: UtilityQueue<T>,
    pub control: ControlLoop,
    /// Retune cadence in ingress frames (from [`ShedderConfig`]; the
    /// shedder borrows its config at construction instead of cloning it).
    update_every: usize,
    drops: DropCounter,
    /// Frames evicted after admission (for stats: they count as drops).
    evictions: u64,
    ingress_since_update: usize,
    /// Nominal ingress fps fallback before the estimator warms up.
    default_fps: f64,
    /// When false, the periodic retune (threshold + queue resize) is
    /// disabled — used by baseline policies that pin the threshold.
    pub auto_retune: bool,
}

impl<T> LoadShedder<T> {
    pub fn new(
        cfg: &ShedderConfig,
        costs: &CostConfig,
        latency_bound_ms: f64,
        default_fps: f64,
    ) -> Self {
        let admission = AdmissionControl::new(cfg.history);
        let mut control = ControlLoop::new(cfg, costs, latency_bound_ms);
        // Cold-start fallback (Eq. 19): before the estimator has two
        // arrivals in its window, report the deployment's nominal rate.
        control.set_nominal_fps(default_fps);
        let queue = UtilityQueue::new(cfg.queue_cap_max);
        LoadShedder {
            admission,
            queue,
            control,
            update_every: cfg.update_every,
            drops: DropCounter::default(),
            evictions: 0,
            ingress_since_update: 0,
            default_fps,
            auto_retune: true,
        }
    }

    /// Seed the utility history from the training set.
    pub fn seed_history(&mut self, utilities: &[f32]) {
        self.admission.seed(utilities);
    }

    /// Replace the utility history after an online model swap: clear the
    /// stale (old-model-scored) window, seed it with `utilities` scored by
    /// the new model, and re-derive the threshold at the current target
    /// rate so admission stays coherent with the scores it now sees.
    pub fn reseed_history(&mut self, utilities: &[f32]) {
        self.admission.reseed(utilities);
    }

    /// Ingress: offer a frame with its utility. Returns the decision for
    /// *this* frame plus all **other** queued frames dropped as a side
    /// effect (displacement eviction, or a retune shrinking the queue).
    /// The offered frame itself is never in the returned vector — its fate
    /// is the returned decision.
    pub fn on_ingress(&mut self, utility: f32, now_ms: f64, item: T) -> (Decision, Vec<Entry<T>>) {
        self.on_ingress_keyed(utility, utility, now_ms, item)
    }

    /// Like [`Self::on_ingress`] but with a separate queue-ordering key —
    /// used by the queue-policy ablation (constant key ⇒ FIFO service,
    /// same admission control).
    pub fn on_ingress_keyed(
        &mut self,
        utility: f32,
        queue_key: f32,
        now_ms: f64,
        item: T,
    ) -> (Decision, Vec<Entry<T>>) {
        let mut dropped = Vec::new();
        let d = self.on_ingress_keyed_into(utility, queue_key, now_ms, item, &mut dropped);
        if d != Decision::Enqueued {
            // `_into` appends the offered frame last when it is shed;
            // this legacy API reports its fate via the decision only.
            dropped.pop();
        }
        (d, dropped)
    }

    /// Zero-allocation ingress: the caller's `dropped` buffer (cleared and
    /// reused across frames) receives **every** frame shed by this call —
    /// retune evictions, a displaced queue victim, and, unlike
    /// [`Self::on_ingress_keyed`], the offered frame itself (appended
    /// last) when the decision is a shed. Hot loops can thus account for
    /// all drops uniformly without cloning per-frame payloads.
    pub fn on_ingress_keyed_into(
        &mut self,
        utility: f32,
        queue_key: f32,
        now_ms: f64,
        item: T,
        dropped: &mut Vec<Entry<T>>,
    ) -> Decision {
        self.control.observe_ingress(now_ms);
        self.admission.observe(utility);
        self.ingress_since_update += 1;
        if self.auto_retune && self.ingress_since_update >= self.update_every {
            self.retune_into(dropped);
        }

        if !self.admission.admit(utility) {
            self.drops.observe(true);
            dropped.push(Entry { utility, arrival_ms: now_ms, item });
            return Decision::ShedAdmission;
        }
        match self.queue.offer(queue_key, now_ms, item) {
            Offer::Accepted { evicted } => {
                self.drops.observe(false);
                if let Some(e) = evicted {
                    self.evictions += 1;
                    dropped.push(e);
                }
                Decision::Enqueued
            }
            Offer::Rejected(entry) => {
                self.drops.observe(true);
                dropped.push(entry);
                Decision::ShedQueueReject
            }
        }
    }

    /// Backend finished a frame after `proc_ms`: feed the control loop.
    /// (Token release is the pipeline runner's job — it owns the bucket.)
    pub fn on_backend_complete(&mut self, proc_ms: f64) {
        self.control.observe_backend(proc_ms);
    }

    /// Re-normalize the nominal fps fallback (Eq. 19 cold-start / outage
    /// value) — per-camera liveness calls this when cameras drop out so
    /// the rate fallback tracks the cameras actually alive.
    pub fn set_nominal_fps(&mut self, fps: f64) {
        let fps = fps.max(0.0);
        self.default_fps = fps;
        self.control.set_nominal_fps(fps);
    }

    /// Next frame to transmit (highest utility), if any.
    pub fn next_to_send(&mut self) -> Option<Entry<T>> {
        self.queue.pop_best()
    }

    /// Re-derive threshold and queue capacity from current load. Evicted
    /// frames (from a shrink) are counted as drops and returned.
    pub fn retune(&mut self) -> Vec<Entry<T>> {
        let mut dropped = Vec::new();
        self.retune_into(&mut dropped);
        dropped
    }

    /// [`Self::retune`] appending evictions to a caller-owned buffer.
    pub fn retune_into(&mut self, dropped: &mut Vec<Entry<T>>) {
        self.ingress_since_update = 0;
        let rate = self.control.target_drop_rate(self.default_fps);
        self.admission.set_target_rate(rate);
        let size = self.control.queue_size();
        let evicted = self.queue.resize(size);
        self.evictions += evicted.len() as u64;
        dropped.extend(evicted);
    }

    /// Observed drop rate so far (admission + queue rejections; queue
    /// evictions tracked separately in `evictions`).
    pub fn observed_drop_rate(&self) -> f64 {
        self.drops.drop_rate()
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn ingress_count(&self) -> u64 {
        self.drops.ingress
    }

    pub fn threshold(&self) -> f32 {
        self.admission.threshold()
    }

    pub fn target_rate(&self) -> f64 {
        self.admission.target_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk() -> LoadShedder<u64> {
        LoadShedder::new(
            &ShedderConfig { update_every: 5, ..Default::default() },
            &CostConfig::default(),
            1000.0,
            10.0,
        )
    }

    #[test]
    fn ingress_into_reports_offered_frame_and_matches_legacy() {
        let mut a = mk();
        let mut b = mk();
        let mut rng = Rng::new(17);
        for _ in 0..100 {
            a.on_backend_complete(400.0);
            b.on_backend_complete(400.0);
        }
        let mut dropped = Vec::new();
        for i in 0..400u64 {
            let u = rng.f32();
            let t = i as f64 * 100.0;
            let (d_legacy, ev_legacy) = a.on_ingress(u, t, i);
            dropped.clear();
            let d_into = b.on_ingress_keyed_into(u, u, t, i, &mut dropped);
            assert_eq!(d_legacy, d_into, "i={i}");
            if d_into == Decision::Enqueued {
                assert_eq!(dropped.len(), ev_legacy.len());
            } else {
                // `_into` additionally carries the offered frame, last.
                assert_eq!(dropped.len(), ev_legacy.len() + 1);
                assert_eq!(dropped.last().unwrap().item, i);
            }
            for (x, y) in dropped.iter().zip(&ev_legacy) {
                assert_eq!(x.item, y.item);
            }
            if i % 7 == 0 {
                a.next_to_send();
                b.next_to_send();
            }
        }
        assert_eq!(a.observed_drop_rate(), b.observed_drop_rate());
        assert_eq!(a.evictions(), b.evictions());
    }

    #[test]
    fn no_load_no_shedding() {
        let mut ls = mk();
        ls.seed_history(&[0.1, 0.2, 0.9]);
        for i in 0..50 {
            ls.on_backend_complete(5.0); // fast backend
            let (d, _) = ls.on_ingress(0.05, i as f64 * 100.0, i);
            assert_ne!(d, Decision::ShedAdmission, "shed at i={i}");
        }
        assert_eq!(ls.target_rate(), 0.0);
    }

    #[test]
    fn overload_raises_threshold_and_sheds_low_utility() {
        let mut ls = mk();
        let mut rng = Rng::new(3);
        // Slow backend: 500 ms → ST 2 fps vs ingress 10 fps → rate 0.8.
        for _ in 0..100 {
            ls.on_backend_complete(500.0);
        }
        let mut shed_low = 0;
        let mut kept_high = 0;
        for i in 0..600 {
            let u = rng.f32();
            let (d, _) = ls.on_ingress(u, i as f64 * 100.0, i);
            // After warmup, low-utility frames shed, high-utility kept.
            if i > 200 {
                if u < 0.5 && d == Decision::ShedAdmission {
                    shed_low += 1;
                }
                if u > 0.95 && d == Decision::Enqueued {
                    kept_high += 1;
                }
            }
            // Drain the queue so it never interferes.
            while ls.next_to_send().is_some() {}
        }
        assert!(ls.target_rate() > 0.75, "rate={}", ls.target_rate());
        assert!(shed_low > 100, "shed_low={shed_low}");
        assert!(kept_high > 5, "kept_high={kept_high}");
    }

    #[test]
    fn queue_eviction_prefers_best_frames() {
        let mut ls = mk();
        // Tiny queue via tight latency bound. Force capacity by retune.
        for _ in 0..100 {
            ls.on_backend_complete(300.0); // queue_size small
        }
        ls.retune();
        let cap = ls.queue.capacity();
        assert!(cap >= 1);
        // Fill beyond capacity with increasing utility; the queue must end
        // up holding the top-cap utilities.
        for i in 0..(cap + 5) {
            let u = i as f32 / (cap + 5) as f32;
            ls.on_ingress(u, i as f64, i as u64);
        }
        let mut sent = Vec::new();
        while let Some(e) = ls.next_to_send() {
            sent.push(e.utility);
        }
        assert_eq!(sent.len(), cap.min(cap + 5));
        for w in sent.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // Best retained utility is the global max offered.
        let max_offered = (cap + 4) as f32 / (cap + 5) as f32;
        assert!((sent[0] - max_offered).abs() < 1e-6);
    }

    #[test]
    fn observed_drop_rate_tracks_decisions() {
        let mut ls = mk();
        for _ in 0..100 {
            ls.on_backend_complete(1000.0); // ST 1 fps → rate 0.9
        }
        let mut rng = Rng::new(9);
        for i in 0..500 {
            let u = rng.f32();
            ls.on_ingress(u, i as f64 * 100.0, i);
            while ls.next_to_send().is_some() {}
        }
        let r = ls.observed_drop_rate();
        assert!(r > 0.5, "observed drop rate {r}");
    }
}
