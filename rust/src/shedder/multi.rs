//! Multi-query shared-stream shedding: N concurrent queries over the same
//! camera streams, sharing **one** feature-extraction pass per frame and
//! **one** backend capacity budget.
//!
//! The paper scores each frame "toward the query at hand"; a production
//! edge node serves many applications at once (cf. FilterForward's shared
//! per-frame base computation and the timely-edge-analytics capacity
//! arbitration line of work). This module supplies the shedder-layer
//! pieces:
//!
//! * [`QuerySet`] — N queries compiled against one *union* utility model:
//!   hue-mask / bin histograms are extracted once per frame for the union
//!   of all query colors, and each query's utility is a cheap reduction
//!   ([`Combine`]) over its colors' shared per-color utilities.
//! * [`CapacityArbiter`] — splits the measured backend budget (one unit of
//!   backend time per wall second) across queries: weighted fair share
//!   with work-conserving reallocation of idle share (water-filling), or
//!   the standalone configuration where every query sees the full budget
//!   (the verification mode: each query then behaves exactly like an
//!   independent single-query pipeline).
//! * [`MultiShedder`] — per-query Load-Shedder state (own utility
//!   threshold + CDF window, own [`UtilityQueue`], own [`TokenBucket`],
//!   own backend-latency EWMA) behind the shared arbiter, with **one**
//!   shared [`RateEstimator`] driving every query's control loop.
//!
//! The pipeline layer (`pipeline::multi`) runs the event loop; the
//! per-query decision semantics here mirror [`super::LoadShedder`]
//! operation-for-operation so that, under [`ArbiterPolicy::Standalone`]
//! and deterministic costs, every query's decision log bit-matches an
//! independent single-query run (pinned by `rust/tests/multiquery.rs`).

use super::admission::{target_drop_rate, AdmissionControl};
use super::control_loop::{ControlLoop, RateEstimator};
use super::queue::{Entry, Offer, UtilityQueue};
use super::tokens::TokenBucket;
use super::Decision;
use crate::color::NamedColor;
use crate::config::{CostConfig, QueryConfig, ShedderConfig};
use crate::features::UtilityValues;
use crate::metrics::DropCounter;
use crate::utility::{train, Combine, ColorModel, UtilityModel};
use crate::video::Video;
use anyhow::{bail, Result};

/// Bitset of query indices (admission bitset on
/// [`crate::pipeline::FramePayload`]): bit `q` set = query `q`'s admission
/// control admitted the frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueryMask(pub u64);

impl QueryMask {
    /// Hard cap on concurrent queries per node (one bit each).
    pub const MAX_QUERIES: usize = 64;

    pub fn empty() -> Self {
        QueryMask(0)
    }

    /// A mask with only query `q` set.
    pub fn single(q: usize) -> Self {
        let mut m = QueryMask(0);
        m.set(q);
        m
    }

    pub fn set(&mut self, q: usize) {
        assert!(q < Self::MAX_QUERIES, "query index {q} out of mask range");
        self.0 |= 1 << q;
    }

    pub fn contains(&self, q: usize) -> bool {
        q < Self::MAX_QUERIES && self.0 & (1 << q) != 0
    }

    pub fn count(&self) -> u32 {
        self.0.count_ones()
    }

    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }
}

/// One application query as the developer states it: target colors +
/// latency bound ([`QueryConfig`]) plus its arbiter weight.
#[derive(Debug, Clone)]
pub struct QuerySpec {
    pub name: String,
    pub query: QueryConfig,
    /// Relative capacity weight under the fair-share arbiter (> 0).
    pub weight: f64,
}

impl QuerySpec {
    pub fn new(name: impl Into<String>, query: QueryConfig) -> Self {
        QuerySpec { name: name.into(), query, weight: 1.0 }
    }

    pub fn with_weight(mut self, weight: f64) -> Self {
        assert!(weight > 0.0, "arbiter weight must be positive");
        self.weight = weight;
        self
    }
}

/// A query compiled against the union model: its colors resolved to
/// indices into the union's per-color utilities.
#[derive(Debug, Clone)]
pub struct CompiledQuery {
    pub name: String,
    pub config: QueryConfig,
    pub weight: f64,
    /// Indices into the union model's color list, in the query's own
    /// color order (preserves the [`Combine`] fold order of an
    /// independent single-query model).
    pub color_idx: Vec<usize>,
}

/// N queries sharing one feature extraction: the union utility model plus
/// the per-query reductions over it.
#[derive(Debug, Clone)]
pub struct QuerySet {
    union: UtilityModel,
    queries: Vec<CompiledQuery>,
}

impl QuerySet {
    /// Distinct colors across the specs, first-seen order.
    pub fn union_colors(specs: &[QuerySpec]) -> Vec<NamedColor> {
        let mut out: Vec<NamedColor> = Vec::new();
        for s in specs {
            for &c in &s.query.colors {
                if !out.contains(&c) {
                    out.push(c);
                }
            }
        }
        out
    }

    /// Compile the specs against a trained union model. The model must
    /// carry every color any spec references.
    pub fn from_model(union: UtilityModel, specs: &[QuerySpec]) -> Result<QuerySet> {
        if specs.is_empty() {
            bail!("query set needs at least one query");
        }
        if specs.len() > QueryMask::MAX_QUERIES {
            bail!(
                "at most {} concurrent queries, got {}",
                QueryMask::MAX_QUERIES,
                specs.len()
            );
        }
        let mut queries = Vec::with_capacity(specs.len());
        for s in specs {
            let mut color_idx = Vec::with_capacity(s.query.colors.len());
            for &c in &s.query.colors {
                let idx = union
                    .colors
                    .iter()
                    .position(|m| m.color == c)
                    .ok_or_else(|| {
                        anyhow::anyhow!("union model lacks color '{}' (query '{}')", c.name(), s.name)
                    })?;
                color_idx.push(idx);
            }
            queries.push(CompiledQuery {
                name: s.name.clone(),
                config: s.query.clone(),
                weight: s.weight,
                color_idx,
            });
        }
        Ok(QuerySet { union, queries })
    }

    /// Train the union model for the specs on a training set and compile.
    /// Per-color training (Eq. 12–14) is independent per color, so the
    /// union's [`ColorModel`]s are identical to what each query would get
    /// from its own training run on the same videos.
    pub fn train(specs: &[QuerySpec], videos: &[Video], train_idx: &[usize]) -> Result<QuerySet> {
        let colors = Self::union_colors(specs);
        if colors.is_empty() {
            bail!("query set references no colors");
        }
        let combine = if colors.len() == 1 { Combine::Single } else { Combine::Or };
        let union = train(videos, train_idx, &colors, combine);
        Self::from_model(union, specs)
    }

    /// The shared extraction model (build the one [`crate::features::Extractor`]
    /// from this).
    pub fn union_model(&self) -> &UtilityModel {
        &self.union
    }

    pub fn queries(&self) -> &[CompiledQuery] {
        &self.queries
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    pub fn weights(&self) -> Vec<f64> {
        self.queries.iter().map(|q| q.weight).collect()
    }

    pub fn latency_bounds(&self) -> Vec<f64> {
        self.queries.iter().map(|q| q.config.latency_bound_ms).collect()
    }

    /// The standalone single-query model of query `q` (its colors cloned
    /// out of the union): what an independent pipeline for this query
    /// would run — used by the bit-match tests and the independent-vs-
    /// shared benchmark.
    pub fn query_model(&self, q: usize) -> UtilityModel {
        let cq = &self.queries[q];
        let colors: Vec<ColorModel> = cq
            .color_idx
            .iter()
            .map(|&i| self.union.colors[i].clone())
            .collect();
        UtilityModel {
            colors,
            combine: cq.config.combine,
            fg_threshold: self.union.fg_threshold,
        }
    }

    /// Per-query combined utilities from the union model's per-color
    /// utilities — the cheap reduction that replaces N full extractions.
    /// Folds exactly as [`UtilityModel::utility_into`] would for the
    /// query's own model, so the values are bit-identical to independent
    /// extraction.
    pub fn utilities_into(&self, union_utils: &UtilityValues, out: &mut Vec<f32>) {
        debug_assert_eq!(union_utils.per_color.len(), self.union.colors.len());
        out.clear();
        for q in &self.queries {
            let pick = |i: &usize| union_utils.per_color[*i];
            let u = match q.config.combine {
                Combine::Single => union_utils.per_color[q.color_idx[0]],
                Combine::Or => q.color_idx.iter().map(pick).fold(f32::MIN, f32::max),
                Combine::And => q.color_idx.iter().map(pick).fold(f32::MAX, f32::min),
            };
            out.push(u);
        }
    }
}

// ---------------------------------------------------------------------------
// Capacity arbitration
// ---------------------------------------------------------------------------

/// How the shared backend budget is split across queries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArbiterPolicy {
    /// Every query sees the full backend budget, exactly as if it ran its
    /// own single-query pipeline (Eq. 19 per query). The verification
    /// configuration: per-query decisions bit-match independent runs.
    Standalone,
    /// Weighted fair share of backend time. With `work_conserving`, share
    /// a query does not demand is re-offered to backlogged queries in
    /// weight proportion (water-filling); without it, idle share is
    /// wasted (strict reservation).
    WeightedFair { work_conserving: bool },
}

/// Splits one unit of backend time per second across queries.
///
/// Demands and allocations are *time fractions*: query `q` demanding
/// `need_q = ingress_fps × proc_q / 1000` wants `need_q` seconds of
/// backend time per second. The allocation `φ_q` caps the fraction of its
/// demand the query may transmit; its Eq. 19 target drop rate becomes
/// `1 − φ_q / need_q`.
#[derive(Debug, Clone)]
pub struct CapacityArbiter {
    policy: ArbiterPolicy,
    weights: Vec<f64>,
}

impl CapacityArbiter {
    pub fn new(policy: ArbiterPolicy, weights: Vec<f64>) -> Self {
        assert!(!weights.is_empty(), "arbiter needs at least one query");
        assert!(
            weights.iter().all(|&w| w > 0.0),
            "arbiter weights must be positive"
        );
        CapacityArbiter { policy, weights }
    }

    pub fn policy(&self) -> ArbiterPolicy {
        self.policy
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Allocate time fractions for the given demands; `phi` is cleared
    /// and filled with one allocation per query (`Σ φ ≤ 1`).
    pub fn allocate_into(&self, needs: &[f64], phi: &mut Vec<f64>) {
        assert_eq!(needs.len(), self.weights.len(), "one demand per query");
        phi.clear();
        match self.policy {
            ArbiterPolicy::Standalone => {
                // Full budget per query (over-commitment is the point:
                // this mode reproduces N independent pipelines).
                phi.extend(needs.iter().map(|n| n.clamp(0.0, 1.0)));
            }
            ArbiterPolicy::WeightedFair { work_conserving } => {
                let wsum: f64 = self.weights.iter().sum();
                if !work_conserving {
                    phi.extend(
                        needs
                            .iter()
                            .zip(&self.weights)
                            .map(|(&n, &w)| n.clamp(0.0, w / wsum)),
                    );
                    return;
                }
                // Work-conserving water-fill: repeatedly offer the
                // remaining capacity to unsatisfied queries in weight
                // proportion; queries whose residual demand fits inside
                // their share are satisfied exactly and removed. Each
                // round satisfies at least one query or exhausts the
                // budget, so this terminates in ≤ N rounds.
                phi.resize(needs.len(), 0.0);
                let mut remaining = 1.0f64;
                let mut unsat: Vec<usize> =
                    (0..needs.len()).filter(|&i| needs[i] > 0.0).collect();
                while remaining > 1e-12 && !unsat.is_empty() {
                    let ws: f64 = unsat.iter().map(|&i| self.weights[i]).sum();
                    let per_w = remaining / ws;
                    let mut satisfied = Vec::new();
                    for &i in &unsat {
                        let gap = needs[i] - phi[i];
                        if gap <= per_w * self.weights[i] + 1e-12 {
                            satisfied.push(i);
                        }
                    }
                    if satisfied.is_empty() {
                        // Nobody saturates: split everything by weight.
                        for &i in &unsat {
                            phi[i] += per_w * self.weights[i];
                        }
                        break;
                    }
                    for &i in &satisfied {
                        remaining -= needs[i] - phi[i];
                        phi[i] = needs[i];
                    }
                    unsat.retain(|i| !satisfied.contains(i));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The multi-query shedder
// ---------------------------------------------------------------------------

/// One query's Load-Shedder state: own threshold + CDF window, own
/// bounded utility queue, own token bucket, own backend-latency EWMA.
pub struct QueryShedder<T> {
    pub admission: AdmissionControl,
    pub queue: UtilityQueue<T>,
    pub control: ControlLoop,
    pub tokens: TokenBucket,
    drops: DropCounter,
    evictions: u64,
}

impl<T> QueryShedder<T> {
    pub fn observed_drop_rate(&self) -> f64 {
        self.drops.drop_rate()
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

/// N per-query shedders behind one [`CapacityArbiter`], driven by one
/// shared [`RateEstimator`]. Generic over the queued item `T` like
/// [`super::LoadShedder`].
pub struct MultiShedder<T> {
    queries: Vec<QueryShedder<T>>,
    arbiter: CapacityArbiter,
    /// The one shared ingress-rate estimator: every query sees the same
    /// arrival stream, so one measurement drives all N control loops.
    rate: RateEstimator,
    update_every: usize,
    ingress_since_update: usize,
    default_fps: f64,
    /// Reused retune scratch (per-query time demands / allocations).
    needs_buf: Vec<f64>,
    phi_buf: Vec<f64>,
}

impl<T> MultiShedder<T> {
    /// `latency_bounds[q]` is query q's LB (ms); `weights[q]` its arbiter
    /// weight; `tokens_per_query` the per-query transmission window (the
    /// single-pipeline `backend_tokens`).
    pub fn new(
        latency_bounds: &[f64],
        weights: &[f64],
        cfg: &ShedderConfig,
        costs: &CostConfig,
        tokens_per_query: u32,
        policy: ArbiterPolicy,
        default_fps: f64,
    ) -> Self {
        assert_eq!(latency_bounds.len(), weights.len());
        assert!(!latency_bounds.is_empty(), "need at least one query");
        let queries = latency_bounds
            .iter()
            .map(|&lb| QueryShedder {
                admission: AdmissionControl::new(cfg.history),
                queue: UtilityQueue::new(cfg.queue_cap_max),
                control: ControlLoop::new(cfg, costs, lb),
                tokens: TokenBucket::new(tokens_per_query.max(1)),
                drops: DropCounter::default(),
                evictions: 0,
            })
            .collect();
        MultiShedder {
            queries,
            arbiter: CapacityArbiter::new(policy, weights.to_vec()),
            rate: RateEstimator::new(3_000.0).with_nominal(default_fps),
            update_every: cfg.update_every,
            ingress_since_update: 0,
            default_fps,
            needs_buf: Vec::new(),
            phi_buf: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    pub fn arbiter(&self) -> &CapacityArbiter {
        &self.arbiter
    }

    /// Measured shared ingress rate (nominal fallback before warmup).
    pub fn fps(&self) -> f64 {
        let f = self.rate.fps();
        if f > 0.0 {
            f
        } else {
            self.default_fps
        }
    }

    /// Shared per-arrival pre-step: one rate observation, every query's
    /// CDF updated with its own utility, and the periodic retune
    /// (threshold + queue size per query from the arbitrated budget).
    /// Queue-shrink evictions land in `dropped[q]`. Mirrors the first
    /// half of [`super::LoadShedder::on_ingress_keyed_into`] per query.
    pub fn observe_arrival(
        &mut self,
        now_ms: f64,
        utilities: &[f32],
        dropped: &mut [Vec<Entry<T>>],
    ) -> bool {
        assert_eq!(utilities.len(), self.queries.len());
        assert_eq!(dropped.len(), self.queries.len());
        self.rate.observe(now_ms);
        for (q, &u) in self.queries.iter_mut().zip(utilities) {
            q.admission.observe(u);
        }
        self.ingress_since_update += 1;
        if self.ingress_since_update >= self.update_every {
            self.retune_into(dropped);
            true
        } else {
            false
        }
    }

    /// Re-derive every query's threshold and queue capacity from the
    /// shared rate measurement and the arbitrated capacity split.
    pub fn retune_into(&mut self, dropped: &mut [Vec<Entry<T>>]) {
        self.ingress_since_update = 0;
        let fps = self.fps();
        match self.arbiter.policy() {
            ArbiterPolicy::Standalone => {
                // Exactly the single-pipeline Eq. 19 derivation per query
                // (same expression, same rounding — the bit-match mode).
                for (q, dr) in self.queries.iter_mut().zip(dropped.iter_mut()) {
                    let rate = target_drop_rate(q.control.effective_service_ms(), fps);
                    q.admission.set_target_rate(rate);
                    let evicted = q.queue.resize(q.control.queue_size());
                    q.evictions += evicted.len() as u64;
                    dr.extend(evicted);
                }
            }
            ArbiterPolicy::WeightedFair { .. } => {
                // Time demands: need_q = fps × proc_q (fraction of one
                // backend-second the query wants per second).
                self.needs_buf.clear();
                self.needs_buf.extend(
                    self.queries
                        .iter()
                        .map(|q| fps * q.control.effective_service_ms() / 1000.0),
                );
                self.arbiter.allocate_into(&self.needs_buf, &mut self.phi_buf);
                for (i, (q, dr)) in
                    self.queries.iter_mut().zip(dropped.iter_mut()).enumerate()
                {
                    let need = self.needs_buf[i];
                    let phi = self.phi_buf[i];
                    let rate = if need <= 0.0 || phi + 1e-12 >= need {
                        0.0
                    } else {
                        (1.0 - phi / need).clamp(0.0, 1.0)
                    };
                    q.admission.set_target_rate(rate);
                    // Eq. 20 with the *effective* service latency: a query
                    // holding a φ share of the backend sees its frames
                    // drain 1/φ× slower, so its queue must shrink
                    // accordingly (satisfied demand ⇒ slowdown 1).
                    let slowdown = if phi > 0.0 { (need / phi).max(1.0) } else { f64::INFINITY };
                    let evicted = q.queue.resize(q.control.queue_size_with_slowdown(slowdown));
                    q.evictions += evicted.len() as u64;
                    dr.extend(evicted);
                }
            }
        }
    }

    /// Read-only admission predicate (the payload bitset): would query
    /// `q` admit a frame of this utility right now? Identical to the
    /// check [`Self::offer`] applies.
    pub fn admits(&self, q: usize, utility: f32) -> bool {
        self.queries[q].admission.admit(utility)
    }

    /// Replace query `q`'s utility history after an online model swap and
    /// re-cut its threshold at the current target rate (the multi-query
    /// counterpart of [`super::LoadShedder::reseed_history`]).
    pub fn reseed_query_history(&mut self, q: usize, utilities: &[f32]) {
        self.queries[q].admission.reseed(utilities);
    }

    /// Offer the frame to query `q` (after [`Self::observe_arrival`]).
    /// Every frame this call sheds — a displaced queue victim or the
    /// offered frame itself (appended last) — lands in `dropped`, like
    /// [`super::LoadShedder::on_ingress_keyed_into`].
    pub fn offer(
        &mut self,
        q: usize,
        utility: f32,
        now_ms: f64,
        item: T,
        dropped: &mut Vec<Entry<T>>,
    ) -> Decision {
        let qs = &mut self.queries[q];
        if !qs.admission.admit(utility) {
            qs.drops.observe(true);
            dropped.push(Entry { utility, arrival_ms: now_ms, item });
            return Decision::ShedAdmission;
        }
        match qs.queue.offer(utility, now_ms, item) {
            Offer::Accepted { evicted } => {
                qs.drops.observe(false);
                if let Some(e) = evicted {
                    qs.evictions += 1;
                    dropped.push(e);
                }
                Decision::Enqueued
            }
            Offer::Rejected(entry) => {
                qs.drops.observe(true);
                dropped.push(entry);
                Decision::ShedQueueReject
            }
        }
    }

    /// Query `q`'s backend finished a frame after `proc_ms`.
    pub fn on_backend_complete(&mut self, q: usize, proc_ms: f64) {
        self.queries[q].control.observe_backend(proc_ms);
    }

    /// The transport layer measured one delivered frame's
    /// (camera→shedder, shedder→backend) transfer pair for query `q`.
    pub fn observe_network(&mut self, q: usize, cam_ms: f64, ls_q_ms: f64) {
        self.queries[q].control.observe_network(cam_ms, ls_q_ms);
    }

    /// Query `q`'s smoothed shedder→backend transfer (ms) — the Eq. 20
    /// network term its dispatch deadline check budgets with.
    pub fn net_ls_q_ms(&self, q: usize) -> f64 {
        self.queries[q].control.net_ls_q_ms()
    }

    /// Next frame query `q` should transmit (highest utility), if any.
    pub fn next_to_send(&mut self, q: usize) -> Option<Entry<T>> {
        self.queries[q].queue.pop_best()
    }

    pub fn tokens(&mut self, q: usize) -> &mut TokenBucket {
        &mut self.queries[q].tokens
    }

    pub fn query(&self, q: usize) -> &QueryShedder<T> {
        &self.queries[q]
    }

    pub fn threshold(&self, q: usize) -> f32 {
        self.queries[q].admission.threshold()
    }

    pub fn target_rate(&self, q: usize) -> f64 {
        self.queries[q].admission.target_rate()
    }

    pub fn proc_q_ms(&self, q: usize) -> f64 {
        self.queries[q].control.proc_q_ms()
    }

    /// Poisoned control observations query `q`'s input validation
    /// rejected (see [`ControlLoop::rejected_samples`]).
    pub fn rejected_samples(&self, q: usize) -> u64 {
        self.queries[q].control.rejected_samples()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::UtilityValues;

    #[test]
    fn query_mask_ops() {
        let mut m = QueryMask::empty();
        assert!(m.is_empty());
        m.set(0);
        m.set(7);
        assert!(m.contains(0) && m.contains(7) && !m.contains(3));
        assert_eq!(m.count(), 2);
        assert_eq!(QueryMask::single(3).0, 0b1000);
        assert!(!QueryMask::single(5).contains(64));
    }

    #[test]
    #[should_panic(expected = "out of mask range")]
    fn query_mask_rejects_out_of_range() {
        let mut m = QueryMask::empty();
        m.set(64);
    }

    fn specs_red_yellow() -> Vec<QuerySpec> {
        use crate::color::NamedColor::{Red, Yellow};
        vec![
            QuerySpec::new("amber", QueryConfig::single(Red)),
            QuerySpec::new("taxi", QueryConfig::single(Yellow)).with_weight(2.0),
            QuerySpec::new(
                "either",
                QueryConfig::composite(Red, Yellow, Combine::Or),
            ),
        ]
    }

    #[test]
    fn union_colors_dedup_preserves_order() {
        let u = QuerySet::union_colors(&specs_red_yellow());
        use crate::color::NamedColor::{Red, Yellow};
        assert_eq!(u, vec![Red, Yellow]);
    }

    fn toy_union() -> UtilityModel {
        use crate::color::NamedColor::{Red, Yellow};
        use crate::features::HIST;
        let mk = |c: NamedColor, hot: usize| {
            let mut m_pos = [0.0; HIST];
            m_pos[hot] = 0.5;
            ColorModel { color: c, ranges: c.ranges(), m_pos, m_neg: [0.0; HIST], norm: 0.5 }
        };
        UtilityModel {
            colors: vec![mk(Red, 62), mk(Yellow, 61)],
            combine: Combine::Or,
            fg_threshold: 25.0,
        }
    }

    #[test]
    fn compile_maps_colors_and_reductions_match_per_query_models() {
        let set = QuerySet::from_model(toy_union(), &specs_red_yellow()).unwrap();
        assert_eq!(set.len(), 3);
        assert_eq!(set.queries()[0].color_idx, vec![0]);
        assert_eq!(set.queries()[1].color_idx, vec![1]);
        assert_eq!(set.queries()[2].color_idx, vec![0, 1]);
        assert_eq!(set.queries()[1].weight, 2.0);

        // Reductions equal the standalone models' own composition.
        let utils = UtilityValues { per_color: vec![0.8, 0.3], combined: 0.8 };
        let mut per_query = Vec::new();
        set.utilities_into(&utils, &mut per_query);
        assert_eq!(per_query, vec![0.8, 0.3, 0.8]);
        for q in 0..set.len() {
            let model = set.query_model(q);
            assert_eq!(model.colors.len(), set.queries()[q].color_idx.len());
            assert_eq!(model.combine, set.queries()[q].config.combine);
        }
    }

    #[test]
    fn compile_rejects_missing_color() {
        use crate::color::NamedColor::Blue;
        let specs = vec![QuerySpec::new("blue", QueryConfig::single(Blue))];
        assert!(QuerySet::from_model(toy_union(), &specs).is_err());
        assert!(QuerySet::from_model(toy_union(), &[]).is_err());
    }

    fn fair(weights: &[f64], work_conserving: bool) -> CapacityArbiter {
        CapacityArbiter::new(
            ArbiterPolicy::WeightedFair { work_conserving },
            weights.to_vec(),
        )
    }

    fn alloc(a: &CapacityArbiter, needs: &[f64]) -> Vec<f64> {
        let mut phi = Vec::new();
        a.allocate_into(needs, &mut phi);
        phi
    }

    #[test]
    fn standalone_gives_every_query_the_full_budget() {
        let a = CapacityArbiter::new(ArbiterPolicy::Standalone, vec![1.0, 1.0]);
        assert_eq!(alloc(&a, &[0.4, 2.5]), vec![0.4, 1.0]);
    }

    #[test]
    fn fair_share_underload_satisfies_everyone() {
        let phi = alloc(&fair(&[1.0, 1.0, 1.0], true), &[0.2, 0.3, 0.1]);
        assert_eq!(phi, vec![0.2, 0.3, 0.1]);
    }

    #[test]
    fn fair_share_overload_splits_by_weight() {
        let phi = alloc(&fair(&[3.0, 1.0], true), &[9.0, 9.0]);
        assert!((phi[0] - 0.75).abs() < 1e-9 && (phi[1] - 0.25).abs() < 1e-9);
        let total: f64 = phi.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn work_conserving_reallocates_idle_share() {
        // Query 0 demands little; its slack must flow to query 1.
        let wc = alloc(&fair(&[1.0, 1.0], true), &[0.2, 5.0]);
        assert!((wc[0] - 0.2).abs() < 1e-9);
        assert!((wc[1] - 0.8).abs() < 1e-9, "slack not reallocated: {wc:?}");
        // Strict reservation wastes it.
        let strict = alloc(&fair(&[1.0, 1.0], false), &[0.2, 5.0]);
        assert!((strict[1] - 0.5).abs() < 1e-9, "reservation leaked: {strict:?}");
    }

    #[test]
    fn water_fill_cascades_through_multiple_levels() {
        // Weights equal; demands 0.1, 0.25, 10 → first two satisfied, the
        // third takes the rest.
        let phi = alloc(&fair(&[1.0, 1.0, 1.0], true), &[0.1, 0.25, 10.0]);
        assert!((phi[0] - 0.1).abs() < 1e-9);
        assert!((phi[1] - 0.25).abs() < 1e-9);
        assert!((phi[2] - 0.65).abs() < 1e-9, "{phi:?}");
        // Zero-demand queries receive nothing.
        let z = alloc(&fair(&[1.0, 1.0], true), &[0.0, 3.0]);
        assert_eq!(z[0], 0.0);
        assert!((z[1] - 1.0).abs() < 1e-9);
    }

    fn mk_multi(policy: ArbiterPolicy) -> MultiShedder<u64> {
        MultiShedder::new(
            &[1000.0, 1000.0],
            &[1.0, 1.0],
            &ShedderConfig { update_every: 5, ..Default::default() },
            &CostConfig::default(),
            1,
            policy,
            10.0,
        )
    }

    #[test]
    fn standalone_queries_match_a_single_load_shedder() {
        // Query 0 of a standalone MultiShedder must make exactly the same
        // decisions as a plain LoadShedder fed the same stream.
        use crate::util::rng::Rng;
        let mut multi = mk_multi(ArbiterPolicy::Standalone);
        let mut single: super::super::LoadShedder<u64> = super::super::LoadShedder::new(
            &ShedderConfig { update_every: 5, ..Default::default() },
            &CostConfig::default(),
            1000.0,
            10.0,
        );
        let mut rng = Rng::new(0xA11);
        let mut m_dropped = [Vec::new(), Vec::new()];
        let mut s_dropped = Vec::new();
        let mut o_dropped = Vec::new();
        for i in 0..400u64 {
            let t = i as f64 * 100.0;
            if i % 3 == 0 {
                multi.on_backend_complete(0, 450.0);
                multi.on_backend_complete(1, 450.0);
                single.on_backend_complete(450.0);
            }
            let u = rng.f32();
            for d in m_dropped.iter_mut() {
                d.clear();
            }
            s_dropped.clear();
            o_dropped.clear();
            // Both queries see the same utility: their decisions agree too.
            multi.observe_arrival(t, &[u, u], &mut m_dropped);
            let dm = multi.offer(0, u, t, i, &mut o_dropped);
            let _ = multi.offer(1, u, t, i, &mut m_dropped[1]);
            let ds = single.on_ingress_keyed_into(u, u, t, i, &mut s_dropped);
            assert_eq!(dm, ds, "frame {i}");
            let multi_all: Vec<u64> = m_dropped[0]
                .iter()
                .chain(o_dropped.iter())
                .map(|e| e.item)
                .collect();
            let single_all: Vec<u64> = s_dropped.iter().map(|e| e.item).collect();
            assert_eq!(multi_all, single_all, "frame {i}");
            assert_eq!(multi.threshold(0), single.threshold(), "frame {i}");
            assert_eq!(multi.target_rate(0), single.target_rate(), "frame {i}");
            if i % 4 == 0 {
                let a = multi.next_to_send(0).map(|e| e.item);
                multi.next_to_send(1);
                let b = single.next_to_send().map(|e| e.item);
                assert_eq!(a, b, "frame {i}");
            }
        }
        assert_eq!(
            multi.query(0).observed_drop_rate(),
            single.observed_drop_rate()
        );
        assert_eq!(multi.query(0).evictions(), single.evictions());
    }

    #[test]
    fn reseed_query_history_is_per_query() {
        let mut m = mk_multi(ArbiterPolicy::Standalone);
        let mut dropped = [Vec::new(), Vec::new()];
        for i in 0..50u64 {
            let u = i as f32 / 50.0;
            m.observe_arrival(i as f64 * 100.0, &[u, u], &mut dropped);
        }
        // Pin a 50% target on both, then reseed only query 1 with a
        // high-scoring distribution: query 0's threshold must not move.
        for q in 0..2 {
            let rate = {
                let qs = &mut m.queries[q];
                qs.admission.set_target_rate(0.5);
                qs.admission.threshold()
            };
            assert!(rate > 0.3 && rate < 0.7, "q{q} th={rate}");
        }
        let th0_before = m.threshold(0);
        m.reseed_query_history(1, &[0.9; 64]);
        assert_eq!(m.threshold(0), th0_before);
        assert_eq!(m.threshold(1), 0.9);
        assert!((m.target_rate(1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fair_share_throttles_low_weight_query_harder() {
        use crate::util::rng::Rng;
        let mut m = MultiShedder::<u64>::new(
            &[1000.0, 1000.0],
            &[4.0, 1.0],
            &ShedderConfig { update_every: 5, ..Default::default() },
            &CostConfig::default(),
            1,
            ArbiterPolicy::WeightedFair { work_conserving: true },
            10.0,
        );
        // Both queries saturated: 500 ms backends at 10 fps ingress.
        let mut rng = Rng::new(7);
        let mut dropped = [Vec::new(), Vec::new()];
        for i in 0..400u64 {
            let t = i as f64 * 100.0;
            m.on_backend_complete(0, 500.0);
            m.on_backend_complete(1, 500.0);
            let u = rng.f32();
            for d in dropped.iter_mut() {
                d.clear();
            }
            m.observe_arrival(t, &[u, u], &mut dropped);
            m.offer(0, u, t, i, &mut dropped[0]);
            m.offer(1, u, t, i, &mut dropped[1]);
            while m.next_to_send(0).is_some() {}
            while m.next_to_send(1).is_some() {}
        }
        assert!(
            m.target_rate(1) > m.target_rate(0) + 0.1,
            "weights not honored: q0 {} q1 {}",
            m.target_rate(0),
            m.target_rate(1)
        );
        // Both overloaded → the arbiter still sheds on the heavy query.
        assert!(m.target_rate(0) > 0.5, "q0 rate {}", m.target_rate(0));
    }
}
