//! Admission control (paper §IV-D "Admission Control"): translate backend
//! load into a target drop rate (Eq. 18/19) and a utility threshold
//! (Eq. 16/17), then gate ingress frames on it.

use crate::utility::UtilityCdf;

/// Supported throughput (Eq. 18): frames/sec the backend sustains at the
/// current average processing latency.
pub fn supported_throughput(proc_q_ms: f64) -> f64 {
    if proc_q_ms <= 0.0 {
        f64::INFINITY
    } else {
        1000.0 / proc_q_ms
    }
}

/// Target drop rate (Eq. 19): fraction of ingress that must be shed for
/// the backend to keep up.
pub fn target_drop_rate(proc_q_ms: f64, ingress_fps: f64) -> f64 {
    if ingress_fps <= 0.0 {
        return 0.0;
    }
    (1.0 - supported_throughput(proc_q_ms) / ingress_fps).max(0.0)
}

/// Threshold-based admission gate over the utility CDF of recent history.
#[derive(Debug, Clone)]
pub struct AdmissionControl {
    cdf: UtilityCdf,
    threshold: f32,
    target_rate: f64,
}

impl AdmissionControl {
    /// `history`: |H|, the CDF window size in frames.
    pub fn new(history: usize) -> Self {
        AdmissionControl { cdf: UtilityCdf::new(history), threshold: 0.0, target_rate: 0.0 }
    }

    /// Seed the history with training-set utilities (paper §IV-C).
    pub fn seed(&mut self, utilities: &[f32]) {
        self.cdf.seed(utilities);
    }

    /// Observe an ingress frame's utility (updates H).
    pub fn observe(&mut self, utility: f32) {
        self.cdf.add(utility);
    }

    /// Replace the utility history wholesale and re-derive the threshold
    /// at the current target rate. Used when a utility-model swap (online
    /// adaptation) invalidates the distribution the threshold was cut
    /// from: the old history was scored by the old model, so the gate
    /// must re-anchor on utilities the *new* model assigns.
    pub fn reseed(&mut self, utilities: &[f32]) {
        self.cdf.clear();
        self.cdf.seed(utilities);
        self.threshold = self.cdf.threshold_for(self.target_rate);
    }

    /// Re-derive the threshold for a target drop rate (Eq. 17).
    pub fn set_target_rate(&mut self, rate: f64) {
        self.target_rate = rate.clamp(0.0, 1.0);
        self.threshold = self.cdf.threshold_for(self.target_rate);
    }

    /// Convenience: Eq. 18/19 then Eq. 17.
    pub fn retune(&mut self, proc_q_ms: f64, ingress_fps: f64) -> f64 {
        let rate = target_drop_rate(proc_q_ms, ingress_fps);
        self.set_target_rate(rate);
        rate
    }

    /// Admit iff utility ≥ threshold (the shedder "drops frames with
    /// utility less than the threshold").
    pub fn admit(&self, utility: f32) -> bool {
        utility >= self.threshold
    }

    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    pub fn target_rate(&self) -> f64 {
        self.target_rate
    }

    pub fn history_len(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn eq18_eq19() {
        assert!((supported_throughput(100.0) - 10.0).abs() < 1e-12);
        // Backend handles 10 fps, ingress 40 fps → shed 75%.
        assert!((target_drop_rate(100.0, 40.0) - 0.75).abs() < 1e-12);
        // Backend faster than ingress → no shedding (max with 0).
        assert_eq!(target_drop_rate(10.0, 50.0), 0.0);
        assert_eq!(target_drop_rate(0.0, 50.0), 0.0);
    }

    #[test]
    fn admits_everything_at_zero_rate() {
        let mut ac = AdmissionControl::new(100);
        ac.seed(&[0.1, 0.5, 0.9]);
        ac.set_target_rate(0.0);
        assert!(ac.admit(0.0));
        assert!(ac.admit(1.0));
    }

    #[test]
    fn threshold_tracks_history_distribution() {
        let mut ac = AdmissionControl::new(1000);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            ac.observe(rng.f32());
        }
        ac.set_target_rate(0.6);
        assert!((ac.threshold() - 0.6).abs() < 0.05, "th={}", ac.threshold());
        // Roughly 60% of uniform draws now rejected.
        let mut rejected = 0;
        for _ in 0..10_000 {
            rejected += (!ac.admit(rng.f32())) as u32;
        }
        let frac = rejected as f64 / 10_000.0;
        assert!((frac - 0.6).abs() < 0.05, "rejected {frac}");
    }

    #[test]
    fn retune_pipeline() {
        let mut ac = AdmissionControl::new(100);
        for i in 0..100 {
            ac.observe(i as f32 / 100.0);
        }
        // proc_q = 200 ms → ST 5 fps; ingress 10 fps → rate 0.5.
        let r = ac.retune(200.0, 10.0);
        assert!((r - 0.5).abs() < 1e-12);
        assert!(ac.threshold() > 0.4 && ac.threshold() < 0.6);
    }

    #[test]
    fn reseed_replaces_history_and_recuts_threshold() {
        let mut ac = AdmissionControl::new(100);
        for i in 0..100 {
            ac.observe(i as f32 / 100.0);
        }
        ac.set_target_rate(0.5);
        let th_old = ac.threshold();
        assert!(th_old > 0.4 && th_old < 0.6, "th_old={th_old}");
        // New model scores everything near 0.9: the old ~0.5 threshold
        // would admit 100%; reseed re-anchors at the same target rate.
        let rescored: Vec<f32> = (0..100).map(|i| 0.85 + i as f32 * 0.001).collect();
        ac.reseed(&rescored);
        assert_eq!(ac.history_len(), 100);
        assert!((ac.target_rate() - 0.5).abs() < 1e-12);
        assert!(ac.threshold() > 0.85, "th={}", ac.threshold());
        let admitted = rescored.iter().filter(|&&u| ac.admit(u)).count();
        assert!((admitted as f64 / 100.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn property_admission_rate_matches_target_on_history() {
        // On the history itself, the fraction admitted ≈ 1 - target rate
        // (exact up to ties, always erring on admitting more).
        Prop::new("admission rate vs target").cases(40).run(|g| {
            let n = g.usize_in(20..500);
            let mut ac = AdmissionControl::new(n);
            let us: Vec<f32> = (0..n).map(|_| g.f64_in(0.0, 1.0) as f32).collect();
            ac.seed(&us);
            let r = g.unit_f64();
            ac.set_target_rate(r);
            let dropped = us.iter().filter(|&&u| !ac.admit(u)).count();
            let dropped_frac = dropped as f64 / n as f64;
            // Threshold = min u with CDF ≥ r and admission keeps u == th,
            // so the dropped fraction is the largest achievable value < r.
            assert!(dropped_frac <= r + 1e-9, "dropped {dropped_frac} > r {r}");
            // And it cannot be short by more than the probability mass of
            // one sample value (ties aside, 1/n granularity).
            let th = ac.threshold();
            let ties = us.iter().filter(|&&u| (u - th).abs() < 1e-12).count();
            assert!(
                dropped_frac + (ties as f64 + 1.0) / n as f64 >= r,
                "dropped {dropped_frac}, ties {ties}, r {r}"
            );
        });
    }
}
