//! Token-based transmission control (paper §V-B): the backend grants the
//! Load Shedder one token per free processing slot; the shedder sends its
//! current best frame only when a token is available, otherwise it keeps
//! buffering/evicting. Replaces the paper's ZeroMQ token channel with an
//! in-process counter (semantics preserved).

/// Counting token bucket with a fixed capacity (backend queue slots).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    capacity: u32,
    available: u32,
    acquired_total: u64,
    released_total: u64,
}

impl TokenBucket {
    pub fn new(capacity: u32) -> Self {
        assert!(capacity > 0, "token capacity must be ≥ 1");
        TokenBucket { capacity, available: capacity, acquired_total: 0, released_total: 0 }
    }

    /// Try to take a token (send one frame downstream).
    pub fn try_acquire(&mut self) -> bool {
        if self.available > 0 {
            self.available -= 1;
            self.acquired_total += 1;
            true
        } else {
            false
        }
    }

    /// Return a token (backend finished a frame).
    pub fn release(&mut self) {
        assert!(
            self.available < self.capacity,
            "token release without acquire (available {} / cap {})",
            self.available,
            self.capacity
        );
        self.available += 1;
        self.released_total += 1;
    }

    pub fn available(&self) -> u32 {
        self.available
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Frames currently in flight at the backend.
    pub fn in_flight(&self) -> u32 {
        self.capacity - self.available
    }

    pub fn acquired_total(&self) -> u64 {
        self.acquired_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut t = TokenBucket::new(2);
        assert!(t.try_acquire());
        assert!(t.try_acquire());
        assert!(!t.try_acquire()); // exhausted
        assert_eq!(t.in_flight(), 2);
        t.release();
        assert!(t.try_acquire());
        assert_eq!(t.acquired_total(), 3);
    }

    #[test]
    #[should_panic]
    fn release_overflow_panics() {
        let mut t = TokenBucket::new(1);
        t.release();
    }

    #[test]
    fn conservation_property() {
        use crate::util::prop::Prop;
        Prop::new("token conservation").cases(50).run(|g| {
            let cap = g.usize_in(1..8) as u32;
            let mut t = TokenBucket::new(cap);
            let mut held = 0u32;
            for _ in 0..200 {
                if g.bool() {
                    if t.try_acquire() {
                        held += 1;
                    }
                } else if held > 0 {
                    t.release();
                    held -= 1;
                }
                assert_eq!(t.available() + held, cap);
            }
        });
    }
}
